"""Measured autotuning (ROADMAP: per-backend autotune cache).

AutoTVM/Ansor-style closed loop for the paper's "pick the best impl per
device" story: instead of trusting the analytical roofline alone, the
benchmark driver (``benchmarks/autotune.py``) times every registered impl of
an op through the dispatch table and persists the results here; the election
pass (``passes.elect_implementations``) prefers those measurements and falls
back to the (optionally calibrated) roofline when the cache is cold.

The Tunable protocol — any kernel, not just the matmul:

A dispatch-table impl may declare a :class:`Tunable` at registration
(``register_shared_impl(..., tunable=Tunable(attr, space))``):

* ``tune_space(node, hw)`` yields the candidate configs for one node —
  integer tuples keyed off the backend's ``HardwareSpec`` (MXU tile sizes,
  attention block sizes, DFP block rows / fusion-split sizes, scan block
  lengths) and clamped/deduplicated against the node's shape;
* ``bind_config(node, cfg)`` pins one config on the node under the
  tunable's ``node.attrs[attr]`` key (``cfg=None`` clears it) — the impl
  reads the same attr at lowering time, so a pinned election reaches the
  kernel with zero extra plumbing.

The sweep in ``benchmarks/autotune.py`` iterates whatever the registry
declares: for every admissible impl it measures each config in the tune
space and records the winner's config next to its time; the election pass
re-binds that config whenever the measurement wins (and *clears* every
candidate's tunable attr first, so re-election never leaves a stale pin).

Cache keying — (op kind, canonicalized shape bucket, dtype, backend, impl):

* shapes canonicalize to **nearest-power-of-two buckets** per dim, so one
  measurement covers a neighbourhood of shapes and the file stays small;
* unseen buckets resolve by **nearest-bucket lookup**: among same-rank
  buckets for the same (op, dtype, backend), minimize L1 distance in
  log2-space;
* LINEAR/MATMUL key on the problem (M, K, N) — leading batch dims collapse
  into M — every other op keys on its output shape.

File format (JSON, schema-versioned):

    {"schema": 1,
     "entries": {"matmul|float32|pallas_tpu|256x256x256":
                   {"pallas.matmul_mxu": {"us": 12.3,
                                          "config": [128, 128, 128],
                                          "flops": 3.4e7, "nbytes": 7.9e5},
                    "ref.matmul": {"us": 20.1, ...}}},
     "calibration": {"pallas_tpu": {"matmul":
                   {"s_per_flop": 5e-15, "s_per_byte": 1.2e-12, "n": 6}}}}

Determinism guarantees: ``save`` is atomic (tmp + ``os.replace``), and a file
whose ``schema`` does not match :data:`SCHEMA_VERSION` is *ignored* on load
(the cache comes back empty with ``stale=True``), never misread.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
from typing import Callable, Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 1

# process-wide cache consulted by the election pass; empty unless the user
# opts in via SOL_AUTOTUNE_CACHE or set_cache()/load_cache()
_CACHE: Optional["AutotuneCache"] = None

EntryKey = Tuple[str, str, str]                  # (op, dtype, backend)
Bucket = Tuple[int, ...]
Config = Tuple[int, ...]                         # one tunable kernel config


@dataclasses.dataclass(frozen=True)
class Tunable:
    """A kernel impl's tuning declaration (see module docstring).

    ``attr``  — the ``node.attrs`` key configs are pinned under; one key per
                kernel family (``'mxu_block'``, ``'attn_block'``, ...), so
                clearing and pinning never collide across impls.
    ``space`` — ``space(node, hw) -> [config, ...]``: candidate configs for
                one node on one ``HardwareSpec``; may be empty (nothing to
                sweep for this shape).
    ``bind``  — optional override of the default pin/clear behaviour.
    ``refine``— optional override of :meth:`refine_space` — the neighborhood
                the gap-driven planner probes AROUND a winning config, which
                may step outside the initial ``space`` (families with
                divisibility constraints override this to stay legal).
    """

    attr: str
    space: Callable[[object, object], Sequence[Config]]
    bind: Optional[Callable[[object, Optional[Config]], None]] = None
    refine: Optional[Callable[[object, object, Config],
                              Sequence[Config]]] = None

    def tune_space(self, node, hw) -> List[Config]:
        return [tuple(int(d) for d in cfg) for cfg in self.space(node, hw)]

    def bind_config(self, node, cfg: Optional[Config]) -> None:
        if self.bind is not None:
            self.bind(node, cfg)
        elif cfg is None:
            node.attrs.pop(self.attr, None)
        else:
            node.attrs[self.attr] = tuple(int(d) for d in cfg)

    def refine_space(self, node, hw, winning_cfg: Config) -> List[Config]:
        """Candidate configs *around* ``winning_cfg`` for the SOL-gap
        refinement planner (``benchmarks/autotune.refine_plan``).  The
        default probes the power-of-two neighborhood — every combination of
        halving / keeping / doubling each dimension — minus the winner
        itself and anything already in the initial ``tune_space`` (those
        were measured by the sweep; re-measuring them wastes the planner's
        budget).  Kernels clamp configs defensively at call time (gcd /
        min-max), so stepping outside the declared space is safe; families
        whose clamp would collapse most neighbors (divisor-constrained
        blocks) override ``refine`` with a legal neighborhood."""
        win = tuple(int(d) for d in winning_cfg)
        if self.refine is not None:
            cands = [tuple(int(d) for d in c)
                     for c in self.refine(node, hw, win)]
        else:
            import itertools
            axes = [sorted({max(1, d // 2), d, 2 * d}) for d in win]
            cands = [c for c in itertools.product(*axes) if c != win]
        seen = set(self.tune_space(node, hw)) | {win}
        out: List[Config] = []
        for c in cands:
            if c not in seen and all(d >= 1 for d in c):
                seen.add(c)
                out.append(c)
        return out


def bucket_dim(d: int) -> int:
    """Nearest power of two (ties round up via round-half-even on the log)."""
    if d <= 1:
        return 1
    return 2 ** int(round(math.log2(d)))


def bucket_shape(shape: Tuple[int, ...]) -> Bucket:
    return tuple(bucket_dim(int(d)) for d in shape)


def ceil_pow2(d: int) -> int:
    """Smallest power of two ≥ ``d`` — the SERVING bucket.

    ``bucket_dim`` rounds to the *nearest* pow2 (fine for cache keying,
    where a measurement covers a neighbourhood), but a server must pad a
    request UP, never truncate it; and because a power of two is its own
    bucket (``bucket_dim(ceil_pow2(d)) == ceil_pow2(d)``), a batch padded
    with ``ceil_pow2`` hits the measured-timing cache and any pinned
    ``Tunable`` configs exactly instead of falling back to the roofline."""
    if d <= 1:
        return 1
    return 2 ** math.ceil(math.log2(d))


def pad_shape(shape: Sequence[int]) -> Tuple[int, ...]:
    """Per-dim ``ceil_pow2`` — the shape a served batch is padded to."""
    return tuple(ceil_pow2(int(d)) for d in shape)


def node_shape(node) -> Optional[Tuple[int, ...]]:
    """The shape a node is keyed under.  LINEAR/MATMUL → (M, K, N) with
    leading batch dims folded into M; DECODE_ATTENTION → (B, S, H, hd) from
    the KV-cache operand, so each decode cache bucket gets its own timings
    (the output shape is (B, 1, H, hd) for *every* cache length and would
    alias all buckets); everything else → the output shape."""
    from .ir import OpKind
    if node.op is OpKind.DECODE_ATTENTION:
        if len(node.inputs) < 2 or len(node.spec.shape) != 4:
            return tuple(node.spec.shape) or None
        b, _one, h, hd = node.spec.shape
        s = node.inputs[1].spec.shape[1]          # k_cache is (B, S, KV, hd)
        return (b, s, h, hd)
    if node.op in (OpKind.LINEAR, OpKind.MATMUL):
        xs = node.inputs[0].spec.shape if node.inputs else ()
        if not xs or not node.spec.shape:
            return None
        k = xs[-1]
        m = 1
        for d in xs[:-1]:
            m *= d
        return (m, k, node.spec.shape[-1])
    return tuple(node.spec.shape) or None


@dataclasses.dataclass
class Measurement:
    us: float                                    # best measured wall time
                                                 # (min over iters — see
                                                 # core.measure docstring)
    config: Optional[Tuple[int, ...]] = None     # winning tunable config
    flops: float = 0.0                           # analytic terms of the node
    nbytes: float = 0.0                          # bytes for this impl's
                                                 # memory mode (calibration)
    mean_us: float = 0.0                         # mean over the same iters

    def to_json(self) -> dict:
        d = {"us": self.us}
        if self.config is not None:
            d["config"] = list(self.config)
        if self.flops:
            d["flops"] = self.flops
        if self.nbytes:
            d["nbytes"] = self.nbytes
        if self.mean_us:
            d["mean_us"] = self.mean_us
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Measurement":
        cfg = d.get("config")
        return cls(us=float(d["us"]),
                   config=tuple(cfg) if cfg else None,
                   flops=float(d.get("flops", 0.0)),
                   nbytes=float(d.get("nbytes", 0.0)),
                   mean_us=float(d.get("mean_us", 0.0)))


class AutotuneCache:
    """Persistent per-(op, shape bucket, dtype, backend, impl) timings."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.stale = False      # a schema-mismatched file was ignored on load
        self._entries: Dict[EntryKey, Dict[Bucket, Dict[str, Measurement]]] = {}
        self._calibration: Dict[Tuple[str, str], Dict[str, float]] = {}

    # -- measurements -------------------------------------------------------

    def record(self, op: str, shape: Tuple[int, ...], dtype: str,
               backend: str, impl: str, us: float, *,
               config: Optional[Tuple[int, ...]] = None,
               flops: float = 0.0, nbytes: float = 0.0,
               mean_us: float = 0.0) -> None:
        """Keep the best (lowest) time per (key, bucket, impl)."""
        bucket = bucket_shape(shape)
        per = self._entries.setdefault((op, dtype, backend), {}) \
                           .setdefault(bucket, {})
        prev = per.get(impl)
        if prev is None or us < prev.us:
            per[impl] = Measurement(us=float(us),
                                    config=tuple(config) if config else None,
                                    flops=float(flops), nbytes=float(nbytes),
                                    mean_us=float(mean_us))

    def lookup(self, op: str, shape: Optional[Tuple[int, ...]], dtype: str,
               backend: str) -> Dict[str, Measurement]:
        """Measurements for the exact bucket, else the nearest same-rank
        bucket (L1 in log2-space), else {}."""
        return self.lookup_with_confidence(op, shape, dtype, backend)[0]

    def lookup_with_confidence(self, op: str,
                               shape: Optional[Tuple[int, ...]], dtype: str,
                               backend: str
                               ) -> Tuple[Dict[str, Measurement], str]:
        """Like :meth:`lookup`, plus WHERE the hit came from: ``"exact"``
        (the shape's own bucket holds measurements), ``"nearest"`` (resolved
        to the nearest same-rank bucket — a neighbourhood estimate, never to
        be reported as an exact measurement), or ``""`` (miss)."""
        if shape is None:
            return {}, ""
        buckets = self._entries.get((op, dtype, backend))
        if not buckets:
            return {}, ""
        want = bucket_shape(shape)
        hit = buckets.get(want)
        if hit is not None:
            return dict(hit), "exact"
        same_rank = [b for b in buckets if len(b) == len(want)]
        if not same_rank:
            return {}, ""

        def dist(b: Bucket) -> float:
            return sum(abs(math.log2(x) - math.log2(y))
                       for x, y in zip(b, want))

        return dict(buckets[min(same_rank, key=dist)]), "nearest"

    def has_bucket(self, op: str, shape: Tuple[int, ...], dtype: str,
                   backend: str) -> bool:
        """Whether the EXACT bucket of ``shape`` holds measurements (no
        nearest-bucket fallback) — the serving warmup uses this to skip
        shapes an earlier run already measured."""
        buckets = self._entries.get((op, dtype, backend))
        return bool(buckets) and bucket_shape(shape) in buckets

    def entries(self) -> List[Tuple[EntryKey, Bucket, str, Measurement]]:
        """Flat iteration for the calibration fit and reporting."""
        out = []
        for key, buckets in sorted(self._entries.items()):
            for bucket, impls in sorted(buckets.items()):
                for impl, m in sorted(impls.items()):
                    out.append((key, bucket, impl, m))
        return out

    def __len__(self) -> int:
        return sum(len(impls) for buckets in self._entries.values()
                   for impls in buckets.values())

    # -- calibration coefficients -------------------------------------------

    def set_calibration(self, backend: str, op: str,
                        coeffs: Dict[str, float]) -> None:
        self._calibration[(backend, op)] = dict(coeffs)

    def calibration(self, backend: str, op: str) -> Optional[Dict[str, float]]:
        return self._calibration.get((backend, op))

    def calibrations(self) -> Dict[Tuple[str, str], Dict[str, float]]:
        return dict(self._calibration)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> dict:
        entries = {}
        for (op, dtype, backend), buckets in sorted(self._entries.items()):
            for bucket, impls in sorted(buckets.items()):
                key = "|".join((op, dtype, backend,
                                "x".join(str(d) for d in bucket)))
                entries[key] = {impl: m.to_json()
                                for impl, m in sorted(impls.items())}
        calibration: Dict[str, Dict[str, dict]] = {}
        for (backend, op), coeffs in sorted(self._calibration.items()):
            calibration.setdefault(backend, {})[op] = coeffs
        return {"schema": SCHEMA_VERSION, "entries": entries,
                "calibration": calibration}

    def save(self, path: Optional[str] = None) -> str:
        """Atomic write: serialize to a tmp file in the target directory,
        then ``os.replace`` — readers never observe a torn cache."""
        path = path or self.path
        if not path:
            raise ValueError("no cache path given")
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> "AutotuneCache":
        """Load a cache file; a missing file or one written by a different
        schema version yields an *empty* cache (``stale=True`` for the
        latter) rather than an error or a misread."""
        cache = cls(path)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return cache
        if doc.get("schema") != SCHEMA_VERSION:
            cache.stale = True
            return cache
        for key, impls in doc.get("entries", {}).items():
            parts = key.split("|")
            if len(parts) != 4:
                continue
            op, dtype, backend, bucket_s = parts
            bucket = tuple(int(d) for d in bucket_s.split("x"))
            per = cache._entries.setdefault((op, dtype, backend), {}) \
                                .setdefault(bucket, {})
            for impl, m in impls.items():
                per[impl] = Measurement.from_json(m)
        for backend, ops in doc.get("calibration", {}).items():
            for op, coeffs in ops.items():
                cache._calibration[(backend, op)] = {
                    k: float(v) for k, v in coeffs.items()}
        return cache


# ---------------------------------------------------------------------------
# process-wide cache
# ---------------------------------------------------------------------------

def get_cache() -> AutotuneCache:
    """The cache the election pass consults.  Starts empty; a warm cache is
    an explicit opt-in (SOL_AUTOTUNE_CACHE env var, or load_cache/set_cache),
    so elections stay deterministic by default."""
    global _CACHE
    if _CACHE is None:
        path = os.environ.get("SOL_AUTOTUNE_CACHE")
        _CACHE = AutotuneCache.load(path) if path else AutotuneCache()
    return _CACHE


def set_cache(cache: Optional[AutotuneCache]) -> Optional[AutotuneCache]:
    global _CACHE
    _CACHE = cache
    return cache


def load_cache(path: str) -> AutotuneCache:
    """Load ``path`` and install it as the process-wide cache."""
    return set_cache(AutotuneCache.load(path))
