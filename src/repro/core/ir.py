"""SOL graph intermediate representation.

The paper's IR has two properties we reproduce exactly:

1. **Purpose-tagged dimensions** (Sec. II-C): a tensor dim is not a bare
   integer index but a (purpose, index) pair — ``N0`` (batch), ``C0``
   (channel), ``P1``/``P0`` (pixels), ``F0`` (features/sequence).  A tensor in
   NCHW is ``[N0, C0, P1, P0]``; in NHWC it is ``[N0, P1, P0, C0]``.  Layers
   select dims by purpose (e.g. a normalization normalizes "all channel dims")
   which makes every layer implementation layout-independent.

2. **Coarse, layer-level nodes**: SOL's IR nodes are layers (Conv, Linear,
   ReLU, MaxPool, ...), not scalar ops.  High-level mathematical
   optimizations (ReLU⊕MaxPool folding etc.) operate on this granularity;
   each node is later assigned to an optimizing module (DFP or DNN).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class Purpose(enum.Enum):
    """Dimension purposes, following the paper's None/Channel/Pixel tagging."""

    NONE = "N"      # batch-like, never vectorized over
    CHANNEL = "C"   # feature channels
    PIXEL = "P"     # spatial
    FEATURE = "F"   # flat features / sequence positions


@dataclasses.dataclass(frozen=True)
class Dim:
    """A purpose-tagged dimension: ``Dim(Purpose.CHANNEL, 0)`` renders as C0."""

    purpose: Purpose
    index: int

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.purpose.value}{self.index}"


# Common layouts --------------------------------------------------------------
def NCHW() -> Tuple[Dim, ...]:
    return (Dim(Purpose.NONE, 0), Dim(Purpose.CHANNEL, 0),
            Dim(Purpose.PIXEL, 1), Dim(Purpose.PIXEL, 0))


def NHWC() -> Tuple[Dim, ...]:
    return (Dim(Purpose.NONE, 0), Dim(Purpose.PIXEL, 1),
            Dim(Purpose.PIXEL, 0), Dim(Purpose.CHANNEL, 0))


def NF() -> Tuple[Dim, ...]:
    return (Dim(Purpose.NONE, 0), Dim(Purpose.FEATURE, 0))


def BSD() -> Tuple[Dim, ...]:
    """Sequence layout (batch, positions, channels) for the sequence models."""
    return (Dim(Purpose.NONE, 0), Dim(Purpose.FEATURE, 0),
            Dim(Purpose.CHANNEL, 0))


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    shape: Tuple[int, ...]
    dtype: str = "float32"
    dims: Tuple[Dim, ...] = ()

    def __post_init__(self):
        if self.dims and len(self.dims) != len(self.shape):
            raise ValueError(
                f"dims {self.dims} do not match shape rank {self.shape}")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def dim_of(self, purpose: Purpose) -> List[int]:
        """Positions of all dims with the given purpose (layout-independent
        dim selection — the paper's 'automatically select all channel
        dimensions' mechanism)."""
        return [i for i, d in enumerate(self.dims) if d.purpose is purpose]


class OpKind(enum.Enum):
    # DNN-module candidates (compute-bound → vendor-library / MXU path)
    LINEAR = "linear"
    CONV2D = "conv2d"
    MATMUL = "matmul"
    ATTENTION = "attention"       # (q, k, v) scaled-dot-product attention
    DECODE_ATTENTION = "decode_attention"  # 1 query vs a paged KV cache
    RGLRU_SCAN = "rglru_scan"     # gated linear recurrence h_t = a·h + b
    RWKV6_SCAN = "rwkv6_scan"     # RWKV6 WKV recurrence
    # DFP-module ops (memory-bound → fused depth-first code)
    RELU = "relu"
    GELU = "gelu"
    SILU = "silu"
    SIGMOID = "sigmoid"
    TANH = "tanh"
    EXP = "exp"
    SOFTPLUS = "softplus"
    SQRT = "sqrt"             # optional 'min' attr clamps before the root
    TIME_SHIFT = "time_shift" # prev-token features along axis 1 (zeros at t=0)
    ADD = "add"
    SUB = "sub"
    MUL = "mul"
    DIV = "div"
    BIAS_ADD = "bias_add"
    SCALE = "scale"
    SOFTCAP = "softcap"
    MAXPOOL = "maxpool"
    AVGPOOL = "avgpool"
    GLOBALPOOL = "globalpool"
    BATCHNORM = "batchnorm"
    LAYERNORM = "layernorm"
    RMSNORM = "rmsnorm"
    SOFTMAX = "softmax"
    DROPOUT = "dropout"       # identity at inference; masks in training
    FLATTEN = "flatten"
    RESHAPE = "reshape"
    TRANSPOSE = "transpose"
    REORDER = "reorder"       # layout change inserted by the layout pass
    IDENTITY = "identity"
    # structural
    INPUT = "input"
    PARAM = "param"
    CONST = "const"           # materialized constant: attrs['fill'] + spec
    OUTPUT = "output"
    FUSED = "fused"           # a DFP fusion group (post-fusion-pass node)


# Which OpKinds are elementwise-ish and therefore DFP-fusable.
DFP_FUSABLE = {
    OpKind.RELU, OpKind.GELU, OpKind.SILU, OpKind.SIGMOID, OpKind.TANH,
    OpKind.EXP, OpKind.SOFTPLUS, OpKind.SQRT, OpKind.ADD, OpKind.SUB,
    OpKind.MUL, OpKind.DIV,
    OpKind.BIAS_ADD, OpKind.SCALE, OpKind.SOFTCAP, OpKind.LAYERNORM,
    OpKind.RMSNORM, OpKind.SOFTMAX, OpKind.BATCHNORM, OpKind.DROPOUT,
    OpKind.IDENTITY, OpKind.MAXPOOL, OpKind.AVGPOOL, OpKind.GLOBALPOOL,
}

# Graph-level sequence kernels: never DFP-fused, always elected as whole
# nodes through the dispatch table (attention + linear-recurrence scans).
SEQUENCE_OPS = {OpKind.ATTENTION, OpKind.DECODE_ATTENTION,
                OpKind.RGLRU_SCAN, OpKind.RWKV6_SCAN}

# Source nodes carry no inputs; everything else must have at least one.
SOURCE_OPS = {OpKind.INPUT, OpKind.PARAM, OpKind.CONST}


class Module(enum.Enum):
    """The paper's two optimizing modules."""

    DFP = "dfp"   # depth-first parallelism: fused, cache/VMEM-resident
    DNN = "dnn"   # vendor-library / MXU path


_node_counter = itertools.count()


@dataclasses.dataclass
class Node:
    op: OpKind
    inputs: List["Node"]
    spec: TensorSpec
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""
    module: Optional[Module] = None          # set by assign_modules pass
    layout: Optional[str] = None             # set by layout pass
    impl: Optional[str] = None               # Impl name elected by
                                             # passes.elect_implementations
    impl_bwd: Optional[str] = None           # backward Impl name elected by
                                             # passes.elect_grad_implementations
    # for FUSED nodes: the ordered list of original nodes in the group
    body: List["Node"] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.name:
            self.name = f"{self.op.value}_{next(_node_counter)}"

    def __hash__(self) -> int:
        return id(self)

    def __eq__(self, other: object) -> bool:
        return self is other

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        mod = f":{self.module.value}" if self.module else ""
        return f"<{self.name}{mod} {self.spec.shape}>"


@dataclasses.dataclass
class Graph:
    """A SOL computation graph: inputs → nodes → outputs, plus named params."""

    inputs: List[Node]
    outputs: List[Node]
    params: Dict[str, Node]

    def topo(self) -> List[Node]:
        seen: Dict[int, bool] = {}
        order: List[Node] = []

        def visit(n: Node) -> None:
            if id(n) in seen:
                return
            seen[id(n)] = True
            for i in n.inputs:
                visit(i)
            order.append(n)

        for o in self.outputs:
            visit(o)
        return order

    def nodes_of(self, *kinds: OpKind) -> List[Node]:
        ks = set(kinds)
        return [n for n in self.topo() if n.op in ks]

    def consumers(self) -> Dict[Node, List[Node]]:
        cons: Dict[Node, List[Node]] = {}
        for n in self.topo():
            for i in n.inputs:
                cons.setdefault(i, []).append(n)
        return cons

    def replace(self, old: Node, new: Node) -> None:
        """Rewire every consumer of ``old`` to consume ``new`` — including
        consumers buried in FUSED bodies, which live outside ``topo()`` (a
        fusion group's side input must stay in sync with the body node that
        reads it, or the group's local environment dangles)."""
        for n in self.topo():
            n.inputs = [new if i is old else i for i in n.inputs]
            for b in n.body:
                b.inputs = [new if i is old else i for i in b.inputs]
        self.outputs = [new if o is old else o for o in self.outputs]

    def validate(self) -> None:
        """Graph invariants (used by property tests)."""
        order = self.topo()
        pos = {id(n): i for i, n in enumerate(order)}
        for n in order:
            for i in n.inputs:
                assert pos[id(i)] < pos[id(n)], f"cycle at {n}"
        for o in self.outputs:
            assert id(o) in pos
        for n in order:
            if n.op not in SOURCE_OPS:
                assert n.inputs, f"non-source node {n} without inputs"

    def stats(self) -> Dict[str, int]:
        order = self.topo()
        return {
            "nodes": len(order),
            "dfp": sum(1 for n in order if n.module is Module.DFP),
            "dnn": sum(1 for n in order if n.module is Module.DNN),
            "fused_groups": sum(1 for n in order if n.op is OpKind.FUSED),
            "reorders": sum(1 for n in order if n.op is OpKind.REORDER),
            "elected": sum(1 for n in order if n.impl is not None),
        }


# -- builders ------------------------------------------------------------------

def input_node(shape: Sequence[int], dtype: str = "float32",
               dims: Tuple[Dim, ...] = (), name: str = "") -> Node:
    return Node(OpKind.INPUT, [], TensorSpec(tuple(shape), dtype, dims),
                name=name or "input")


def param_node(shape: Sequence[int], dtype: str = "float32",
               name: str = "param") -> Node:
    return Node(OpKind.PARAM, [], TensorSpec(tuple(shape), dtype), name=name)


def const_node(shape: Sequence[int], fill: float = 0.0,
               dtype: str = "float32", name: str = "") -> Node:
    """A materialized fill-constant (zero recurrence states, unit norm gains
    ...) — a source node the executor binds without framework storage."""
    return Node(OpKind.CONST, [], TensorSpec(tuple(shape), dtype),
                attrs={"fill": float(fill)}, name=name or "const")
