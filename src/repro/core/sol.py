"""Speed-of-light (SOL) gap analysis — how far is each kernel from the
hardware limit, and where should tuning effort go next?

SOLAR-style closed loop (PAPERS.md): every autotune measurement already
carries the analytic roofline terms of the node it timed (``flops`` and
``nbytes``, recorded by ``core.measure.sweep_node`` from
``passes._node_cost_terms``).  Dividing the measured time by the roofline
bound those terms imply —

    bound_us = HardwareSpec.roofline_s(flops, nbytes) · 1e6
    ratio    = measured_us / bound_us          (1.0 = at the hardware limit)

— ranks every kernel by how much headroom is left.  The bound reuses the
SAME cost model the election pass uses (``passes.node_roofline_terms`` /
``HardwareSpec.roofline_s``), never a parallel formula, so a kernel's gap
is measured against exactly the model that elected it.

Every row carries provenance so a neighbourhood estimate can never
masquerade as a measurement:

* ``confidence`` — ``"exact"``: the shape's own pow2 bucket was measured;
  ``"nearest"``: resolved by nearest-bucket lookup (an estimate).
* ``source`` — ``"measured"``: a wall-clock timing from the cache;
  ``"calibrated"``: estimated from fitted per-(backend, op) roofline
  coefficients; ``"analytical"``: neither (no time estimate at all).

Consumers: ``SolModel.impl_report(sol=True)`` (per elected node of a live
graph), ``benchmarks/run.py sol`` (the ranked table + ``BENCH_sol.json``
artifact), and the gap-driven refinement planner
(``benchmarks/autotune.refine_plan``) which spends its measurement budget
on the worst-ratio cells instead of sweeping uniformly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .ir import OpKind, SOURCE_OPS


@dataclasses.dataclass
class SolRow:
    """One (op, bucket, dtype, backend, impl) cell of the SOL report."""

    op: str
    bucket: Tuple[int, ...]
    dtype: str
    backend: str
    impl: str
    us: float                       # measured (or calibrated-estimate) time
    bound_us: float                 # roofline bound for the recorded terms
    ratio: float                    # us / bound_us; 0.0 when no bound exists
    bottleneck: str                 # 'compute' | 'memory' | '' (no terms)
    confidence: str                 # 'exact' | 'nearest'
    source: str                     # 'measured' | 'calibrated' | 'analytical'
    config: Optional[Tuple[int, ...]] = None
    flops: float = 0.0
    nbytes: float = 0.0
    node: str = ""                  # node name for graph-scoped reports

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["bucket"] = list(self.bucket)
        if self.config is not None:
            d["config"] = list(self.config)
        return d


def sol_bound_us(hw, flops: float, nbytes: float) -> Tuple[float, str]:
    """Roofline bound in µs plus the dominant term.  Degenerate terms
    (no flops AND no bytes recorded) yield (0.0, '') — the caller reports
    the cell as unbounded-below rather than dividing by zero."""
    bound_s = hw.roofline_s(flops, nbytes)
    if not (bound_s > 0.0) or not math.isfinite(bound_s):
        return 0.0, ""
    dom = "compute" if hw.compute_s(flops) >= hw.memory_s(nbytes) \
        else "memory"
    return bound_s * 1e6, dom


def sol_ratio(us: float, bound_us: float) -> float:
    """measured ÷ bound, guarded to be finite and ≥ 0 for ANY cache entry:
    a missing bound (0.0) or non-finite measurement yields 0.0 — such a
    row ranks as 'no known gap', never as an infinite one."""
    if bound_us <= 0.0 or not math.isfinite(bound_us):
        return 0.0
    if us < 0.0 or not math.isfinite(us):
        return 0.0
    return us / bound_us


def cache_rows(cache, *, backends: Optional[Sequence[str]] = None,
               best_only: bool = False) -> List[SolRow]:
    """SOL rows for every measurement in an ``AutotuneCache`` (confidence is
    ``"exact"`` by construction: each entry IS its own bucket's
    measurement).  ``best_only`` keeps just the fastest impl per
    (op, bucket, dtype, backend) cell — the elected kernel's row, which is
    what the ranked table and the planner reason about.  Backends unknown
    to the registry are skipped (a cache file can outlive a backend)."""
    from ..backends.registry import available_backends

    known = available_backends()
    rows: List[SolRow] = []
    cells: Dict[Tuple[str, str, str, Tuple[int, ...]], SolRow] = {}
    for (op, dtype, backend), bucket, impl, m in cache.entries():
        if backends is not None and backend not in backends:
            continue
        bk = known.get(backend)
        if bk is None:
            continue
        bound, dom = sol_bound_us(bk.hw, m.flops, m.nbytes)
        row = SolRow(op=op, bucket=bucket, dtype=dtype, backend=backend,
                     impl=impl, us=m.us, bound_us=bound,
                     ratio=sol_ratio(m.us, bound), bottleneck=dom,
                     confidence="exact", source="measured",
                     config=m.config, flops=m.flops, nbytes=m.nbytes)
        rows.append(row)
        cell = (op, dtype, backend, bucket)
        if cell not in cells or row.us < cells[cell].us:
            cells[cell] = row
    return list(cells.values()) if best_only else rows


def node_rows(graph, backend, cache) -> List[SolRow]:
    """Per-elected-node SOL rows for a live graph — the
    ``SolModel.impl_report(sol=True)`` view.  The bound comes from the
    node's own cost terms under the elected impl's memory mode
    (``passes.node_roofline_terms``); the time comes from the cache under
    the node's bucket, tagged ``exact``/``nearest`` by where the lookup
    resolved.  A node whose elected impl has no cached timing falls back
    to the calibrated coefficient estimate when one is fit
    (``source="calibrated"``), else reports ``source="analytical"`` with
    no ratio — silence stays visible, it never fakes a measurement."""
    from ..backends import registry as R
    from . import autotune
    from .passes import node_roofline_terms

    rows: List[SolRow] = []
    for n in graph.topo():
        if n.op in SOURCE_OPS or n.op is OpKind.OUTPUT:
            continue
        impl_name = getattr(n, "impl", None)
        if not impl_name:
            continue
        impl = R.get_impl(impl_name)
        memory = impl.memory if impl is not None else "streamed"
        flops, nbytes, bound_s = node_roofline_terms(n, backend.hw, memory)
        bound, dom = sol_bound_us(backend.hw, flops, nbytes)
        shape = autotune.node_shape(n)
        hits, conf = cache.lookup_with_confidence(
            n.op.value, shape, n.spec.dtype, backend.cache_name)
        m = hits.get(impl_name)
        if m is not None:
            us, source, cfg = m.us, "measured", m.config
        else:
            cal = cache.calibration(backend.cache_name, n.op.value)
            if cal:
                us = (cal["s_per_flop"] * flops
                      + cal["s_per_byte"] * nbytes) * 1e6
                source, conf, cfg = "calibrated", "", None
            else:
                us, source, conf, cfg = 0.0, "analytical", "", None
        rows.append(SolRow(
            op=n.op.value, bucket=autotune.bucket_shape(shape or ()),
            dtype=n.spec.dtype, backend=backend.cache_name, impl=impl_name,
            us=us, bound_us=bound,
            ratio=sol_ratio(us, bound) if source != "analytical" else 0.0,
            bottleneck=dom, confidence=conf, source=source, config=cfg,
            flops=flops, nbytes=nbytes, node=n.name or n.op.value))
    return rows


def rank(rows: Sequence[SolRow]) -> List[SolRow]:
    """Worst gap first.  Exact-bucket measurements rank ahead of
    nearest-bucket estimates and calibrated guesses — an estimate is
    steering data for the planner, but it must never outrank (or be
    mistaken for) a real measurement of the same standing."""
    def key(r: SolRow):
        exact_measured = (r.confidence == "exact" and r.source == "measured")
        return (0 if exact_measured else 1, -r.ratio)
    return sorted(rows, key=key)


def render(rows: Sequence[SolRow], limit: int = 0) -> str:
    """The ranked SOL table ``benchmarks/run.py sol`` prints."""
    hdr = (f"{'backend':17s} {'op':14s} {'bucket':>16s} {'impl':26s} "
           f"{'us':>9s} {'bound_us':>9s} {'ratio':>7s} {'bneck':>7s} "
           f"{'conf':>8s} {'src':>10s}")
    out = [hdr, "-" * len(hdr)]
    for r in (rows[:limit] if limit else rows):
        bucket = "x".join(str(d) for d in r.bucket)
        out.append(
            f"{r.backend:17s} {r.op:14s} {bucket:>16s} {r.impl:26s} "
            f"{r.us:9.1f} {r.bound_us:9.3f} {r.ratio:7.1f} "
            f"{r.bottleneck:>7s} {r.confidence:>8s} {r.source:>10s}")
    return "\n".join(out)
