"""SOL core: graph IR, compiler passes, executor, and the sol.optimize API."""
from . import autotune, ir, passes, executor

__all__ = ["autotune", "ir", "passes", "executor"]
