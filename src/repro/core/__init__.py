"""SOL core: graph IR, compiler passes, executor, and the sol.optimize API."""
from . import ir, passes, executor

__all__ = ["ir", "passes", "executor"]
