"""SOL code generation / execution (the paper's 'SOL generates code for these
and compiles it for the target devices').

On JAX the 'generated code' is a closed-over Python function lowered through
jit.  Per-node implementations are resolved through the backend dispatch table
(``backends.registry``): the election pass annotates ``node.impl`` with the
chosen flavour, and anything unannotated falls back along the chain
backend-specific kernel → shared Pallas kernel → the XLA/jnp reference
lowerings defined below.  This module registers the **reference tier** for
every op it can lower — it knows nothing about which backends exist, so new
backends plug in without touching this file.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .ir import Graph, Module, Node, OpKind
from ..backends import registry

Array = jax.Array


# ---------------------------------------------------------------------------
# individual op lowerings (the reference tier)
# ---------------------------------------------------------------------------

def linear_weight_kn(n: Node, w: Array) -> Array:
    """Normalize a Linear weight to the (K=in, N=out) contraction
    orientation.  Params are stored (out,in) framework-style; the single
    home of the orientation heuristic, shared with the MXU matmul impl."""
    return w.T if w.shape[0] == n.attrs["out_features"] else w


def _lower_linear(n: Node, x: Array, w: Array, b: Array | None,
                  backend: "registry.Backend") -> Array:
    # layout pass decides operand order: 'oi' keeps (out,in) and contracts on
    # the last dim of both; 'io' stores (in,out) — fewer transposes for
    # backends whose matmul wants the reduction dim major (paper Sec. III-A).
    if n.layout == "io":
        y = jnp.einsum("...i,io->...o", x, linear_weight_kn(n, w))
    else:
        wt = w if w.shape[0] == n.attrs["out_features"] else w.T
        y = jnp.einsum("...i,oi->...o", x, wt)
    if b is not None:
        y = y + b
    return y


def _lower_conv2d(n: Node, x: Array, w: Array, b: Array | None,
                  backend: "registry.Backend") -> Array:
    stride = n.attrs.get("stride", 1)
    padding = n.attrs.get("padding", 0)
    groups = n.attrs.get("groups", 1)
    strides = (stride, stride) if isinstance(stride, int) else stride
    pads = ((padding, padding), (padding, padding)) \
        if isinstance(padding, int) else padding
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pads,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups)
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def _pool(n: Node, x: Array, reduce_fn, init) -> Array:
    k = n.attrs.get("kernel", 2)
    s = n.attrs.get("stride", k)
    ks = (k, k) if isinstance(k, int) else k
    ss = (s, s) if isinstance(s, int) else s
    return jax.lax.reduce_window(
        x, init, reduce_fn, window_dimensions=(1, 1) + ks,
        window_strides=(1, 1) + ss, padding="VALID")


_ELEMENTWISE: Dict[OpKind, Callable[..., Array]] = {
    OpKind.RELU: lambda x: jnp.maximum(x, 0.0),
    OpKind.GELU: jax.nn.gelu,
    OpKind.SILU: jax.nn.silu,
    OpKind.SIGMOID: jax.nn.sigmoid,
    OpKind.TANH: jnp.tanh,
    OpKind.EXP: jnp.exp,
    OpKind.SOFTPLUS: jax.nn.softplus,
    OpKind.IDENTITY: lambda x: x,
}


def _lower_node(n: Node, vals: List[Array], backend: "registry.Backend"
                ) -> Array:
    op = n.op
    if op in _ELEMENTWISE:
        return _ELEMENTWISE[op](vals[0])
    if op is OpKind.ADD:
        return vals[0] + vals[1]
    if op is OpKind.SUB:
        return vals[0] - vals[1]
    if op is OpKind.MUL:
        return vals[0] * vals[1]
    if op is OpKind.DIV:
        return vals[0] / vals[1]
    if op is OpKind.BIAS_ADD:
        x, b = vals
        shape = [1] * x.ndim
        axis = n.attrs.get("axis", -1)
        shape[axis] = b.shape[0]
        return x + b.reshape(shape)
    if op is OpKind.SCALE:
        return vals[0] * n.attrs["value"]
    if op is OpKind.SQRT:
        mv = n.attrs.get("min")
        x = vals[0] if mv is None else jnp.maximum(vals[0], mv)
        return jnp.sqrt(x)
    if op is OpKind.TIME_SHIFT:
        x = vals[0]
        return jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    if op is OpKind.SOFTCAP:
        c = n.attrs["cap"]
        return jnp.tanh(vals[0] / c) * c
    if op is OpKind.MAXPOOL:
        y = _pool(n, vals[0], jax.lax.max, -jnp.inf)
        mv = n.attrs.get("min_value")
        if mv is not None:          # the folded ReLU (paper's optimization)
            y = jnp.maximum(y, mv)
        return y
    if op is OpKind.AVGPOOL:
        k = n.attrs.get("kernel", 2)
        area = k * k if isinstance(k, int) else k[0] * k[1]
        return _pool(n, vals[0], jax.lax.add, 0.0) / area
    if op is OpKind.GLOBALPOOL:
        return vals[0].mean(axis=(2, 3))
    if op is OpKind.LAYERNORM:
        x, g, b = vals
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + n.attrs.get("eps", 1e-5)) * g + b
    if op is OpKind.RMSNORM:
        x, g = vals
        ms = (x.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
        return (x * jax.lax.rsqrt(ms + n.attrs.get("eps", 1e-6)).astype(x.dtype)) * g
    if op is OpKind.BATCHNORM:
        x, g, b, m, v = vals
        shape = [1, -1] + [1] * (x.ndim - 2)
        inv = jax.lax.rsqrt(v + n.attrs.get("eps", 1e-5))
        return (x - m.reshape(shape)) * (inv * g).reshape(shape) + b.reshape(shape)
    if op is OpKind.SOFTMAX:
        return jax.nn.softmax(vals[0], axis=n.attrs.get("axis", -1))
    if op is OpKind.DROPOUT:
        return vals[0]  # inference identity; training handled by frontend rng
    if op is OpKind.FLATTEN:
        return vals[0].reshape(vals[0].shape[0], -1)
    if op is OpKind.RESHAPE:
        return vals[0].reshape(n.attrs["shape"])
    if op is OpKind.TRANSPOSE:
        return jnp.transpose(vals[0], n.attrs["perm"])
    if op is OpKind.REORDER:
        return vals[0]
    if op is OpKind.LINEAR:
        return _lower_linear(n, vals[0], vals[1],
                             vals[2] if len(vals) > 2 else None, backend)
    if op is OpKind.MATMUL:
        return vals[0] @ vals[1]
    if op is OpKind.CONV2D:
        return _lower_conv2d(n, vals[0], vals[1],
                             vals[2] if len(vals) > 2 else None, backend)
    raise NotImplementedError(f"lowering for {op}")


# ---------------------------------------------------------------------------
# DFP fusion-group reference: compose — under jit, XLA fuses the chain (the
# 'vendor stack' flavour of DFP); numerically identical to the Pallas kernel.
# ---------------------------------------------------------------------------

def compose_fused(n: Node, vals: Sequence[Array],
                  backend: "registry.Backend") -> Array:
    """Lower a FUSED node op-at-a-time; vals are the group's side inputs in
    node.inputs order.  Also the runtime fallback of the Pallas DFP kernel.

    Body ops resolve through the dispatch table too, so a backend's tier-0
    override of a fusable op (say a custom GELU) still applies when the op
    sits inside a composed group."""
    local: Dict[int, Array] = {id(i): v for i, v in zip(n.inputs, vals)}
    out = None
    for b in n.body:
        body_vals = [local[id(i)] for i in b.inputs]
        out = _impl_for(b, backend).fn(b, body_vals, backend)
        local[id(b)] = out
    return out


# ---------------------------------------------------------------------------
# reference-tier registration — invoked by registry._load_entry_points(), not
# at import time, so the executor↔registry import cycle stays one-directional.
# ---------------------------------------------------------------------------

_REFERENCE_OPS = (
    list(_ELEMENTWISE)
    + [OpKind.ADD, OpKind.SUB, OpKind.MUL, OpKind.DIV, OpKind.BIAS_ADD,
       OpKind.SCALE, OpKind.SQRT, OpKind.TIME_SHIFT, OpKind.SOFTCAP,
       OpKind.MAXPOOL, OpKind.AVGPOOL,
       OpKind.GLOBALPOOL, OpKind.LAYERNORM, OpKind.RMSNORM, OpKind.BATCHNORM,
       OpKind.SOFTMAX, OpKind.DROPOUT, OpKind.FLATTEN, OpKind.RESHAPE,
       OpKind.TRANSPOSE, OpKind.REORDER, OpKind.LINEAR, OpKind.MATMUL,
       OpKind.CONV2D]
)


def reference_vjp_grad(n: Node, res, ct, backend: "registry.Backend"):
    """Universal tier-2 backward: ``jax.vjp`` of the op's forward *reference*
    impl, recomputed from the saved primals (remat-style — no extra residuals
    beyond the default ``(inputs, output)`` pair).  Works for any op with a
    registered forward reference, FUSED groups included (vjp of
    ``compose_fused`` re-derives every body op's gradient op-at-a-time)."""
    vals, _out = res
    ref = registry._REFERENCE_IMPLS[n.op]
    diff = [i for i, v in enumerate(vals)
            if jnp.issubdtype(jnp.result_type(v), jnp.inexact)]

    def fwd(*xs):
        full = list(vals)
        for i, x in zip(diff, xs):
            full[i] = x
        return ref.fn(n, full, backend)

    _, pull = jax.vjp(fwd, *[vals[i] for i in diff])
    cts = pull(ct)
    out: List[Any] = [None] * len(vals)
    for i, c in zip(diff, cts):
        out[i] = c
    return tuple(out)


# Ops whose elected forward can be a Pallas kernel (no JAX AD rule) — these
# MUST carry a registered backward for training to ride elected forwards.
# Heavier reference ops join too so their backwards are electable/sweepable;
# plain elementwise/norm ops differentiate through their jnp lowerings.
_GRAD_REFERENCE_OPS = (
    OpKind.LINEAR, OpKind.MATMUL, OpKind.CONV2D, OpKind.AVGPOOL,
    OpKind.FUSED,
)


def _register_reference_impls() -> None:
    for _op in _REFERENCE_OPS:
        registry.register_reference_impl(_op, _lower_node)
    registry.register_reference_impl(OpKind.FUSED, compose_fused,
                                     name="ref.compose", memory="roundtrip")
    for _op in _GRAD_REFERENCE_OPS:
        registry.register_reference_grad_impl(_op, reference_vjp_grad)


# ---------------------------------------------------------------------------
# graph → callable
# ---------------------------------------------------------------------------

def _impl_for(n: Node, backend: "registry.Backend") -> registry.Impl:
    """Honour the election pass's annotation when it is still admissible for
    this backend, else resolve through the fallback chain."""
    if n.impl:
        impl = registry.get_impl(n.impl)
        if impl is not None and impl.op is n.op \
                and impl.admissible(backend, n):
            return impl
    return registry.resolve(backend, n)


def _grad_impl_for(n: Node, backend: "registry.Backend"
                   ) -> registry.Impl | None:
    """Honour the backward election's annotation when still admissible, else
    first admissible backward in the chain; None when the op registers no
    backward (plain JAX AD differentiates its jnp forward impl)."""
    if n.impl_bwd:
        impl = registry.get_grad_impl(n.impl_bwd)
        if impl is not None and impl.op is n.op \
                and impl.admissible(backend, n):
            return impl
    return registry.resolve_grad(backend, n)


def _differentiable_call(n: Node, impl: registry.Impl,
                         grad_impl: registry.Impl,
                         backend: "registry.Backend") -> Callable[..., Any]:
    """Pair a node's elected forward with its elected backward under one
    ``jax.custom_vjp``.  Residuals are the default ``(primal_inputs, output)``
    pair; the backward impl recomputes anything else it needs.  Integer-dtype
    primals (e.g. decode lens) receive ``float0`` cotangents, and float
    cotangents are cast back to the primal dtype so mixed-precision backward
    math (f32 accumulation) round-trips cleanly."""

    @jax.custom_vjp
    def call(*vals):
        return impl.fn(n, list(vals), backend)

    def fwd(*vals):
        out = impl.fn(n, list(vals), backend)
        return out, (vals, out)

    def bwd(res, ct):
        vals, _out = res
        cts = grad_impl.fn(n, res, ct, backend)
        cts = tuple(cts) if isinstance(cts, (tuple, list)) else (cts,)
        if len(cts) != len(vals):
            raise ValueError(
                f"{grad_impl.name} returned {len(cts)} cotangents for "
                f"{len(vals)} inputs of {n}")
        fixed = []
        for v, c in zip(vals, cts):
            if not jnp.issubdtype(jnp.result_type(v), jnp.inexact):
                fixed.append(np.zeros(jnp.shape(v), jax.dtypes.float0))
            elif c is None:
                fixed.append(jnp.zeros_like(v))
            else:
                fixed.append(jnp.asarray(c, dtype=jnp.result_type(v)))
        return tuple(fixed)

    call.defvjp(fwd, bwd)
    return call


def lower_graph(g: Graph, backend: "registry.Backend", *,
                differentiable: bool = False) -> Callable[..., Any]:
    """Return fn(params: dict, *inputs) -> outputs evaluating the graph.

    With ``differentiable=True`` every node whose op registers a backward
    impl is wrapped in ``jax.custom_vjp`` pairing its elected forward with
    its elected backward — the training path's ``jax.grad`` then rides
    elected kernels in both directions.  Mesh note: the ``psum_axes``
    collective stays OUTSIDE the wrapper, so JAX AD transposes it to the
    psum-correct gradient collective for sharded graphs."""
    order = g.topo()
    input_ids = [id(i) for i in g.inputs]
    param_items = sorted(g.params.items())
    impls: Dict[int, registry.Impl] = {
        id(n): _impl_for(n, backend) for n in order
        if n.op not in (OpKind.INPUT, OpKind.PARAM, OpKind.CONST,
                        OpKind.OUTPUT)
    }
    # differentiable lowering: bind custom_vjp wrappers once, at lower time
    calls: Dict[int, Callable[..., Any]] = {}
    if differentiable:
        for n in order:
            if id(n) not in impls:
                continue
            gi = _grad_impl_for(n, backend)
            if gi is not None:
                calls[id(n)] = _differentiable_call(n, impls[id(n)], gi,
                                                    backend)
    # CONST sources bind to fill-constants once; under jit they are baked
    # into the lowered program, never staged from the framework.
    const_vals: Dict[int, Array] = {
        id(n): jnp.full(n.spec.shape, n.attrs.get("fill", 0.0),
                        dtype=n.spec.dtype)
        for n in order if n.op is OpKind.CONST
    }

    def fn(params: Dict[str, Array], *inputs: Array):
        env: Dict[int, Array] = dict(const_vals)
        for nid, x in zip(input_ids, inputs):
            env[nid] = x
        for name, node in param_items:
            env[id(node)] = params[name]
        for n in order:
            if id(n) in env:
                continue
            if n.op in (OpKind.INPUT, OpKind.PARAM):
                raise ValueError(f"unbound source node {n}")
            vals = [env[id(i)] for i in n.inputs]
            call = calls.get(id(n))
            env[id(n)] = (call(*vals) if call is not None
                          else impls[id(n)].fn(n, vals, backend))
            # row-parallel matmuls under shard_map produce partial sums:
            # shard_graph marks them and the collective lowers here, before
            # any downstream bias add (BIAS_ADD is its own node)
            if n.attrs.get("psum_axes"):
                env[id(n)] = jax.lax.psum(env[id(n)],
                                          tuple(n.attrs["psum_axes"]))
        outs = tuple(env[id(o)] for o in g.outputs)
        return outs[0] if len(outs) == 1 else outs

    return fn
