"""SOL code generation / execution (the paper's 'SOL generates code for these
and compiles it for the target devices').

On JAX the 'generated code' is a closed-over Python function lowered through
jit; DFP fusion groups either compose (XLA fuses them — the CPU/'vendor stack'
flavour) or dispatch to the ``kernels.dfp_fused`` Pallas kernel (the TPU
flavour, interpret-mode on CPU).  DNN nodes go to dot_general/conv in the
operand order elected by the layout pass.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from .ir import Graph, Module, Node, OpKind

if TYPE_CHECKING:    # avoid circular import (backends.registry imports core.ir)
    from ..backends.registry import Backend

Array = jax.Array


# ---------------------------------------------------------------------------
# individual op lowerings
# ---------------------------------------------------------------------------

def _lower_linear(n: Node, x: Array, w: Array, b: Array | None,
                  backend: "Backend") -> Array:
    # layout pass decides operand order: 'oi' keeps (out,in) and contracts on
    # the last dim of both; 'io' stores (in,out) — fewer transposes for
    # backends whose matmul wants the reduction dim major (paper Sec. III-A).
    if n.layout == "io":
        y = jnp.einsum("...i,io->...o", x, w.T if w.shape[0] == n.attrs["out_features"] else w)
    else:
        wt = w if w.shape[0] == n.attrs["out_features"] else w.T
        y = jnp.einsum("...i,oi->...o", x, wt)
    if b is not None:
        y = y + b
    return y


def _lower_conv2d(n: Node, x: Array, w: Array, b: Array | None,
                  backend: "Backend") -> Array:
    stride = n.attrs.get("stride", 1)
    padding = n.attrs.get("padding", 0)
    groups = n.attrs.get("groups", 1)
    strides = (stride, stride) if isinstance(stride, int) else stride
    pads = ((padding, padding), (padding, padding)) \
        if isinstance(padding, int) else padding
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pads,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups)
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def _pool(n: Node, x: Array, reduce_fn, init) -> Array:
    k = n.attrs.get("kernel", 2)
    s = n.attrs.get("stride", k)
    ks = (k, k) if isinstance(k, int) else k
    ss = (s, s) if isinstance(s, int) else s
    return jax.lax.reduce_window(
        x, init, reduce_fn, window_dimensions=(1, 1) + ks,
        window_strides=(1, 1) + ss, padding="VALID")


_ELEMENTWISE: Dict[OpKind, Callable[..., Array]] = {
    OpKind.RELU: lambda x: jnp.maximum(x, 0.0),
    OpKind.GELU: jax.nn.gelu,
    OpKind.SILU: jax.nn.silu,
    OpKind.SIGMOID: jax.nn.sigmoid,
    OpKind.TANH: jnp.tanh,
    OpKind.EXP: jnp.exp,
    OpKind.IDENTITY: lambda x: x,
}


def _lower_node(n: Node, vals: List[Array], backend: "Backend") -> Array:
    op = n.op
    if op in _ELEMENTWISE:
        return _ELEMENTWISE[op](vals[0])
    if op is OpKind.ADD:
        return vals[0] + vals[1]
    if op is OpKind.SUB:
        return vals[0] - vals[1]
    if op is OpKind.MUL:
        return vals[0] * vals[1]
    if op is OpKind.DIV:
        return vals[0] / vals[1]
    if op is OpKind.BIAS_ADD:
        x, b = vals
        shape = [1] * x.ndim
        axis = n.attrs.get("axis", -1)
        shape[axis] = b.shape[0]
        return x + b.reshape(shape)
    if op is OpKind.SCALE:
        return vals[0] * n.attrs["value"]
    if op is OpKind.SOFTCAP:
        c = n.attrs["cap"]
        return jnp.tanh(vals[0] / c) * c
    if op is OpKind.MAXPOOL:
        y = _pool(n, vals[0], jax.lax.max, -jnp.inf)
        mv = n.attrs.get("min_value")
        if mv is not None:          # the folded ReLU (paper's optimization)
            y = jnp.maximum(y, mv)
        return y
    if op is OpKind.AVGPOOL:
        k = n.attrs.get("kernel", 2)
        area = k * k if isinstance(k, int) else k[0] * k[1]
        return _pool(n, vals[0], jax.lax.add, 0.0) / area
    if op is OpKind.GLOBALPOOL:
        return vals[0].mean(axis=(2, 3))
    if op is OpKind.LAYERNORM:
        x, g, b = vals
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + n.attrs.get("eps", 1e-5)) * g + b
    if op is OpKind.RMSNORM:
        x, g = vals
        ms = (x.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
        return (x * jax.lax.rsqrt(ms + n.attrs.get("eps", 1e-6)).astype(x.dtype)) * g
    if op is OpKind.BATCHNORM:
        x, g, b, m, v = vals
        shape = [1, -1] + [1] * (x.ndim - 2)
        inv = jax.lax.rsqrt(v + n.attrs.get("eps", 1e-5))
        return (x - m.reshape(shape)) * (inv * g).reshape(shape) + b.reshape(shape)
    if op is OpKind.SOFTMAX:
        return jax.nn.softmax(vals[0], axis=n.attrs.get("axis", -1))
    if op is OpKind.DROPOUT:
        return vals[0]  # inference identity; training handled by frontend rng
    if op is OpKind.FLATTEN:
        return vals[0].reshape(vals[0].shape[0], -1)
    if op is OpKind.RESHAPE:
        return vals[0].reshape(n.attrs["shape"])
    if op is OpKind.TRANSPOSE:
        return jnp.transpose(vals[0], n.attrs["perm"])
    if op is OpKind.REORDER:
        return vals[0]
    if op is OpKind.LINEAR:
        return _lower_linear(n, vals[0], vals[1],
                             vals[2] if len(vals) > 2 else None, backend)
    if op is OpKind.MATMUL:
        return vals[0] @ vals[1]
    if op is OpKind.CONV2D:
        return _lower_conv2d(n, vals[0], vals[1],
                             vals[2] if len(vals) > 2 else None, backend)
    raise NotImplementedError(f"lowering for {op}")


# ---------------------------------------------------------------------------
# DFP fusion-group lowering
# ---------------------------------------------------------------------------

# ops the Pallas dfp_fused kernel supports as a single VMEM-resident program
_DFP_KERNEL_OPS = {
    OpKind.RELU, OpKind.GELU, OpKind.SILU, OpKind.SIGMOID, OpKind.TANH,
    OpKind.EXP, OpKind.ADD, OpKind.SUB, OpKind.MUL, OpKind.DIV,
    OpKind.BIAS_ADD, OpKind.SCALE, OpKind.SOFTCAP, OpKind.RMSNORM,
    OpKind.LAYERNORM, OpKind.IDENTITY, OpKind.DROPOUT,
}


def _lower_fused(n: Node, env: Dict[int, Array], backend: "Backend") -> Array:
    body = n.body
    kernel_ok = (backend.dfp_impl == "pallas"
                 and all(b.op in _DFP_KERNEL_OPS for b in body)
                 and all(b.spec.shape == body[-1].spec.shape or
                         b.op in (OpKind.BIAS_ADD,) for b in body))
    if kernel_ok:
        from ..kernels.dfp_fused import ops as dfp_ops
        program, operands = _compile_dfp_program(n, env)
        if program is not None:
            return dfp_ops.dfp_fused(program, operands,
                                     interpret=backend.interpret)
    # fallback: compose — under jit, XLA fuses the chain (the 'vendor stack'
    # flavour of DFP); numerically identical to the kernel path.
    local: Dict[int, Array] = dict(env)
    out = None
    for b in body:
        vals = [local[id(i)] for i in b.inputs]
        out = _lower_node(b, vals, backend)
        local[id(b)] = out
    return out


def _compile_dfp_program(n: Node, env: Dict[int, Array]):
    """Translate a fusion-group body into the dfp_fused kernel's static
    program encoding.  Returns (program, operands) or (None, None) when the
    chain has shapes the kernel does not handle (then we compose instead)."""
    from ..kernels.dfp_fused.program import encode_program
    try:
        return encode_program(n, env)
    except NotImplementedError:
        return None, None


# ---------------------------------------------------------------------------
# graph → callable
# ---------------------------------------------------------------------------

def lower_graph(g: Graph, backend: "Backend") -> Callable[..., Any]:
    """Return fn(params: dict, *inputs) -> outputs evaluating the graph."""
    order = g.topo()
    input_ids = [id(i) for i in g.inputs]
    param_items = sorted(g.params.items())

    def fn(params: Dict[str, Array], *inputs: Array):
        env: Dict[int, Array] = {}
        for nid, x in zip(input_ids, inputs):
            env[nid] = x
        for name, node in param_items:
            env[id(node)] = params[name]
        for n in order:
            if id(n) in env:
                continue
            if n.op is OpKind.FUSED:
                env[id(n)] = _lower_fused(n, env, backend)
            elif n.op in (OpKind.INPUT, OpKind.PARAM):
                raise ValueError(f"unbound source node {n}")
            else:
                vals = [env[id(i)] for i in n.inputs]
                env[id(n)] = _lower_node(n, vals, backend)
        outs = tuple(env[id(o)] for o in g.outputs)
        return outs[0] if len(outs) == 1 else outs

    return fn
