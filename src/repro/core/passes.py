"""SOL compiler passes (Sec. III-A of the paper).

Pipeline (mirrors the paper's order):

  1. ``simplify``          — high-level mathematical optimizations on the IR
                             (ReLU⊕MaxPool folding, transpose cancellation,
                             dead-node elimination, identity removal).
  2. ``assign_modules``    — per-layer optimizing-module election: Convolution
                             and Linear → DNN module; everything else → DFP;
                             exception: grouped convolutions with
                             groups == out_channels (depthwise, MobileNet-style)
                             → DFP, because they reduce to a WeightedPooling.
  3. ``form_fusion_groups``— DFP region formation: maximal chains of fusable
                             nodes are collapsed into FUSED nodes, which the
                             backend lowers to a single depth-first kernel
                             (registers/cache in the paper; VMEM on TPU).
  4. ``assign_layouts``    — per-backend memory-layout election (e.g. Linear
                             weights (out,in) on CPU-like backends vs (in,out)
                             on long-vector backends), inserting the minimal
                             number of REORDER nodes.
  5. ``elect_implementations`` — per-node implementation election: each node's
                             admissible impls (backend kernel → shared Pallas
                             kernel → XLA reference, from the backend dispatch
                             table) are costed with the backend's
                             ``HardwareSpec`` roofline terms and the cheapest
                             wins; the choice is recorded on ``node.impl``.

Each pass returns the (mutated) graph so they compose with ``functools.reduce``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .ir import (DFP_FUSABLE, SEQUENCE_OPS, SOURCE_OPS, Graph, Module, Node,
                 OpKind, TensorSpec)


# ----------------------------------------------------------------------------
# 1. high-level mathematical simplifications
# ----------------------------------------------------------------------------

def _fold_relu_maxpool(g: Graph) -> int:
    """The paper's flagship example: a ReLU followed or preceded by a
    MaxPooling is removed by clamping the pooling's minimum value to 0
    (max(maxpool(x), 0) == maxpool(max(x, 0)) == maxpool_{min=0}(x))."""
    folded = 0
    cons = g.consumers()
    for n in list(g.topo()):
        if n.op is OpKind.RELU:
            src = n.inputs[0]
            users = cons.get(n, [])
            # relu -> maxpool : fold into the pool
            if len(users) == 1 and users[0].op is OpKind.MAXPOOL:
                pool = users[0]
                pool.attrs["min_value"] = 0.0
                g.replace(n, src)  # pool now reads src directly via rewire
                pool.inputs = [src if i is n else i for i in pool.inputs]
                folded += 1
            # maxpool -> relu : fold into the pool
            elif src.op is OpKind.MAXPOOL and len(cons.get(src, [])) == 1:
                src.attrs["min_value"] = 0.0
                g.replace(n, src)
                folded += 1
    return folded


def _cancel_transposes(g: Graph) -> int:
    """transpose(transpose(x, p), p⁻¹) → x."""
    cancelled = 0
    for n in list(g.topo()):
        if n.op is OpKind.TRANSPOSE and n.inputs[0].op is OpKind.TRANSPOSE:
            inner = n.inputs[0]
            p_out = n.attrs.get("perm")
            p_in = inner.attrs.get("perm")
            if p_out and p_in:
                comp = tuple(p_in[i] for i in p_out)
                if comp == tuple(range(len(comp))):
                    g.replace(n, inner.inputs[0])
                    cancelled += 1
    return cancelled


def _drop_identities(g: Graph) -> int:
    dropped = 0
    for n in list(g.topo()):
        if n.op in (OpKind.IDENTITY, OpKind.DROPOUT) and \
                not n.attrs.get("training", False):
            g.replace(n, n.inputs[0])
            dropped += 1
    return dropped


def simplify(g: Graph) -> Graph:
    g.attrs_log = getattr(g, "attrs_log", [])
    g.attrs_log.append({
        "relu_maxpool_folded": _fold_relu_maxpool(g),
        "transposes_cancelled": _cancel_transposes(g),
        "identities_dropped": _drop_identities(g),
    })
    g.validate()
    return g


# ----------------------------------------------------------------------------
# 2. optimizing-module assignment (DFP vs DNN)
# ----------------------------------------------------------------------------

def assign_modules(g: Graph) -> Graph:
    for n in g.topo():
        if n.op in SOURCE_OPS or n.op is OpKind.OUTPUT:
            continue
        if n.op in (OpKind.LINEAR, OpKind.MATMUL) or n.op in SEQUENCE_OPS:
            # sequence kernels (attention, linear-recurrence scans) are
            # whole-node dispatch-table ops, like the matmul family
            n.module = Module.DNN
        elif n.op is OpKind.CONV2D:
            groups = n.attrs.get("groups", 1)
            out_c = n.attrs.get("out_channels")
            # depthwise convs reduce to WeightedPooling → DFP (paper Sec III-A)
            if groups > 1 and groups == out_c:
                n.module = Module.DFP
                n.attrs["as_weighted_pool"] = True
            else:
                n.module = Module.DNN
        else:
            n.module = Module.DFP
    return g


# ----------------------------------------------------------------------------
# 3. DFP fusion-group formation
# ----------------------------------------------------------------------------

def form_fusion_groups(g: Graph) -> Graph:
    """Collapse maximal single-consumer chains of fusable DFP nodes into FUSED
    nodes.  The depth-first insight: inside a group, intermediate tensors never
    round-trip to main memory (HBM on TPU) — they live in registers/VMEM."""
    cons = g.consumers()

    def fusable(n: Node) -> bool:
        # SEQUENCE_OPS are hard fusion barriers: attention and the
        # recurrence scans must stay whole nodes for the dispatch table,
        # never disappear into a depth-first elementwise group.
        return (n.module is Module.DFP and n.op in DFP_FUSABLE
                and n.op not in SEQUENCE_OPS and n.op is not OpKind.FUSED)

    visited: set = set()
    for n in g.topo():
        if id(n) in visited or not fusable(n):
            continue
        # grow a chain downstream while the single consumer is fusable
        chain: List[Node] = [n]
        visited.add(id(n))
        cur = n
        while True:
            users = [u for u in cons.get(cur, []) if u.op is not OpKind.OUTPUT]
            if len(users) == 1 and fusable(users[0]) \
                    and id(users[0]) not in visited:
                # all *other* inputs of the next node must come from outside
                # the chain or be params (side inputs are allowed: residuals,
                # bias tensors etc. become extra kernel operands)
                cur = users[0]
                chain.append(cur)
                visited.add(id(cur))
            else:
                break
        if len(chain) < 2:
            continue
        in_chain = {id(c) for c in chain}
        side_inputs: List[Node] = []
        for c in chain:
            for i in c.inputs:
                if id(i) not in in_chain and i not in side_inputs:
                    side_inputs.append(i)
        fused = Node(OpKind.FUSED, side_inputs, chain[-1].spec,
                     attrs={"length": len(chain)},
                     name=f"fused[{'+'.join(c.op.value for c in chain)}]",
                     body=chain)
        fused.module = Module.DFP
        g.replace(chain[-1], fused)
        cons = g.consumers()
    g.validate()
    return g


# ----------------------------------------------------------------------------
# 4. layout assignment
# ----------------------------------------------------------------------------

def assign_layouts(g: Graph, backend: "object") -> Graph:
    """Per-backend layout election.  The backend exposes
    ``preferred_layout(node) -> str`` (e.g. 'oi' vs 'io' for Linear weights,
    'nchw' vs 'nhwc' for convs).  We tag nodes and count the reorders a real
    materialization would need; reorders between adjacent nodes that agree are
    elided (the minimization the paper describes)."""
    prev_layout: Dict[int, str] = {}
    reorders = 0
    for n in g.topo():
        if n.op in SOURCE_OPS:
            continue
        want = backend.preferred_layout(n)
        n.layout = want
        for i in n.inputs:
            have = prev_layout.get(id(i))
            if have is not None and have != want:
                reorders += 1
        prev_layout[id(n)] = want
    g.layout_reorders = reorders
    return g


# ----------------------------------------------------------------------------
# 5. implementation election (per-node 'flavour' choice, paper Sec. IV)
# ----------------------------------------------------------------------------

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "int8": 1, "float64": 8}

# FLOPs per element for the memory-bound DFP ops.  The nominal default only
# needs relative magnitudes; ``benchmarks/perf_iter.py --calibrate-ew``
# replaces it with the element-weighted mean measured from compiled
# whole-model HLO (``calibrate_ew_flops`` below), and SOL_EW_FLOPS carries a
# fitted value into a fresh process.
_EW_FLOPS_NOMINAL = 5.0


def _initial_ew_flops() -> float:
    import os
    try:
        v = float(os.environ.get("SOL_EW_FLOPS", ""))
    except ValueError:
        return _EW_FLOPS_NOMINAL
    return v if v > 0 else _EW_FLOPS_NOMINAL


_EW_FLOPS = _initial_ew_flops()


def ew_flops() -> float:
    """The per-element FLOP weight the DFP cost terms currently use."""
    return _EW_FLOPS


def set_ew_flops(value: Optional[float]) -> float:
    """Override the elementwise FLOP weight (``None`` restores the nominal
    default).  Non-positive values are rejected back to the default — a
    degenerate fit must not zero out every DFP node's compute term."""
    global _EW_FLOPS
    _EW_FLOPS = (float(value) if value is not None and value > 0
                 else _EW_FLOPS_NOMINAL)
    return _EW_FLOPS


def fit_ew_flops(samples) -> float:
    """Least squares through the origin of measured elementwise FLOPs onto
    elementwise element counts: each sample is ``(ew_flops, ew_elements)``
    for one whole compiled model (``benchmarks/perf_iter.py`` derives both
    from the HLO).  Returns the fitted FLOPs-per-element, falling back to
    the nominal default when the data is degenerate."""
    num = sum(f * e for f, e in samples if e > 0)
    den = sum(e * e for _f, e in samples if e > 0)
    if den <= 0 or num <= 0:
        return _EW_FLOPS_NOMINAL
    return num / den


def calibrate_ew_flops(samples) -> float:
    """Fit and install the elementwise FLOP weight in one step."""
    return set_ew_flops(fit_ew_flops(samples))


def _node_cost_terms(n: Node) -> Tuple[float, float, float]:
    """Rough roofline terms for one node: (flops, streamed_bytes,
    roundtrip_bytes).  'streamed' assumes inputs and the output cross HBM
    exactly once (a depth-first kernel); 'roundtrip' charges every
    intermediate of a fusion group a full write+read (op-at-a-time
    composition).  For non-FUSED nodes the two coincide."""
    eltsize = _DTYPE_BYTES.get(n.spec.dtype, 4)
    in_bytes = sum(i.spec.size for i in n.inputs) * eltsize
    out_bytes = n.spec.size * eltsize
    streamed = float(in_bytes + out_bytes)

    if n.op in (OpKind.LINEAR, OpKind.MATMUL):
        k = n.inputs[0].spec.shape[-1] if n.inputs[0].spec.shape else 1
        return 2.0 * n.spec.size * k, streamed, streamed
    if n.op is OpKind.CONV2D:
        w = n.inputs[1].spec
        out_c = n.attrs.get("out_channels") or (w.shape[0] if w.shape else 1)
        taps = w.size / max(out_c, 1)       # in_ch/groups · kh · kw
        return 2.0 * n.spec.size * taps, streamed, streamed
    if n.op is OpKind.FUSED:
        flops = sum(b.spec.size for b in n.body) * _EW_FLOPS
        roundtrip = float(in_bytes) + sum(
            2.0 * b.spec.size * eltsize for b in n.body)
        return flops, streamed, roundtrip
    if n.op is OpKind.ATTENTION:
        # (B, S, H, hd): one qkᵀ + one p·v matmul → 4·B·H·S²·hd FLOPs; a
        # roundtrip impl additionally writes+reads the f32 S×S score matrix
        # per head (what flash attention exists to avoid).
        b, s, h, hd = n.spec.shape
        flops = 4.0 * b * h * s * s * hd
        score_bytes = 2.0 * b * h * s * s * 4.0
        return flops, streamed, streamed + score_bytes
    if n.op is OpKind.DECODE_ATTENTION:
        # one query row vs an S-row KV cache: 4·B·H·(S+1)·hd FLOPs; the cache
        # read dominates the streamed bytes, so decode is memory-bound and
        # O(S) in the cache length — never O(S²) like a full re-forward.  A
        # roundtrip impl additionally writes+reads the f32 (B, H, S) scores.
        b, _one, h, hd = n.spec.shape
        s = n.inputs[1].spec.shape[1] if len(n.inputs) > 1 else 1
        flops = 4.0 * b * h * (s + 1) * hd
        score_bytes = 2.0 * b * h * s * 4.0
        return flops, streamed, streamed + score_bytes
    if n.op is OpKind.RGLRU_SCAN:
        # h_t = a·h + b: ~2 FLOPs/element; streamed bytes dominate either way
        return 2.0 * n.spec.size, streamed, streamed
    if n.op is OpKind.RWKV6_SCAN:
        # per step each head updates an hd×hd state: ~4·B·S·H·hd² FLOPs; a
        # roundtrip impl spills the f32 state matrix every step.
        b, s, h, hd = n.spec.shape
        flops = 4.0 * b * s * h * hd * hd
        state_bytes = 2.0 * b * s * h * hd * hd * 4.0
        return flops, streamed, streamed + state_bytes
    return n.spec.size * _EW_FLOPS, streamed, streamed


def node_roofline_terms(n: Node, hw: "object",
                        memory: str = "streamed"
                        ) -> Tuple[float, float, float]:
    """Public face of :func:`_node_cost_terms` for the speed-of-light
    report (``core.sol``): the node's (flops, nbytes, bound_s) under the
    given impl memory mode, with the bound computed by the SAME
    ``HardwareSpec.roofline_s`` the election pass costs with — the SOL gap
    is measured against the model that elected the kernel, never a
    parallel formula."""
    flops, streamed, roundtrip = _node_cost_terms(n)
    nbytes = roundtrip if memory == "roundtrip" else streamed
    return flops, nbytes, hw.roofline_s(flops, nbytes)


def elect_implementations(g: Graph, backend: "object") -> Graph:
    """Cost-based per-node impl election over the backend dispatch table.

    Measurements beat models (the AutoTVM/Ansor lesson): when the autotune
    cache (``core.autotune``) holds timings for this (op, shape bucket,
    dtype, backend), the candidate with the best *measured* time wins and
    the node is tagged with ``'measured'`` provenance — including any tuned
    kernel config the measurement carried, pinned through the winning
    impl's ``Tunable`` declaration (``node.attrs['mxu_block']``,
    ``'attn_block'``, ``'dfp_block'``, ``'rglru_block'``, ...).  Every
    tunable attr registered for the op (any backend's) is cleared first, so
    re-electing a graph on a different backend or cache state never leaves
    a stale pin.

    Cold cache falls back to the analytical path: every admissible impl is
    costed with the backend's ``HardwareSpec`` roofline terms — scaled by
    calibrated per-(backend, op) coefficients when ``benchmarks/calibrate``
    has fit them (``'calibrated'`` provenance, else ``'analytical'``) — and
    the cheapest wins; ties break toward the more specific tier.  The
    executor honours ``node.impl`` and falls back along the chain when the
    annotation is absent or inadmissible (e.g. the graph is re-lowered on a
    different backend)."""
    from ..backends import registry as R
    from . import autotune

    cache = autotune.get_cache()
    elections: Dict[str, int] = {}
    by_op: Dict[str, Dict[str, int]] = {}
    provenance: Dict[str, Dict[str, int]] = {}
    pinned: Dict[str, List[Tuple[int, ...]]] = {}
    for n in g.topo():
        if n.op in SOURCE_OPS or n.op is OpKind.OUTPUT:
            continue
        cands = R.candidates(backend, n)
        if not cands:
            raise NotImplementedError(
                f"no implementation of {n.op} for backend {backend.name!r}")
        flops, streamed, roundtrip = _node_cost_terms(n)
        by_name = {c.name: c for c in cands}
        measured = {name: m for name, m in cache.lookup(
            n.op.value, autotune.node_shape(n), n.spec.dtype,
            backend.cache_name).items() if name in by_name}

        cfg = None
        if measured:
            best_name = min(measured,
                            key=lambda nm: (measured[nm].us,
                                            by_name[nm].tier))
            best = by_name[best_name]
            cfg = measured[best_name].config
            source = "measured"
        else:
            cal = cache.calibration(backend.cache_name, n.op.value)

            def cost(impl: "R.Impl") -> Tuple[float, int]:
                nbytes = roundtrip if impl.memory == "roundtrip" else streamed
                if cal:
                    t = cal["s_per_flop"] * flops + cal["s_per_byte"] * nbytes
                else:
                    t = backend.hw.roofline_s(flops, nbytes)
                return (t, impl.tier)

            best = min(cands, key=cost)
            source = "calibrated" if cal else "analytical"
        # re-election must not leave a stale tuned config: clear every
        # tunable attr registered for this op — not just this backend's
        # admissible candidates, or a pin would survive re-electing on a
        # backend where the tuned impl is inadmissible — then pin the
        # winner's measured config
        for t in R.tunables_for(n.op):
            t.bind_config(n, None)
        if cfg and best.tunable is not None:
            best.tunable.bind_config(n, tuple(cfg))
            pinned.setdefault(best.name, []).append(tuple(cfg))
        n.impl = best.name
        elections[best.name] = elections.get(best.name, 0) + 1
        per = by_op.setdefault(n.op.value, {})
        per[best.name] = per.get(best.name, 0) + 1
        src = provenance.setdefault(best.name, {})
        src[source] = src.get(source, 0) + 1
    g.elections = elections
    g.elections_by_op = by_op
    g.election_provenance = provenance
    g.election_pinned = pinned
    return g


def elect_grad_implementations(g: Graph, backend: "object") -> Graph:
    """Backward-impl election — the forward election's exact mirror over the
    gradient dispatch table (``registry.grad_candidates``).

    Measured timings come from the autotune cache under the ``_bwd``-suffixed
    op key (``registry.grad_cache_op``), so forward and backward sweeps never
    collide; the analytical fallback costs a backward as roughly two
    forward-sized programs (dX and dW / dKV and dQ).  Winners land on
    ``node.impl_bwd``, tuned configs pin through the backward impl's own
    ``Tunable`` (attrs suffixed ``_bwd``, so clearing them never drops a
    forward pin), and elections/provenance merge into the graph's existing
    election dicts under the ``_bwd`` op key — ``impl_report`` and
    ``check_provenance`` see the backward program exactly like the forward
    one."""
    from ..backends import registry as R
    from . import autotune

    cache = autotune.get_cache()
    elections: Dict[str, int] = getattr(g, "elections", {}) or {}
    by_op: Dict[str, Dict[str, int]] = getattr(g, "elections_by_op", {}) or {}
    provenance: Dict[str, Dict[str, int]] = \
        getattr(g, "election_provenance", {}) or {}
    pinned: Dict[str, List[Tuple[int, ...]]] = \
        getattr(g, "election_pinned", {}) or {}
    for n in g.topo():
        if n.op in SOURCE_OPS or n.op is OpKind.OUTPUT:
            continue
        cands = R.grad_candidates(backend, n)
        if not cands:
            n.impl_bwd = None     # JAX AD differentiates the jnp forward
            continue
        op_key = R.grad_cache_op(n.op)
        flops, streamed, roundtrip = _node_cost_terms(n)
        flops, streamed, roundtrip = 2 * flops, 2 * streamed, 2 * roundtrip
        by_name = {c.name: c for c in cands}
        measured = {name: m for name, m in cache.lookup(
            op_key, autotune.node_shape(n), n.spec.dtype,
            backend.cache_name).items() if name in by_name}

        cfg = None
        if measured:
            best_name = min(measured,
                            key=lambda nm: (measured[nm].us,
                                            by_name[nm].tier))
            best = by_name[best_name]
            cfg = measured[best_name].config
            source = "measured"
        else:
            cal = cache.calibration(backend.cache_name, op_key)

            def cost(impl: "R.Impl") -> Tuple[float, int]:
                nbytes = roundtrip if impl.memory == "roundtrip" else streamed
                if cal:
                    t = cal["s_per_flop"] * flops + cal["s_per_byte"] * nbytes
                else:
                    t = backend.hw.roofline_s(flops, nbytes)
                return (t, impl.tier)

            best = min(cands, key=cost)
            source = "calibrated" if cal else "analytical"
        for t in R.grad_tunables_for(n.op):
            t.bind_config(n, None)
        if cfg and best.tunable is not None:
            best.tunable.bind_config(n, tuple(cfg))
            pinned.setdefault(best.name, []).append(tuple(cfg))
        n.impl_bwd = best.name
        elections[best.name] = elections.get(best.name, 0) + 1
        per = by_op.setdefault(op_key, {})
        per[best.name] = per.get(best.name, 0) + 1
        src = provenance.setdefault(best.name, {})
        src[source] = src.get(source, 0) + 1
    g.elections = elections
    g.elections_by_op = by_op
    g.election_provenance = provenance
    g.election_pinned = pinned
    return g


# ----------------------------------------------------------------------------
# pipeline
# ----------------------------------------------------------------------------

def run_pipeline(g: Graph, backend: "object",
                 training: bool = False) -> Graph:
    for n in g.topo():
        if n.op is OpKind.DROPOUT:
            n.attrs["training"] = training
    g = simplify(g)
    g = assign_modules(g)
    g = form_fusion_groups(g)
    g = assign_layouts(g, backend)
    g = elect_implementations(g, backend)
    if training:
        g = elect_grad_implementations(g, backend)
    return g
