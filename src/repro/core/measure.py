"""Shared impl-sweep measurement (one implementation, two callers).

``sweep_node`` times every admissible impl of ONE node through the dispatch
table — sweeping each impl's declared :class:`~repro.core.autotune.Tunable`
config space, restoring the node's attrs afterwards — and records the best
time (plus the winning config and the impl's roofline terms) into an
:class:`~repro.core.autotune.AutotuneCache`.

Both measurement paths go through here so they can never drift: the
offline driver ``benchmarks/autotune.py`` sweeps synthetic (op, shape)
problems, and ``launch/serve.SolServer.warm_autotune`` sweeps the actual
nodes of the graphs it is about to serve.  The gap-driven refinement
planner (``benchmarks/autotune.refine_plan``) measures *specific* config
lists through :func:`measure_impl_configs`, the same primitive the sweep
uses internally.

Timing convention — min for elections, mean for figures:

Every call is timed individually and both statistics are kept
(:class:`Timing`).  **Election-grade** numbers (the autotune cache, the
SOL gap report, the refinement planner) use the **min**: one scheduler
hiccup inflates a mean arbitrarily but can never deflate a min, so the min
is the robust estimate of what the kernel costs when the machine is quiet.
The paper-figure tables (``benchmarks/paper_tables._time``) keep the
**mean** convention — a figure reproduces the latency a user experiences,
hiccups included.  Cache records carry both (``Measurement.us`` = min,
``Measurement.mean_us`` = mean) so either view can be reconstructed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class Timing:
    min_us: float                          # election-grade estimate
    mean_us: float                         # user-experienced average


@dataclasses.dataclass(frozen=True)
class ConfigMeasurement:
    config: Optional[Tuple[int, ...]]      # the swept tunable config
    us: float                              # min wall time
    mean_us: float                         # mean wall time
    error: Optional[str] = None            # impl raised for this config


@dataclasses.dataclass(frozen=True)
class ImplMeasurement:
    impl: str                              # impl name, as cache-recorded
    us: float                              # best measured (min) time
    config: Optional[Tuple[int, ...]]      # winning tunable config (or None)
    n_configs: int                         # size of the swept config space
    mean_us: float = 0.0                   # mean time of the winning config


def time_call_stats(fn: Callable[[], object], warmup: int = 2,
                    iters: int = 5) -> Timing:
    """Time ``fn`` per call (µs) after warmup and return both min and mean
    (see the module docstring for which consumer uses which)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e6)
    return Timing(min_us=min(samples), mean_us=sum(samples) / len(samples))


def time_call(fn: Callable[[], object], warmup: int = 2,
              iters: int = 5) -> float:
    """Election-grade wall time of ``fn`` in µs: the **min** over ``iters``
    individually-timed calls after warmup.  NOTE this deliberately differs
    from ``benchmarks/paper_tables._time`` (mean over a single timed loop):
    a scheduler hiccup distorts a mean — and with it an election — but
    never a min.  Use :func:`time_call_stats` when both are needed."""
    return time_call_stats(fn, warmup, iters).min_us


def measure_impl_configs(node, vals: Sequence[object], backend, impl,
                         configs: Sequence[Optional[Tuple[int, ...]]], *,
                         warmup: int = 2, iters: int = 5,
                         skip_errors: bool = False
                         ) -> List[ConfigMeasurement]:
    """Time ``impl`` on ``node`` once per config in ``configs`` (``None``
    means the impl's untuned default).  The node's tunable attr is restored
    in a ``try/finally`` — an impl raising mid-measurement must never leave
    a swept config pinned on the node (a stale pin would silently change
    what a later election or lowering executes).

    With ``skip_errors=True`` a raising config yields a ``ConfigMeasurement``
    with ``error`` set instead of propagating — the refinement planner uses
    this to probe configs outside an impl's declared space safely."""
    tun = impl.tunable
    out: List[ConfigMeasurement] = []
    try:
        for cfg in configs:
            if tun is not None:
                tun.bind_config(node, cfg)
            try:
                fn = jax.jit(lambda *a: impl.fn(node, list(a), backend))
                t = time_call_stats(lambda: fn(*vals), warmup, iters)
            except Exception as e:
                if not skip_errors:
                    raise
                out.append(ConfigMeasurement(cfg, float("inf"), float("inf"),
                                             error=f"{type(e).__name__}: {e}"))
                continue
            out.append(ConfigMeasurement(cfg, t.min_us, t.mean_us))
    finally:
        if tun is not None:
            tun.bind_config(node, None)    # never leave a sweep's pin behind
    return out


def measure_grad_impl_configs(node, res, ct, backend, impl,
                              configs: Sequence[Optional[Tuple[int, ...]]],
                              *, warmup: int = 2, iters: int = 5,
                              skip_errors: bool = False
                              ) -> List[ConfigMeasurement]:
    """Backward mirror of :func:`measure_impl_configs`: times a *gradient*
    impl (``fn(node, res, ct, backend)`` signature) once per config.
    ``res`` is the registry residual pair ``(primal_inputs, primal_out)``
    and ``ct`` the output cotangent."""
    vals, out = res
    tun = impl.tunable
    results: List[ConfigMeasurement] = []
    try:
        for cfg in configs:
            if tun is not None:
                tun.bind_config(node, cfg)
            try:
                fn = jax.jit(lambda o_, c_, *a:
                             impl.fn(node, (a, o_), c_, backend))
                t = time_call_stats(lambda: fn(out, ct, *vals),
                                    warmup, iters)
            except Exception as e:
                if not skip_errors:
                    raise
                results.append(ConfigMeasurement(
                    cfg, float("inf"), float("inf"),
                    error=f"{type(e).__name__}: {e}"))
                continue
            results.append(ConfigMeasurement(cfg, t.min_us, t.mean_us))
    finally:
        if tun is not None:
            tun.bind_config(node, None)    # never leave a sweep's pin behind
    return results


def sweep_node_grad(node, vals: Sequence[object], backend, cache, *,
                    warmup: int = 2, iters: int = 5
                    ) -> List[ImplMeasurement]:
    """Measure every admissible *backward* impl of ``node`` — each gradient
    impl's own Tunable space swept exactly like the forwards — and record
    best times under the ``_bwd``-suffixed cache op key
    (``registry.grad_cache_op``), which the backward election reads."""
    import jax.numpy as jnp

    from ..backends import registry as R
    from . import autotune as AT
    from .passes import _node_cost_terms

    grads = R.grad_candidates(backend, node)
    if not grads:
        return []
    ref = R._REFERENCE_IMPLS[node.op]
    out = jax.jit(lambda *a: ref.fn(node, list(a), backend))(*vals)
    ct = jnp.ones_like(out)
    res = (tuple(vals), out)
    flops, streamed, roundtrip = _node_cost_terms(node)
    flops, streamed, roundtrip = 2 * flops, 2 * streamed, 2 * roundtrip
    op_key = R.grad_cache_op(node.op)
    results: List[ImplMeasurement] = []
    for impl in grads:
        tun = impl.tunable
        configs: List[Optional[Tuple[int, ...]]] = [None]
        if tun is not None:
            space = tun.tune_space(node, backend.hw)
            if space:
                configs = list(space)
        measured = measure_grad_impl_configs(node, res, ct, backend, impl,
                                             configs, warmup=warmup,
                                             iters=iters)
        best = min(measured, key=lambda r: r.us)
        nbytes = roundtrip if impl.memory == "roundtrip" else streamed
        cache.record(op_key, AT.node_shape(node), node.spec.dtype,
                     backend.cache_name, impl.name, best.us,
                     config=best.config, flops=flops, nbytes=nbytes,
                     mean_us=best.mean_us)
        results.append(ImplMeasurement(impl.name, best.us, best.config,
                                       len(configs), mean_us=best.mean_us))
    return results


def sweep_node(node, vals: Sequence[object], backend, cache, *,
               warmup: int = 2, iters: int = 5) -> List[ImplMeasurement]:
    """Measure every admissible impl of ``node`` on ``backend`` using the
    concrete operand arrays ``vals`` (in ``node.inputs`` order) and record
    each impl's best time into ``cache`` keyed on the node's autotune
    bucket.  Returns the per-impl results for reporting."""
    from ..backends import registry as R
    from . import autotune as AT
    from .passes import _node_cost_terms

    flops, streamed, roundtrip = _node_cost_terms(node)
    out: List[ImplMeasurement] = []
    for impl in R.candidates(backend, node):
        tun = impl.tunable
        configs: List[Optional[Tuple[int, ...]]] = [None]
        if tun is not None:
            space = tun.tune_space(node, backend.hw)
            if space:
                configs = list(space)
        results = measure_impl_configs(node, vals, backend, impl, configs,
                                       warmup=warmup, iters=iters)
        best = min(results, key=lambda r: r.us)
        nbytes = roundtrip if impl.memory == "roundtrip" else streamed
        cache.record(node.op.value, AT.node_shape(node), node.spec.dtype,
                     backend.cache_name, impl.name, best.us, config=best.config,
                     flops=flops, nbytes=nbytes, mean_us=best.mean_us)
        out.append(ImplMeasurement(impl.name, best.us, best.config,
                                   len(configs), mean_us=best.mean_us))
    return out
