"""Shared impl-sweep measurement (one implementation, two callers).

``sweep_node`` times every admissible impl of ONE node through the dispatch
table — sweeping each impl's declared :class:`~repro.core.autotune.Tunable`
config space, restoring the node's attrs afterwards — and records the best
time (plus the winning config and the impl's roofline terms) into an
:class:`~repro.core.autotune.AutotuneCache`.

Both measurement paths go through here so they can never drift: the
offline driver ``benchmarks/autotune.py`` sweeps synthetic (op, shape)
problems, and ``launch/serve.SolServer.warm_autotune`` sweeps the actual
nodes of the graphs it is about to serve.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ImplMeasurement:
    impl: str                              # impl name, as cache-recorded
    us: float                              # best measured time
    config: Optional[Tuple[int, ...]]      # winning tunable config (or None)
    n_configs: int                         # size of the swept config space


def time_call(fn: Callable[[], object], warmup: int = 2,
              iters: int = 5) -> float:
    """Mean wall time of ``fn`` in µs after warmup (same convention as
    ``benchmarks/paper_tables._time``)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(max(iters, 1)):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / max(iters, 1) * 1e6


def sweep_node(node, vals: Sequence[object], backend, cache, *,
               warmup: int = 2, iters: int = 5) -> List[ImplMeasurement]:
    """Measure every admissible impl of ``node`` on ``backend`` using the
    concrete operand arrays ``vals`` (in ``node.inputs`` order) and record
    each impl's best time into ``cache`` keyed on the node's autotune
    bucket.  Returns the per-impl results for reporting."""
    from ..backends import registry as R
    from . import autotune as AT
    from .passes import _node_cost_terms

    flops, streamed, roundtrip = _node_cost_terms(node)
    out: List[ImplMeasurement] = []
    for impl in R.candidates(backend, node):
        tun = impl.tunable
        configs: List[Optional[Tuple[int, ...]]] = [None]
        if tun is not None:
            space = tun.tune_space(node, backend.hw)
            if space:
                configs = list(space)
        best_us, best_cfg = float("inf"), None
        for cfg in configs:
            if tun is not None:
                tun.bind_config(node, cfg)
            fn = jax.jit(lambda *a: impl.fn(node, list(a), backend))
            us = time_call(lambda: fn(*vals), warmup, iters)
            if us < best_us:
                best_us, best_cfg = us, cfg
        if tun is not None:
            tun.bind_config(node, None)    # never leave a sweep's pin behind
        nbytes = roundtrip if impl.memory == "roundtrip" else streamed
        cache.record(node.op.value, AT.node_shape(node), node.spec.dtype,
                     backend.name, impl.name, best_us, config=best_cfg,
                     flops=flops, nbytes=nbytes)
        out.append(ImplMeasurement(impl.name, best_us, best_cfg,
                                   len(configs)))
    return out
