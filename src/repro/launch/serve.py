"""SOL serving subsystem: continuous batching ON the elected/tuned graph,
with the forward split into a prefill program and an O(1)-per-token
incremental decode program.

The runtime chapter (paper Sec. IV-C) under real traffic: earlier drivers
served ``models/backbone.py`` directly, bypassing everything the middleware
exists for — elections, pinned autotune configs, Pallas kernels.  This
server routes every forward through ``frontends/optimize.SolModel`` (or a
``frontends/deploy`` artifact, closing the Sec. III-C deployment loop), so
the impls that serve traffic are exactly the impls the conformance matrix
validates and the autotune cache elected.

Two serving programs (``ServeConfig.decode=True``, the default):

* **prefill** (``frontends.extract.extract_prefill``) — one forward over
  the whole prompt; every attention layer's (k, v) projections join the
  graph outputs so the same forward that produces the first token also
  seeds the request's KV-cache slot.
* **decode** (``frontends.extract.extract_decode``) — one token per
  resident request against the cached keys/values through the
  ``DECODE_ATTENTION`` op: inputs are the last token's embedding
  ``(B, 1, D)``, the per-request cache lengths ``(B,) int32`` and the
  gathered cache tensors ``(B, cache_bucket, KV, hd)``; outputs are
  next-token logits plus the new (k, v) rows the scheduler appends at
  position ``lens[b]``.  Per decoded token the work is O(cache) instead of
  the O(T·T) full re-forward — the decode program's cost does not grow
  with how much of the sequence was already generated.

``ServeConfig(decode=False)`` keeps the full-re-forward scheduler of the
previous revision — every step re-runs the whole resident context — as a
measured baseline (``benchmarks/serving.py`` reports both).

Pieces, and which paper mechanism each reproduces:

* :class:`SlotArena` — per-request slots in an ``AsyncQueue``-backed
  arena: admission ``malloc_async``s the token region AND one KV region
  per cached tensor, prompt/token writes land via ``memcpy_async``, cache
  rows are appended with virtual-pointer arithmetic
  (``ptr + row·row_bytes``), and eviction is an async free of both
  regions.  Admission blocks when no slot is free — that interleaving is
  what lets prefill and decode share the machine.
* **Bucket padding aligned with the autotune cache** — prefill batches pad
  to ``(batch, seq)`` pow2 buckets; decode batches pad to
  ``(batch, cache_len)`` pow2 buckets.  A power of two is its own cache
  bucket, so every served shape (including every ``DECODE_ATTENTION``
  cache bucket) hits the measured-timing entries and pinned ``Tunable``
  configs exactly, never the roofline fallback.
* **Packed staging** — each prefill forward's embedded rows go
  host→device as ONE DMA via ``runtime.packed.stage_batch``; each decode
  forward's mixed inputs (token rows, int32 lengths, KV caches) go as ONE
  DMA via ``runtime.packed.stage_inputs`` (the VEO-udma gather policy).
* **Continuous batching** — the scheduler serves the least-recently-served
  ``max_batch`` residents each step (starvation-free round-robin), then
  partitions them: freshly admitted requests run the prefill program,
  residents run the decode program, in the same tick.
* **Sampling** — logits→token is a host-side policy per request
  (:class:`SamplingParams`: greedy / temperature / top-k / top-p with a
  per-request seed).  Sampling is deterministic given the seed, so a
  deployed-artifact replay reproduces a live run token-for-token.
* **Provenance enforcement** — with ``strict_provenance`` every
  LINEAR/MATMUL/ATTENTION/DECODE_ATTENTION dispatch must have been
  elected from autotune measurements (``SolModel.check_provenance``); a
  cold cache raises :class:`ProvenanceError` instead of silently serving
  roofline guesses.  ``warm_autotune`` measures every admissible impl
  (sweeping declared ``Tunable`` spaces) for every prefill AND decode
  bucket the workload can produce.

Smoke run (what CI executes):

    PYTHONPATH=src python -m repro.launch.serve --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..backends import get_backend
from ..core import autotune as AT
from ..core import measure, passes
from ..core.ir import OpKind
from ..frontends import nn
from ..frontends.extract import extract, extract_decode, extract_prefill
from ..frontends.optimize import (SolModel, compile_graph, optimize,
                                  provenance_violations)
from ..runtime import packed
from ..runtime.async_queue import AsyncQueue

TOKEN_BYTES = 4                    # int32 tokens in the slot arena
KV_BYTES = 4                       # float32 cache rows in the slot arena
MIN_SEQ_BUCKET = 8                 # smallest padded sequence bucket
SERVED_KINDS = (OpKind.LINEAR, OpKind.MATMUL, OpKind.ATTENTION,
                OpKind.DECODE_ATTENTION)


class ProvenanceError(RuntimeError):
    """A bucket model would serve elections that did not come from autotune
    measurements — the silent-roofline-fallback the smoke run must catch."""


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request token-sampling policy.

    ``temperature <= 0`` is greedy argmax (the default) and consumes no
    randomness.  Otherwise logits are divided by ``temperature``, truncated
    to the ``top_k`` highest (0 = no truncation) and then to the smallest
    set whose probability mass reaches ``top_p``, renormalized, and sampled
    with the request's own ``numpy`` generator seeded from ``seed`` — so a
    given (logits stream, params) pair always produces the same tokens,
    live or from a deployed artifact."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature {self.temperature} must be >= 0")
        if self.top_k < 0:
            raise ValueError(f"top_k {self.top_k} must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p {self.top_p} must be in (0, 1]")


def _softmax(z: np.ndarray) -> np.ndarray:
    z = z - np.max(z)
    e = np.exp(z)
    return e / e.sum()


def sample_token(logits: np.ndarray,
                 params: Optional[SamplingParams] = None,
                 rng: Optional[np.random.Generator] = None) -> int:
    """Host-side logits→token step.  Float64 throughout so the sampled
    distribution is a pure function of the logits bits — the determinism
    the deploy round-trip asserts."""
    logits = np.asarray(logits, np.float64).reshape(-1)
    if params is None or params.temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits / params.temperature
    if params.top_k:
        k = min(params.top_k, z.size)
        kth = np.partition(z, -k)[-k]
        z = np.where(z < kth, -np.inf, z)
    p = _softmax(z)
    if params.top_p < 1.0:
        order = np.argsort(-z, kind="stable")
        csum = np.cumsum(p[order])
        keep = order[: min(z.size, int(np.searchsorted(csum, params.top_p))
                           + 1)]
        masked = np.full_like(z, -np.inf)
        masked[keep] = z[keep]
        p = _softmax(masked)
    if rng is None:
        raise ValueError("temperature sampling needs the request's rng")
    return int(rng.choice(p.size, p=p))


# ---------------------------------------------------------------------------
# serving model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Shape of the served LM + scheduler limits.  ``max_seq`` must be a
    power of two so the largest sequence bucket is exactly the context
    bound.  ``decode=True`` serves residents through the incremental
    single-token decode program; ``decode=False`` keeps the full
    re-forward scheduler as a baseline."""

    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    vocab: int = 128
    max_seq: int = 64              # per-request context bound (pow2)
    max_batch: int = 4             # requests per forward step
    slots: int = 8                 # KV-slot arena size (resident requests)
    backend: str = "xla"
    seed: int = 0
    decode: bool = True            # incremental KV-cache decode program
    mesh: Tuple[int, int] = (1, 1)  # (data, model) axes; (1,1) = one device

    def __post_init__(self):
        if self.max_seq != AT.ceil_pow2(self.max_seq):
            raise ValueError(f"max_seq {self.max_seq} must be a power of "
                             f"two (it is the largest sequence bucket)")
        if self.max_batch < 1 or self.slots < 1:
            raise ValueError("max_batch and slots must be >= 1")
        if len(self.mesh) != 2 or any(int(a) < 1 for a in self.mesh):
            raise ValueError(f"mesh {self.mesh} must be two positive axis "
                             f"sizes (data, model)")


def build_lm(cfg: ServeConfig) -> nn.Sequential:
    """The served module: pre-norm transformer blocks + LM head.  Plain
    framework modules — SOL extracts/optimizes them; the server never calls
    their eager forward."""
    blocks = [nn.transformer_block(cfg.d_model, cfg.n_heads)
              for _ in range(cfg.n_layers)]
    return nn.Sequential(*blocks, nn.Linear(cfg.d_model, cfg.vocab))


def embedding_table(cfg: ServeConfig) -> np.ndarray:
    """Deterministic host-side token embedding.  Token→vector lookup is a
    host gather (the SOL IR starts at dense tensors); everything after it —
    every LINEAR/MATMUL/ATTENTION/DECODE_ATTENTION — runs through the
    elected graph."""
    rng = np.random.default_rng(cfg.seed)
    return (rng.standard_normal((cfg.vocab, cfg.d_model)) * 0.25
            ).astype(np.float32)


def validate_prompt(cfg: ServeConfig, prompt: Sequence[int]) -> np.ndarray:
    """Admission-time prompt validation, shared by ``SolServer.submit`` and
    the fleet router (``launch/fleet.SolFleet.submit``) so a bad request is
    rejected where it is submitted, not replicas later when it is routed."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    if prompt.size == 0:
        raise ValueError("empty prompt")
    if prompt.size >= cfg.max_seq:
        raise ValueError(f"prompt of {prompt.size} tokens leaves no "
                         f"room to decode within max_seq={cfg.max_seq}")
    if np.any(prompt < 0) or np.any(prompt >= cfg.vocab):
        raise ValueError("prompt token out of vocabulary range")
    return prompt


# ---------------------------------------------------------------------------
# requests + KV-slot arena
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                       # int32 (L,)
    max_new_tokens: int
    submitted: float
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    rng: Optional[np.random.Generator] = None
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    phase: str = "pending"                   # pending|prefill|decode|done
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None
    last_served_step: int = -1
    served_steps: List[int] = dataclasses.field(default_factory=list)
    last_logits: Optional[np.ndarray] = None

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def done(self) -> bool:
        return self.phase == "done"

    @property
    def cache_len(self) -> int:
        """Rows of the request's KV cache that hold attended positions.
        Invariant between steps: every token except the newest has been
        folded into the cache, so ``cache_len == length - 1``."""
        return self.length - 1


class SlotArena:
    """Per-request slots backed by the async queue's virtual allocator
    (paper Sec. IV-C).  A slot holds the request's materialized token
    context (``max_seq`` int32s) and — when ``kv_row_shapes`` is given —
    one KV region per cached tensor (``max_seq`` float32 rows each, all
    tensors packed into a single allocation with per-tensor offsets).
    Admission/append/evict are all enqueued operations, so the arena
    exercises the exact machinery the runtime bugfixes harden:
    snapshot-at-enqueue memcopies, error re-raising at ``synchronize``,
    loud use-after-free."""

    def __init__(self, queue: AsyncQueue, n_slots: int, max_seq: int,
                 kv_row_shapes: Optional[Sequence[Tuple[int, ...]]] = None):
        self.queue = queue
        self.max_seq = max_seq
        self._free = list(range(n_slots - 1, -1, -1))
        self._ptr: Dict[int, Any] = {}
        self._len: Dict[int, int] = {}
        self.kv_row_shapes = [tuple(s) for s in (kv_row_shapes or [])]
        self._row_bytes = [int(np.prod(s)) * KV_BYTES
                           for s in self.kv_row_shapes]
        self._kv_offs: List[int] = []
        total = 0
        for rb in self._row_bytes:
            self._kv_offs.append(total)
            total += max_seq * rb
        self._kv_total = total
        self._kv_ptr: Dict[int, Any] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def resident(self) -> int:
        return len(self._ptr)

    def admit(self, tokens: np.ndarray) -> Optional[int]:
        """Allocate a slot (token region + KV regions) and stage the prompt
        into it; None when full (the request waits in the pending queue —
        admission control)."""
        if not self._free:
            return None
        tokens = np.ascontiguousarray(tokens, np.int32)
        if len(tokens) > self.max_seq:
            raise ValueError(f"prompt of {len(tokens)} tokens exceeds the "
                             f"{self.max_seq}-token slot")
        slot = self._free.pop()
        ptr = self.queue.malloc_async(self.max_seq * TOKEN_BYTES)
        self.queue.memcpy_async(ptr, tokens)
        self._ptr[slot] = ptr
        self._len[slot] = len(tokens)
        if self._kv_total:
            self._kv_ptr[slot] = self.queue.malloc_async(self._kv_total)
        return slot

    def append(self, slot: int, token: int) -> None:
        """Append one decoded token — virtual-pointer arithmetic into the
        live allocation, no host-side reassembly."""
        n = self._len[slot]
        if n >= self.max_seq:
            raise ValueError(f"slot {slot} is full ({n} tokens)")
        self.queue.memcpy_async(self._ptr[slot] + n * TOKEN_BYTES,
                                np.asarray([token], np.int32))
        self._len[slot] = n + 1

    def tokens(self, slot: int) -> np.ndarray:
        """The slot's current context.  Callers must ``synchronize`` the
        queue first so staged writes have landed."""
        buf = self.queue.allocator.resolve(self._ptr[slot])
        n = self._len[slot]
        return buf[:n * TOKEN_BYTES].view(np.int32).copy()

    def write_kv_rows(self, slot: int, tensor: int, start_row: int,
                      rows: np.ndarray) -> None:
        """Stage cache rows ``[start_row, start_row + n)`` of one cached
        tensor — prefill seeds ``[0, L)`` in one write, decode appends one
        row at ``lens[b]`` — all virtual-pointer arithmetic into the slot's
        single KV allocation."""
        rows = np.ascontiguousarray(rows, np.float32)
        n = rows.shape[0]
        if start_row + n > self.max_seq:
            raise ValueError(f"KV write [{start_row}, {start_row + n}) "
                             f"overflows the {self.max_seq}-row slot")
        rb = self._row_bytes[tensor]
        self.queue.memcpy_async(
            self._kv_ptr[slot] + self._kv_offs[tensor] + start_row * rb,
            rows)

    def kv_rows(self, slot: int, tensor: int, n_rows: int) -> np.ndarray:
        """The first ``n_rows`` cache rows of one cached tensor, shaped
        ``(n_rows,) + row_shape``.  Callers must ``synchronize`` first."""
        buf = self.queue.allocator.resolve(self._kv_ptr[slot])
        off = self._kv_offs[tensor]
        rb = self._row_bytes[tensor]
        return (buf[off: off + n_rows * rb].view(np.float32)
                .reshape((n_rows,) + self.kv_row_shapes[tensor]).copy())

    def evict(self, slot: int) -> None:
        self.queue.free_async(self._ptr.pop(slot))
        kv = self._kv_ptr.pop(slot, None)
        if kv is not None:
            self.queue.free_async(kv)
        del self._len[slot]
        self._free.append(slot)


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class SolServer:
    """Continuous-batching server over the SOL pipeline.

    Bucket-model keys are ``(program, batch_bucket, seq_bucket)`` where
    ``program`` is ``"prefill"`` / ``"decode"`` (or ``"full"`` with
    ``decode=False``); for decode the seq bucket is the padded CACHE
    length.  ``deployed`` switches the server to artifact mode: a mapping
    of those keys to deploy blobs / DeployedModels; buckets outside the
    mapping raise instead of silently compiling a parallel live path."""

    def __init__(self, cfg: Optional[ServeConfig] = None,
                 model: Optional[nn.Module] = None, *,
                 deployed: Optional[Dict[Tuple, Any]] = None,
                 strict_provenance: bool = False,
                 device=None):
        self.cfg = cfg or ServeConfig()
        self.backend = get_backend(self.cfg.backend)
        self.strict_provenance = strict_provenance
        self._device = device
        # mesh mode: one server, many devices — every bucket model compiles
        # under shard_map and every autotune key carries the mesh tag, so
        # measured timings / pinned configs / strict provenance all hold on
        # PER-SHARD shapes (the arena and scheduler stay host-global)
        self.mesh = None
        if tuple(self.cfg.mesh) != (1, 1):
            from ..distributed import sharding as shd
            from .mesh import make_debug_mesh
            data_ax, model_ax = (int(a) for a in self.cfg.mesh)
            self.mesh = make_debug_mesh(data=data_ax, model=model_ax)
            self.backend = shd.mesh_backend(self.backend, self.mesh)
            if device is None:
                # packed DMA staging broadcasts the single buffer to every
                # shard; SolModel.forward then lays inputs out per-spec
                self._device = packed.replicated(self.mesh)
        # smallest batch bucket that still shards the batch dim: smaller
        # buckets would silently fall back to a replicated batch (no DP)
        self._min_batch = 1
        if self.mesh is not None:
            from ..distributed import sharding as shd
            self._min_batch = shd.axis_size(self.mesh, shd.dp_axes(self.mesh))
        self.embed = embedding_table(self.cfg)
        self.queue = AsyncQueue()
        self._models: Dict[Tuple, Any] = {}
        self._deploy_only = deployed is not None
        self.served_elections: Dict[Tuple, Dict[str, Any]] = {}
        self.model = model if model is not None else (
            None if self._deploy_only else build_lm(self.cfg))
        if self.cfg.decode:
            # the decode program's cache-input specs fix the arena's KV row
            # shapes; a throwaway minimal extraction (no compile) reads them
            spec_model = self.model if self.model is not None \
                else build_lm(self.cfg)
            g = extract_decode(spec_model, 1, self.cfg.max_seq,
                               self.cfg.d_model)
            self._kv_row_shapes = [tuple(n.spec.shape[2:])
                                   for n in g.inputs[2:]]
        else:
            self._kv_row_shapes = []
        self.arena = SlotArena(self.queue, self.cfg.slots, self.cfg.max_seq,
                               kv_row_shapes=self._kv_row_shapes)
        if deployed is not None:
            from ..frontends import deploy as D
            for key, art in deployed.items():
                m = D.load(art, device) if isinstance(art, bytes) else art
                self._models[tuple(key)] = self._audit(m, tuple(key))
        self._pending: "deque[Request]" = deque()
        self._active: List[Request] = []
        self._finished: List[Request] = []
        self._next_rid = 0
        self._step = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self.stats = {"steps": 0, "forwards": 0, "dmas": 0, "tokens": 0,
                      "prefills": 0, "decodes": 0, "admitted": 0,
                      "evicted": 0, "buckets": {}}

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               sampling: Optional[SamplingParams] = None) -> Request:
        prompt = validate_prompt(self.cfg, prompt)
        sampling = sampling or SamplingParams()
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max(1, int(max_new_tokens)),
                      submitted=time.perf_counter(), sampling=sampling,
                      rng=np.random.default_rng(sampling.seed))
        self._next_rid += 1
        self._pending.append(req)
        return req

    def step(self) -> List[int]:
        """One scheduler tick: admit → select the LRU batch → run the
        prefill forward for new admissions and the decode forward for
        residents (one packed DMA each) → sample/append/evict.  Returns
        the rids served this step."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        # admission: pending requests claim free KV slots
        while self._pending and self.arena.free_slots:
            req = self._pending.popleft()
            req.slot = self.arena.admit(req.prompt)
            req.phase = "prefill"
            self._active.append(req)
            self.stats["admitted"] += 1
        if not self._active:
            return []
        # fairness: least-recently-served first (rid FIFO tiebreak) — every
        # resident request is served at least once per ceil(R/max_batch)
        # steps, so nothing starves
        batch = sorted(self._active,
                       key=lambda r: (r.last_served_step, r.rid)
                       )[: self.cfg.max_batch]
        # flush staged slot writes; a failed async op re-raises HERE
        self.queue.synchronize()
        self._step += 1
        self.stats["steps"] += 1
        if self.cfg.decode:
            results = (self._forward_prefill(
                           [r for r in batch if r.phase == "prefill"])
                       + self._forward_decode(
                           [r for r in batch if r.phase == "decode"]))
        else:
            results = self._forward_full(batch)
        now = time.perf_counter()
        for req, row in results:
            req.last_logits = row
            tok = sample_token(row, req.sampling, req.rng)
            if req.phase == "prefill":
                req.first_token_time = now
                req.phase = "decode"
                self.stats["prefills"] += 1
            else:
                self.stats["decodes"] += 1
            req.generated.append(tok)
            req.last_served_step = self._step
            req.served_steps.append(self._step)
            self.stats["tokens"] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or req.length >= self.cfg.max_seq):
                req.phase = "done"
                req.finished_time = now
                self.arena.evict(req.slot)
                req.slot = None
                self.stats["evicted"] += 1
                self._active.remove(req)
                self._finished.append(req)
            else:
                self.arena.append(req.slot, tok)
        self._t_last = time.perf_counter()
        return [r.rid for r in batch]

    # -- the three forward programs ------------------------------------------

    def _forward_full(self, batch: List[Request]
                      ) -> List[Tuple[Request, np.ndarray]]:
        """Baseline scheduler (``decode=False``): every step re-runs the
        whole resident context through the plain forward graph."""
        rows_tok = [self.arena.tokens(r.slot) for r in batch]
        lens = [len(t) for t in rows_tok]
        bb, sb = self._bucket(len(batch), max(lens))
        rows = []
        for t in rows_tok:
            padded = np.zeros(sb, np.int32)
            padded[: len(t)] = t
            rows.append(self.embed[padded])            # (sb, d_model) f32
        for _ in range(bb - len(batch)):
            rows.append(np.zeros((sb, self.cfg.d_model), np.float32))
        x = packed.stage_batch(rows, self._device)     # ONE DMA
        self.stats["dmas"] += 1
        self.stats["forwards"] += 1
        logits = np.asarray(self._model_for(("full", bb, sb))(x))
        self._bucket_stat(f"{bb}x{sb}")
        return [(r, logits[i, lens[i] - 1].copy())
                for i, r in enumerate(batch)]

    def _forward_prefill(self, reqs: List[Request]
                         ) -> List[Tuple[Request, np.ndarray]]:
        """Prompt forward through the prefill program: produces the first
        token's logits AND the (k, v) rows that seed each request's KV
        slot — rows ``[0, L)`` of every cached tensor, written through the
        arena's virtual pointers."""
        if not reqs:
            return []
        rows_tok = [self.arena.tokens(r.slot) for r in reqs]
        lens = [len(t) for t in rows_tok]
        bb, sb = self._bucket(len(reqs), max(lens))
        rows = []
        for t in rows_tok:
            padded = np.zeros(sb, np.int32)
            padded[: len(t)] = t
            rows.append(self.embed[padded])
        for _ in range(bb - len(reqs)):
            rows.append(np.zeros((sb, self.cfg.d_model), np.float32))
        x = packed.stage_batch(rows, self._device)     # ONE DMA
        self.stats["dmas"] += 1
        self.stats["forwards"] += 1
        outs = self._model_for(("prefill", bb, sb))(x)
        logits = np.asarray(outs[0])                   # (bb, sb, vocab)
        kv = [np.asarray(o) for o in outs[1:]]         # (bb, sb, KV, hd)
        results = []
        for i, r in enumerate(reqs):
            for t in range(len(kv)):
                self.arena.write_kv_rows(r.slot, t, 0, kv[t][i, : lens[i]])
            # copy: a bare slice would pin the whole step's logits tensor
            # in memory for as long as the request record lives
            results.append((r, logits[i, lens[i] - 1].copy()))
        self._bucket_stat(f"{bb}x{sb}")
        return results

    def _forward_decode(self, reqs: List[Request]
                        ) -> List[Tuple[Request, np.ndarray]]:
        """One token per resident request through the decode program:
        gather each request's cache rows from its arena slot, pad to the
        (batch, cache) bucket, stage everything as ONE packed DMA, and
        append the returned (k, v) rows at position ``lens[b]``."""
        if not reqs:
            return []
        lens = [r.cache_len for r in reqs]
        db, cb = self._bucket(len(reqs), max(lens))
        x = np.zeros((db, 1, self.cfg.d_model), np.float32)
        lens_arr = np.zeros((db,), np.int32)
        caches = [np.zeros((db, cb) + shape, np.float32)
                  for shape in self._kv_row_shapes]
        for i, r in enumerate(reqs):
            x[i, 0] = self.embed[r.generated[-1]]
            lens_arr[i] = lens[i]
            for t in range(len(caches)):
                caches[t][i, : lens[i]] = self.arena.kv_rows(
                    r.slot, t, lens[i])
        staged = packed.stage_inputs([x, lens_arr] + caches,
                                     self._device)    # ONE DMA
        self.stats["dmas"] += 1
        self.stats["forwards"] += 1
        outs = self._model_for(("decode", db, cb))(*staged)
        logits = np.asarray(outs[0])                   # (db, 1, vocab)
        results = []
        for i, r in enumerate(reqs):
            for t in range(len(caches)):
                self.arena.write_kv_rows(r.slot, t, lens[i],
                                         np.asarray(outs[1 + t])[i])
            results.append((r, logits[i, 0].copy()))
        self._bucket_stat(f"d{db}x{cb}")
        return results

    def _bucket_stat(self, key: str) -> None:
        self.stats["buckets"][key] = self.stats["buckets"].get(key, 0) + 1

    def run(self, max_steps: int = 100_000) -> Dict[str, Any]:
        while self._pending or self._active:
            if self._step >= max_steps:
                raise RuntimeError(f"serving exceeded {max_steps} steps "
                                   f"with requests still in flight")
            self.step()
        return self.summary()

    def close(self) -> None:
        self.queue.close()

    @property
    def depth(self) -> int:
        """Requests in flight (queued + resident) — the router's
        per-replica queue-depth signal."""
        return len(self._pending) + len(self._active)

    @property
    def in_flight(self) -> List[Request]:
        """Every submitted-but-unfinished request, in admission order —
        what the fleet router re-queues when this replica dies."""
        return list(self._pending) + list(self._active)

    # -- buckets + models ----------------------------------------------------

    def _bucket(self, n_rows: int, max_len: int) -> Tuple[int, int]:
        """The (batch, seq) pow2 bucket a physical batch is padded to —
        aligned with ``core.autotune`` keying so served shapes hit measured
        cache entries exactly.  For decode, ``max_len`` is the longest
        resident CACHE length and the second element is the cache bucket."""
        sb = min(self.cfg.max_seq,
                 max(min(MIN_SEQ_BUCKET, self.cfg.max_seq),
                     AT.ceil_pow2(max_len)))
        return (AT.ceil_pow2(max(n_rows, self._min_batch)), sb)

    def _seq_buckets(self, max_len: int) -> List[int]:
        smax = min(self.cfg.max_seq,
                   max(min(MIN_SEQ_BUCKET, self.cfg.max_seq),
                       AT.ceil_pow2(max_len)))
        out = []
        s = min(MIN_SEQ_BUCKET, self.cfg.max_seq)
        while s <= smax:
            out.append(s)
            s *= 2
        return out

    def _batch_buckets(self) -> List[int]:
        out = []
        b = AT.ceil_pow2(self._min_batch)
        while b <= AT.ceil_pow2(max(self.cfg.max_batch, self._min_batch)):
            out.append(b)
            b *= 2
        return out

    def _workload_maxima(self, max_len: Optional[int] = None
                         ) -> Tuple[int, int]:
        """(longest prompt, longest total context) the current workload can
        produce — the prefill and decode bucket spaces derive from them."""
        if max_len is not None:
            return max_len, max_len
        reqs = list(self._pending) + self._active
        if not reqs:
            raise ValueError("no requests to derive the bucket space "
                             "from; pass max_len explicitly")
        prompts = [len(r.prompt) for r in reqs]
        totals = [min(self.cfg.max_seq, r.length
                      + (r.max_new_tokens - len(r.generated)))
                  for r in reqs]
        return max(prompts), max(totals)

    def bucket_space(self, max_len: Optional[int] = None
                     ) -> List[Tuple[int, int]]:
        """Every (batch, seq) bucket the current workload can produce
        through the full-re-forward program — what ``warm_autotune``
        measures ahead of serving with ``decode=False``."""
        _, max_total = self._workload_maxima(max_len)
        return [(b, s) for b in self._batch_buckets()
                for s in self._seq_buckets(max_total)]

    def _warm_graphs(self, max_len: Optional[int]) -> Iterator:
        """Every program graph whose buckets the workload can open: the
        plain forward per (batch, seq) bucket with ``decode=False``;
        otherwise the prefill program per (batch, prompt) bucket plus the
        decode program per (batch, cache) bucket (caches peak one row
        short of the total context — the newest token is never cached)."""
        d = self.cfg.d_model
        if not self.cfg.decode:
            for bb, sb in self.bucket_space(max_len):
                yield extract(self.model, (bb, sb, d))
            return
        max_prompt, max_total = self._workload_maxima(max_len)
        for bb in self._batch_buckets():
            for sb in self._seq_buckets(max_prompt):
                yield extract_prefill(self.model, (bb, sb, d))
        for db in self._batch_buckets():
            for cb in self._seq_buckets(max(1, max_total - 1)):
                yield extract_decode(self.model, db, cb, d)

    def _model_for(self, key: Tuple):
        m = self._models.get(key)
        if m is not None:
            return m
        if self._deploy_only:
            raise KeyError(
                f"bucket {key} not among the deployed artifacts "
                f"{sorted(self._models)} — deploy-mode serving never "
                f"falls back to a live compile")
        program, b, s = key
        if program == "full":
            sol = optimize(self.model, (b, s, self.cfg.d_model),
                           backend=self.backend, mesh=self.mesh)
        elif program == "prefill":
            sol = compile_graph(
                self.model,
                extract_prefill(self.model, (b, s, self.cfg.d_model)),
                self.backend, mesh=self.mesh)
        else:
            sol = compile_graph(
                self.model,
                extract_decode(self.model, b, s, self.cfg.d_model),
                self.backend, mesh=self.mesh)
        self._models[key] = self._audit(sol, key)
        return sol

    def _audit(self, model, key: Tuple):
        """Record (and under ``strict_provenance`` enforce) which impls the
        bucket model serves."""
        kinds = tuple(k.value for k in SERVED_KINDS)
        self.served_elections[key] = {
            "by_op": {k: dict(v) for k, v in
                      model.impl_report(by_kind=True).items()
                      if k in kinds},
            "provenance": model.impl_report(provenance=True),
        }
        if self.strict_provenance:
            viol = provenance_violations(model.impl_report(by_kind=True),
                                         model.impl_report(provenance=True),
                                         kinds=kinds)
            if isinstance(model, SolModel):
                viol += self._exact_bucket_violations(model)
            if viol:
                raise ProvenanceError(
                    f"bucket {key} would serve unmeasured elections "
                    f"(warm the autotune cache first): {viol}")
        return model

    def _exact_bucket_violations(self, model: SolModel) -> List[str]:
        """An election can carry 'measured' provenance via the cache's
        nearest-bucket fallback — timings from a *different* shape.  Strict
        serving requires every served-kind node's EXACT bucket to hold
        measurements (a late-submitted request that opens a new bucket
        needs another ``warm_autotune()`` call, which skips
        already-measured buckets)."""
        cache = AT.get_cache()
        out = []
        for node in model.graph.topo():
            if node.op not in SERVED_KINDS:
                continue
            shape = AT.node_shape(node)
            if not cache.has_bucket(node.op.value, shape, node.spec.dtype,
                                    self.backend.cache_name):
                out.append(f"{node.op.value}@{shape}: measured via "
                           f"nearest-bucket fallback, not this bucket")
        return out

    def export_artifacts(self) -> Dict[Tuple, bytes]:
        """Deploy every live bucket model (Sec. III-C): the returned blobs
        feed ``SolServer(deployed=...)`` for artifact serving.  Input specs
        come from each program's graph, so the multi-input decode program
        exports the same way the single-input programs do."""
        from ..frontends import deploy as D
        if self.mesh is not None:
            raise RuntimeError(
                "export_artifacts: mesh-compiled bucket models cannot "
                "round-trip through jax.export + single-device "
                "DeployedModel staging — serve them live, or compile "
                "with mesh=(1, 1) for artifact export (per-shard "
                "artifacts are the serving-fleet step)")
        out = {}
        for key, m in self._models.items():
            if isinstance(m, SolModel):
                out[key] = D.deploy(m)
        return out

    # -- autotune warmup -----------------------------------------------------

    def warm_autotune(self, max_len: Optional[int] = None, *,
                      warmup: int = 1, iters: int = 3) -> Dict[str, int]:
        """Measure every admissible impl of every served-kind node
        (LINEAR/MATMUL/ATTENTION/DECODE_ATTENTION) — sweeping declared
        ``Tunable`` config spaces — for every prefill and decode bucket
        the workload can produce, and record the timings into the election
        cache.  After this, bucket compiles elect from measurements
        ('measured'/'pinned' provenance), exactly like
        ``benchmarks/autotune.py`` but scoped to the served graphs.

        Measurements land in the process-wide ``autotune.get_cache()`` —
        the cache the election pass and the strict audit read; install a
        different one with ``autotune.set_cache`` BEFORE warming."""
        if self._deploy_only:
            raise RuntimeError("deploy-mode serving has no live graphs to "
                               "warm; tune before deploying instead")
        cache = AT.get_cache()
        counts = {"nodes": 0, "impls": 0, "skipped": 0}
        seen = set()
        for g in self._warm_graphs(max_len):
            if self.mesh is not None:
                # partition BEFORE the pipeline, exactly like the serving
                # compile: measurements then key on per-shard shapes (each
                # timed on one device — the local work a shard executes)
                from ..distributed import sharding as shd
                g = shd.shard_graph(g, self.mesh)
            g = passes.run_pipeline(g, self.backend)
            for node in g.topo():
                if node.op not in SERVED_KINDS:
                    continue
                shape = AT.node_shape(node)
                key = (node.op.value, shape, node.spec.dtype)
                if key in seen:
                    continue
                seen.add(key)
                if cache.has_bucket(node.op.value, shape, node.spec.dtype,
                                    self.backend.cache_name):
                    counts["skipped"] += 1
                    continue
                counts["nodes"] += 1
                counts["impls"] += _measure_node(
                    node, self.backend, cache, warmup=warmup, iters=iters)
        return counts

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        done = self._finished
        lat = [1e3 * (r.finished_time - r.submitted) for r in done
               if r.finished_time is not None]
        ttft = [1e3 * (r.first_token_time - r.submitted) for r in done
                if r.first_token_time is not None]
        # wall clock of the serving itself (first step → last step), so the
        # metric is stable however long after run() summary() is called
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None
                else 0.0)

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        return {
            "mode": "decode" if self.cfg.decode else "reforward",
            "mesh": list(self.cfg.mesh),
            "requests": len(done),
            "tokens": self.stats["tokens"],
            "tokens_per_s": self.stats["tokens"] / wall if wall else 0.0,
            "steps": self.stats["steps"],
            "forwards": self.stats["forwards"],
            "dmas": self.stats["dmas"],
            "prefills": self.stats["prefills"],
            "decodes": self.stats["decodes"],
            "latency_ms": {"p50": pct(lat, 50), "p99": pct(lat, 99)},
            "ttft_ms": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
            "buckets": dict(self.stats["buckets"]),
            "queue": self.queue.stats(),
        }


def _measure_node(node, backend, cache: AT.AutotuneCache, *,
                  warmup: int, iters: int) -> int:
    """Time every admissible impl of one node (all tunable configs) through
    the shared sweep (``core.measure.sweep_node`` — the same code path as
    ``benchmarks/autotune.py``) and return how many impls were recorded.
    Integer inputs (the decode program's ``lens``) get worst-case values:
    every row attends a full cache, so the recorded timing bounds the
    served cost."""
    rng = np.random.default_rng(0)
    vals = []
    for inp in node.inputs:
        if inp.spec.dtype.startswith("int"):
            fill = (node.inputs[1].spec.shape[1]
                    if node.op is OpKind.DECODE_ATTENTION else 1)
            vals.append(jnp.full(inp.spec.shape, fill, jnp.int32))
        else:
            vals.append(jnp.asarray(rng.standard_normal(inp.spec.shape),
                                    jnp.float32))
    return len(measure.sweep_node(node, vals, backend, cache,
                                  warmup=warmup, iters=iters))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _fleet_smoke(cfg: ServeConfig, n_replicas: int, n_requests: int,
                 gen: int) -> int:
    """``--fleet N`` smoke: serve the workload through a ``SolFleet`` of N
    strict-provenance replicas with ONE injected mid-stream replica kill,
    then verify against an undisturbed same-seed fleet on the same
    weights: every request must complete (re-queued included) with
    token-identical output.  What CI's fleet step runs."""
    from .fleet import FleetConfig, SolFleet

    model = build_lm(cfg)
    workload = _smoke_workload(cfg, n_requests, gen)
    samplings = [SamplingParams(temperature=0.8, seed=1000 + i)
                 for i in range(len(workload))]

    fleet = SolFleet(cfg, FleetConfig(n_replicas=n_replicas), model=model,
                     strict_provenance=True)
    reqs = [fleet.submit(p, g, sampling=sp)
            for (p, g), sp in zip(workload, samplings)]
    t0 = time.perf_counter()
    counts = fleet.warm_autotune()
    print(f"[fleet] autotune warmup on {cfg.backend}: {counts['impls']} "
          f"impl timings over {counts['nodes']} keys "
          f"({counts['skipped']} already cached) in "
          f"{time.perf_counter() - t0:.1f}s — shared by all "
          f"{n_replicas} replicas")
    for _ in range(2):              # get requests mid-stream before the kill
        fleet.tick()
    killed = fleet.kill()
    print(f"[fleet] injected kill of replica {killed} at tick "
          f"{fleet.stats['ticks']}")
    s = fleet.run()
    fleet.close()
    print(f"[fleet] {s['requests']} requests, {s['tokens']} tokens over "
          f"{s['replicas']} replicas in {s['ticks']} ticks "
          f"({s['tokens_per_s']:.1f} tok/s); requeued={s['requeued']} "
          f"respawns={s['respawns']} recovery={s['recovery_s']['max'] * 1e3:.1f}ms; "
          f"served_by={s['served_by']}")
    dropped = [r.fid for r in reqs if r.generated is None]
    if dropped:
        print(f"[fleet] DROPPED requests after kill: {dropped}",
              file=sys.stderr)
        return 1

    base = SolFleet(cfg, FleetConfig(n_replicas=1), model=model,
                    strict_provenance=True)
    breqs = [base.submit(p, g, sampling=sp)
             for (p, g), sp in zip(workload, samplings)]
    base.run()
    base.close()
    diverged = [r.fid for r, b in zip(reqs, breqs)
                if r.generated != b.generated]
    if diverged:
        print(f"[fleet] kill-recovery DIVERGED from the undisturbed "
              f"same-seed run for requests {diverged}", file=sys.stderr)
        return 1
    print(f"[fleet] token output identical to the undisturbed same-seed "
          f"run for all {len(reqs)} requests "
          f"({s['requeued']} re-queued across the kill)")
    return 0


def _smoke_workload(cfg: ServeConfig, n_requests: int, gen: int,
                    seed: int = 1) -> List[Tuple[np.ndarray, int]]:
    hi = min(24, cfg.max_seq - gen - 1)    # prompts leave room to decode
    if hi <= 4:
        raise ValueError(
            f"gen={gen} leaves no room for prompts within "
            f"max_seq={cfg.max_seq}; lower --gen or raise --max-seq")
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_requests):
        plen = int(rng.integers(4, hi))
        out.append((rng.integers(0, cfg.vocab, plen, dtype=np.int32)
                    .astype(np.int32), gen))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + strict measured-provenance audit "
                         "over prefill AND decode buckets + deploy "
                         "round-trip; what CI runs")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=128)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--no-decode", action="store_true",
                    help="serve with the full re-forward baseline instead "
                         "of the incremental decode program")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve through a SolFleet of N replicas with one "
                         "injected mid-stream kill + token-identity check "
                         "vs an undisturbed fleet (launch/fleet.py)")
    ap.add_argument("--mesh", default="1,1", metavar="DATA,MODEL",
                    help="serve across a debug mesh of data,model devices "
                         "(default 1,1 = single device); needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count "
                         "on CPU")
    ap.add_argument("--json", help="write the serve summary to this path")
    ap.add_argument("--no-deploy-roundtrip", action="store_true",
                    help="skip the artifact round-trip leg of --smoke")
    args = ap.parse_args(argv)

    try:
        mesh = tuple(int(a) for a in args.mesh.split(","))
        if len(mesh) != 2:
            raise ValueError
    except ValueError:
        print(f"--mesh wants 'data,model' (got {args.mesh!r})",
              file=sys.stderr)
        return 2

    if args.smoke:
        cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64,
                          max_seq=32, max_batch=4, slots=4,
                          backend=args.backend, decode=not args.no_decode,
                          mesh=mesh)
        args.requests, args.gen = min(args.requests, 6), min(args.gen, 6)
    else:
        cfg = ServeConfig(d_model=args.d_model, n_heads=args.n_heads,
                          n_layers=args.layers, vocab=args.vocab,
                          max_seq=args.max_seq, max_batch=args.max_batch,
                          slots=args.slots, backend=args.backend,
                          decode=not args.no_decode, mesh=mesh)

    if args.fleet:
        if args.fleet < 1 or mesh != (1, 1):
            print("--fleet wants N >= 1 replicas on mesh 1,1 (a replica "
                  "may itself be a mesh once per-replica meshes get their "
                  "own devices)", file=sys.stderr)
            return 2
        return _fleet_smoke(cfg, args.fleet,
                            max(args.requests, 4 * args.fleet), args.gen)

    server = SolServer(cfg, strict_provenance=True)
    workload = _smoke_workload(cfg, args.requests, args.gen)
    for prompt, g in workload:
        server.submit(prompt, g)

    t0 = time.perf_counter()
    counts = server.warm_autotune()
    print(f"[serve] autotune warmup on {cfg.backend}: "
          f"{counts['impls']} impl timings over {counts['nodes']} "
          f"(op, shape) keys ({counts['skipped']} already cached) in "
          f"{time.perf_counter() - t0:.1f}s")

    summary = server.run()
    print(f"[serve] mode={summary['mode']}: {summary['requests']} "
          f"requests, {summary['tokens']} tokens in {summary['steps']} "
          f"steps / {summary['forwards']} forwards "
          f"({summary['tokens_per_s']:.1f} tok/s, one packed DMA per "
          f"forward: {summary['dmas']})")
    print(f"[serve] latency p50/p99 = {summary['latency_ms']['p50']:.1f}/"
          f"{summary['latency_ms']['p99']:.1f} ms; ttft p50 = "
          f"{summary['ttft_ms']['p50']:.1f} ms; buckets "
          f"{summary['buckets']}")

    failures = []
    for bucket, rec in sorted(server.served_elections.items()):
        prov = rec["provenance"]
        for kind, impls in rec["by_op"].items():
            for name in impls:
                entry = prov.get(name, {})
                srcs = entry.get("sources", {})
                pins = entry.get("pinned", "")
                print(f"[serve] bucket {bucket} {kind} → {name} "
                      f"sources={srcs}"
                      + (f" pinned={pins}" if pins else ""))
                if set(srcs) - {"measured"} or not srcs:
                    failures.append(f"{bucket}:{kind}->{name}:{srcs}")
    if failures:
        print(f"[serve] unmeasured elections served: {failures}",
              file=sys.stderr)
        return 1

    if args.smoke and mesh != (1, 1) and not args.no_deploy_roundtrip:
        print("[serve] mesh run: skipping the deploy round-trip leg "
              "(mesh-compiled models are served live, not exported)")
    elif args.smoke and not args.no_deploy_roundtrip:
        arts = server.export_artifacts()
        replay = SolServer(cfg, deployed=arts, strict_provenance=True)
        reqs = [replay.submit(p, g) for p, g in workload]
        replay.run()
        live_by_rid = {r.rid: r.generated for r in server._finished}
        for r in reqs:
            if r.generated != live_by_rid[r.rid]:
                print(f"[serve] deploy round-trip DIVERGED for request "
                      f"{r.rid}: {r.generated} != {live_by_rid[r.rid]}",
                      file=sys.stderr)
                return 1
        print(f"[serve] deploy round-trip: {len(arts)} bucket artifacts "
              f"served {len(reqs)} requests bit-identically")
        replay.close()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[serve] wrote {args.json}")
    server.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
