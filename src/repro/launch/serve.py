"""Serving driver: batched prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke
from ..models import backbone as B
from .mesh import make_debug_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh \
        else make_debug_mesh(1, 1)
    key = jax.random.PRNGKey(0)
    params = B.init_params(cfg, key)
    max_seq = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    enc_out = None
    extra = {}
    if cfg.frontend == "audio":
        frames = jnp.zeros((args.batch, cfg.enc_dec.enc_seq, cfg.d_model))
        enc_out = B.run_encoder(cfg, params, frames)
    if cfg.frontend == "vision":
        extra["patches"] = jnp.zeros((args.batch, cfg.n_patches,
                                      cfg.d_model))

    decode = jax.jit(
        lambda p, c, t, pos: B.decode_step(cfg, p, c, t, pos,
                                           enc_out=enc_out),
        donate_argnums=(1,))

    with mesh:
        # prefill: replay prompt through decode steps to fill the cache
        # (token-by-token prefill — the batched prefill path is exercised by
        # benchmarks/serving.py; this driver shows the decode loop)
        cache = B.init_cache(cfg, args.batch, max_seq)
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, cache = decode(params, cache, prompts[:, t:t + 1],
                                   jnp.asarray(t))
        t_prefill = time.time() - t0

        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens = [tokens]
        t0 = time.time()
        for t in range(args.prompt_len, max_seq - 1):
            logits, cache = decode(params, cache, tokens, jnp.asarray(t))
            tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out_tokens.append(tokens)
        dt = time.time() - t0
        gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)

    n_gen = gen.shape[1] - 1
    print(f"[serve] {cfg.name}: batch {args.batch}, prompt "
          f"{args.prompt_len}, generated {n_gen} tokens/seq")
    print(f"[serve] prefill {t_prefill:.2f}s; decode "
          f"{dt / max(n_gen, 1) * 1000:.1f} ms/token/batch "
          f"({args.batch * n_gen / max(dt, 1e-9):.1f} tok/s)")
    print(f"[serve] sample continuation: {gen[0, :12].tolist()}")


if __name__ == "__main__":
    main()
