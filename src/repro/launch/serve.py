"""SOL serving subsystem: continuous batching ON the elected/tuned graph.

The runtime chapter (paper Sec. IV-C) under real traffic: earlier drivers
served ``models/backbone.py`` directly, bypassing everything the middleware
exists for — elections, pinned autotune configs, Pallas kernels.  This
server routes every forward through ``frontends/optimize.SolModel`` (or a
``frontends/deploy`` artifact, closing the Sec. III-C deployment loop), so
the impls that serve traffic are exactly the impls the conformance matrix
validates and the autotune cache elected.

Pieces, and which paper mechanism each reproduces:

* :class:`SlotArena` — per-request KV-cache slots in an
  ``AsyncQueue``-backed arena: admission ``malloc_async``s a slot-sized
  virtual allocation, the prompt lands via ``memcpy_async``, each decoded
  token is appended with virtual-pointer arithmetic (``ptr + len·4``), and
  eviction is an async free.  Admission blocks when no slot is free;
  eviction on completion frees the slot for the next pending request —
  that interleaving is what lets prefill and decode share the machine.
* **Bucket padding aligned with the autotune cache** — batches are padded
  to ``core.autotune.ceil_pow2`` buckets per dim.  A power of two is its
  own cache bucket, so every served shape hits the measured-timing entries
  and pinned ``Tunable`` configs exactly, never the roofline fallback.
* **Packed staging** — each step's embedded rows go host→device as ONE DMA
  via ``runtime.packed.stage_batch`` (the VEO-udma gather policy).
* **Continuous batching** — the scheduler serves the least-recently-served
  ``max_batch`` residents each step (starvation-free round-robin); newly
  admitted requests prefill in the same forward that decodes older ones
  (causal models make prefill and decode the same padded forward here, so
  the batch mixes phases freely).
* **Provenance enforcement** — with ``strict_provenance`` every
  LINEAR/MATMUL/ATTENTION dispatch must have been elected from autotune
  measurements (``SolModel.check_provenance``); a cold cache raises
  :class:`ProvenanceError` instead of silently serving roofline guesses.
  ``warm_autotune`` measures every admissible impl (sweeping declared
  ``Tunable`` spaces) for every bucket the workload can produce.

Smoke run (what CI executes):

    PYTHONPATH=src python -m repro.launch.serve --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..backends import get_backend
from ..core import autotune as AT
from ..core import measure, passes
from ..core.ir import OpKind
from ..frontends import nn
from ..frontends.extract import extract
from ..frontends.optimize import SolModel, optimize, provenance_violations
from ..runtime import packed
from ..runtime.async_queue import AsyncQueue

TOKEN_BYTES = 4                    # int32 tokens in the slot arena
MIN_SEQ_BUCKET = 8                 # smallest padded sequence bucket
SERVED_KINDS = (OpKind.LINEAR, OpKind.MATMUL, OpKind.ATTENTION)


class ProvenanceError(RuntimeError):
    """A bucket model would serve elections that did not come from autotune
    measurements — the silent-roofline-fallback the smoke run must catch."""


# ---------------------------------------------------------------------------
# serving model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Shape of the served LM + scheduler limits.  ``max_seq`` must be a
    power of two so the largest sequence bucket is exactly the context
    bound."""

    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    vocab: int = 128
    max_seq: int = 64              # per-request context bound (pow2)
    max_batch: int = 4             # requests per forward step
    slots: int = 8                 # KV-slot arena size (resident requests)
    backend: str = "xla"
    seed: int = 0

    def __post_init__(self):
        if self.max_seq != AT.ceil_pow2(self.max_seq):
            raise ValueError(f"max_seq {self.max_seq} must be a power of "
                             f"two (it is the largest sequence bucket)")
        if self.max_batch < 1 or self.slots < 1:
            raise ValueError("max_batch and slots must be >= 1")


def build_lm(cfg: ServeConfig) -> nn.Sequential:
    """The served module: pre-norm transformer blocks + LM head.  Plain
    framework modules — SOL extracts/optimizes them; the server never calls
    their eager forward."""
    blocks = [nn.transformer_block(cfg.d_model, cfg.n_heads)
              for _ in range(cfg.n_layers)]
    return nn.Sequential(*blocks, nn.Linear(cfg.d_model, cfg.vocab))


def embedding_table(cfg: ServeConfig) -> np.ndarray:
    """Deterministic host-side token embedding.  Token→vector lookup is a
    host gather (the SOL IR starts at dense tensors); everything after it —
    every LINEAR/MATMUL/ATTENTION — runs through the elected graph."""
    rng = np.random.default_rng(cfg.seed)
    return (rng.standard_normal((cfg.vocab, cfg.d_model)) * 0.25
            ).astype(np.float32)


# ---------------------------------------------------------------------------
# requests + KV-slot arena
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                       # int32 (L,)
    max_new_tokens: int
    submitted: float
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    phase: str = "pending"                   # pending|prefill|decode|done
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None
    last_served_step: int = -1
    served_steps: List[int] = dataclasses.field(default_factory=list)
    last_logits: Optional[np.ndarray] = None

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def done(self) -> bool:
        return self.phase == "done"


class SlotArena:
    """Per-request KV-cache slots backed by the async queue's virtual
    allocator (paper Sec. IV-C).  A slot holds the request's materialized
    token context (`max_seq` int32s); admission/append/evict are all
    enqueued operations, so the arena exercises the exact machinery the
    runtime bugfixes harden: snapshot-at-enqueue memcopies, error
    re-raising at ``synchronize``, loud use-after-free."""

    def __init__(self, queue: AsyncQueue, n_slots: int, max_seq: int):
        self.queue = queue
        self.max_seq = max_seq
        self._free = list(range(n_slots - 1, -1, -1))
        self._ptr: Dict[int, Any] = {}
        self._len: Dict[int, int] = {}

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def resident(self) -> int:
        return len(self._ptr)

    def admit(self, tokens: np.ndarray) -> Optional[int]:
        """Allocate a slot and stage the prompt into it; None when full
        (the request waits in the pending queue — admission control)."""
        if not self._free:
            return None
        tokens = np.ascontiguousarray(tokens, np.int32)
        if len(tokens) > self.max_seq:
            raise ValueError(f"prompt of {len(tokens)} tokens exceeds the "
                             f"{self.max_seq}-token slot")
        slot = self._free.pop()
        ptr = self.queue.malloc_async(self.max_seq * TOKEN_BYTES)
        self.queue.memcpy_async(ptr, tokens)
        self._ptr[slot] = ptr
        self._len[slot] = len(tokens)
        return slot

    def append(self, slot: int, token: int) -> None:
        """Append one decoded token — virtual-pointer arithmetic into the
        live allocation, no host-side reassembly."""
        n = self._len[slot]
        if n >= self.max_seq:
            raise ValueError(f"slot {slot} is full ({n} tokens)")
        self.queue.memcpy_async(self._ptr[slot] + n * TOKEN_BYTES,
                                np.asarray([token], np.int32))
        self._len[slot] = n + 1

    def tokens(self, slot: int) -> np.ndarray:
        """The slot's current context.  Callers must ``synchronize`` the
        queue first so staged writes have landed."""
        buf = self.queue.allocator.resolve(self._ptr[slot])
        n = self._len[slot]
        return buf[:n * TOKEN_BYTES].view(np.int32).copy()

    def evict(self, slot: int) -> None:
        self.queue.free_async(self._ptr.pop(slot))
        del self._len[slot]
        self._free.append(slot)


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class SolServer:
    """Continuous-batching server over the SOL pipeline.

    ``deployed`` switches the server to artifact mode: a mapping
    ``(batch_bucket, seq_bucket) → deploy blob / DeployedModel``; buckets
    outside the mapping raise instead of silently compiling a parallel
    live path."""

    def __init__(self, cfg: Optional[ServeConfig] = None,
                 model: Optional[nn.Module] = None, *,
                 deployed: Optional[Dict[Tuple[int, int], Any]] = None,
                 strict_provenance: bool = False,
                 device=None):
        self.cfg = cfg or ServeConfig()
        self.backend = get_backend(self.cfg.backend)
        self.strict_provenance = strict_provenance
        self._device = device
        self.embed = embedding_table(self.cfg)
        self.queue = AsyncQueue()
        self.arena = SlotArena(self.queue, self.cfg.slots, self.cfg.max_seq)
        self._models: Dict[Tuple[int, int], Any] = {}
        self._deploy_only = deployed is not None
        self.served_elections: Dict[Tuple[int, int], Dict[str, Any]] = {}
        if deployed is not None:
            from ..frontends import deploy as D
            for key, art in deployed.items():
                m = D.load(art, device) if isinstance(art, bytes) else art
                self._models[tuple(key)] = self._audit(m, tuple(key))
            self.model = model
        else:
            self.model = model if model is not None else build_lm(self.cfg)
        self._pending: "deque[Request]" = deque()
        self._active: List[Request] = []
        self._finished: List[Request] = []
        self._next_rid = 0
        self._step = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self.stats = {"steps": 0, "dmas": 0, "tokens": 0, "prefills": 0,
                      "decodes": 0, "admitted": 0, "evicted": 0,
                      "buckets": {}}

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt: Sequence[int],
               max_new_tokens: int = 16) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size >= self.cfg.max_seq:
            raise ValueError(f"prompt of {prompt.size} tokens leaves no "
                             f"room to decode within max_seq="
                             f"{self.cfg.max_seq}")
        if np.any(prompt < 0) or np.any(prompt >= self.cfg.vocab):
            raise ValueError("prompt token out of vocabulary range")
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max(1, int(max_new_tokens)),
                      submitted=time.perf_counter())
        self._next_rid += 1
        self._pending.append(req)
        return req

    def step(self) -> List[int]:
        """One scheduler tick: admit → select → stage (one DMA) → forward
        through the elected graph → sample/append/evict.  Returns the rids
        served this step."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        # admission: pending requests claim free KV slots
        while self._pending and self.arena.free_slots:
            req = self._pending.popleft()
            req.slot = self.arena.admit(req.prompt)
            req.phase = "prefill"
            self._active.append(req)
            self.stats["admitted"] += 1
        if not self._active:
            return []
        # fairness: least-recently-served first (rid FIFO tiebreak) — every
        # resident request is served at least once per ceil(R/max_batch)
        # steps, so nothing starves
        batch = sorted(self._active,
                       key=lambda r: (r.last_served_step, r.rid)
                       )[: self.cfg.max_batch]
        # flush staged slot writes; a failed async op re-raises HERE
        self.queue.synchronize()
        rows_tok = [self.arena.tokens(r.slot) for r in batch]
        lens = [len(t) for t in rows_tok]
        bucket = self._bucket(len(batch), max(lens))
        bb, sb = bucket
        rows = []
        for t in rows_tok:
            padded = np.zeros(sb, np.int32)
            padded[: len(t)] = t
            rows.append(self.embed[padded])            # (sb, d_model) f32
        for _ in range(bb - len(batch)):
            rows.append(np.zeros((sb, self.cfg.d_model), np.float32))
        x = packed.stage_batch(rows, self._device)     # ONE DMA per batch
        self.stats["dmas"] += 1
        model = self._model_for(bucket)
        logits = np.asarray(model(x))                  # (bb, sb, vocab)
        self._step += 1
        self.stats["steps"] += 1
        key = f"{bb}x{sb}"
        self.stats["buckets"][key] = self.stats["buckets"].get(key, 0) + 1
        now = time.perf_counter()
        for i, req in enumerate(batch):
            # copy: a bare slice would pin the whole step's logits tensor
            # in memory for as long as the request record lives
            row = logits[i, lens[i] - 1].copy()
            req.last_logits = row
            tok = int(np.argmax(row))
            if req.phase == "prefill":
                req.first_token_time = now
                req.phase = "decode"
                self.stats["prefills"] += 1
            else:
                self.stats["decodes"] += 1
            req.generated.append(tok)
            req.last_served_step = self._step
            req.served_steps.append(self._step)
            self.stats["tokens"] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or req.length >= self.cfg.max_seq):
                req.phase = "done"
                req.finished_time = now
                self.arena.evict(req.slot)
                req.slot = None
                self.stats["evicted"] += 1
                self._active.remove(req)
                self._finished.append(req)
            else:
                self.arena.append(req.slot, tok)
        self._t_last = time.perf_counter()
        return [r.rid for r in batch]

    def run(self, max_steps: int = 100_000) -> Dict[str, Any]:
        while self._pending or self._active:
            if self._step >= max_steps:
                raise RuntimeError(f"serving exceeded {max_steps} steps "
                                   f"with requests still in flight")
            self.step()
        return self.summary()

    def close(self) -> None:
        self.queue.close()

    # -- buckets + models ----------------------------------------------------

    def _bucket(self, n_rows: int, max_len: int) -> Tuple[int, int]:
        """The (batch, seq) pow2 bucket a physical batch is padded to —
        aligned with ``core.autotune`` keying so served shapes hit measured
        cache entries exactly."""
        sb = min(self.cfg.max_seq,
                 max(min(MIN_SEQ_BUCKET, self.cfg.max_seq),
                     AT.ceil_pow2(max_len)))
        return (AT.ceil_pow2(n_rows), sb)

    def bucket_space(self, max_len: Optional[int] = None
                     ) -> List[Tuple[int, int]]:
        """Every (batch, seq) bucket the current workload can produce —
        what ``warm_autotune`` measures ahead of serving."""
        if max_len is None:
            reqs = list(self._pending) + self._active
            if not reqs:
                raise ValueError("no requests to derive the bucket space "
                                 "from; pass max_len explicitly")
            max_len = max(min(self.cfg.max_seq,
                              len(r.prompt) + r.max_new_tokens)
                          for r in reqs)
        smax = min(self.cfg.max_seq, AT.ceil_pow2(max_len))
        sbs = []
        s = min(MIN_SEQ_BUCKET, self.cfg.max_seq)
        while s <= smax:
            sbs.append(s)
            s *= 2
        bbs = []
        b = 1
        while b <= AT.ceil_pow2(self.cfg.max_batch):
            bbs.append(b)
            b *= 2
        return [(b, s) for b in bbs for s in sbs]

    def _model_for(self, bucket: Tuple[int, int]):
        m = self._models.get(bucket)
        if m is not None:
            return m
        if self._deploy_only:
            raise KeyError(
                f"bucket {bucket} not among the deployed artifacts "
                f"{sorted(self._models)} — deploy-mode serving never "
                f"falls back to a live compile")
        bb, sb = bucket
        sol = optimize(self.model, (bb, sb, self.cfg.d_model),
                       backend=self.backend)
        self._models[bucket] = self._audit(sol, bucket)
        return sol

    def _audit(self, model, bucket: Tuple[int, int]):
        """Record (and under ``strict_provenance`` enforce) which impls the
        bucket model serves."""
        kinds = tuple(k.value for k in SERVED_KINDS)
        self.served_elections[bucket] = {
            "by_op": {k: dict(v) for k, v in
                      model.impl_report(by_kind=True).items()
                      if k in kinds},
            "provenance": model.impl_report(provenance=True),
        }
        if self.strict_provenance:
            viol = provenance_violations(model.impl_report(by_kind=True),
                                         model.impl_report(provenance=True),
                                         kinds=kinds)
            if isinstance(model, SolModel):
                viol += self._exact_bucket_violations(model)
            if viol:
                raise ProvenanceError(
                    f"bucket {bucket} would serve unmeasured elections "
                    f"(warm the autotune cache first): {viol}")
        return model

    def _exact_bucket_violations(self, model: SolModel) -> List[str]:
        """An election can carry 'measured' provenance via the cache's
        nearest-bucket fallback — timings from a *different* shape.  Strict
        serving requires every LINEAR/MATMUL/ATTENTION node's EXACT bucket
        to hold measurements (a late-submitted request that opens a new
        bucket needs another ``warm_autotune()`` call, which skips
        already-measured buckets)."""
        cache = AT.get_cache()
        out = []
        for node in model.graph.topo():
            if node.op not in SERVED_KINDS:
                continue
            shape = AT.node_shape(node)
            if not cache.has_bucket(node.op.value, shape, node.spec.dtype,
                                    self.backend.name):
                out.append(f"{node.op.value}@{shape}: measured via "
                           f"nearest-bucket fallback, not this bucket")
        return out

    def export_artifacts(self) -> Dict[Tuple[int, int], bytes]:
        """Deploy every live bucket model (Sec. III-C): the returned blobs
        feed ``SolServer(deployed=...)`` for artifact serving."""
        from ..frontends import deploy as D
        out = {}
        for (bb, sb), m in self._models.items():
            if isinstance(m, SolModel):
                out[(bb, sb)] = D.deploy(m, (bb, sb, self.cfg.d_model))
        return out

    # -- autotune warmup -----------------------------------------------------

    def warm_autotune(self, max_len: Optional[int] = None, *,
                      warmup: int = 1, iters: int = 3) -> Dict[str, int]:
        """Measure every admissible impl of every LINEAR/MATMUL/ATTENTION
        node — sweeping declared ``Tunable`` config spaces — for every
        bucket the workload can produce, and record the timings into the
        election cache.  After this, bucket compiles elect from
        measurements ('measured'/'pinned' provenance), exactly like
        ``benchmarks/autotune.py`` but scoped to the served graph.

        Measurements land in the process-wide ``autotune.get_cache()`` —
        the cache the election pass and the strict audit read; install a
        different one with ``autotune.set_cache`` BEFORE warming."""
        if self._deploy_only:
            raise RuntimeError("deploy-mode serving has no live graphs to "
                               "warm; tune before deploying instead")
        cache = AT.get_cache()
        counts = {"nodes": 0, "impls": 0, "skipped": 0}
        seen = set()
        for bb, sb in self.bucket_space(max_len):
            g = extract(self.model, (bb, sb, self.cfg.d_model))
            g = passes.run_pipeline(g, self.backend)
            for node in g.topo():
                if node.op not in SERVED_KINDS:
                    continue
                shape = AT.node_shape(node)
                key = (node.op.value, shape, node.spec.dtype)
                if key in seen:
                    continue
                seen.add(key)
                if cache.has_bucket(node.op.value, shape, node.spec.dtype,
                                    self.backend.name):
                    counts["skipped"] += 1
                    continue
                counts["nodes"] += 1
                counts["impls"] += _measure_node(
                    node, self.backend, cache, warmup=warmup, iters=iters)
        return counts

    # -- reporting -----------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        done = self._finished
        lat = [1e3 * (r.finished_time - r.submitted) for r in done
               if r.finished_time is not None]
        ttft = [1e3 * (r.first_token_time - r.submitted) for r in done
                if r.first_token_time is not None]
        # wall clock of the serving itself (first step → last step), so the
        # metric is stable however long after run() summary() is called
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None
                else 0.0)

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        return {
            "requests": len(done),
            "tokens": self.stats["tokens"],
            "tokens_per_s": self.stats["tokens"] / wall if wall else 0.0,
            "steps": self.stats["steps"],
            "dmas": self.stats["dmas"],
            "prefills": self.stats["prefills"],
            "decodes": self.stats["decodes"],
            "latency_ms": {"p50": pct(lat, 50), "p99": pct(lat, 99)},
            "ttft_ms": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
            "buckets": dict(self.stats["buckets"]),
            "queue": self.queue.stats(),
        }


def _measure_node(node, backend, cache: AT.AutotuneCache, *,
                  warmup: int, iters: int) -> int:
    """Time every admissible impl of one node (all tunable configs) through
    the shared sweep (``core.measure.sweep_node`` — the same code path as
    ``benchmarks/autotune.py``) and return how many impls were recorded."""
    rng = np.random.default_rng(0)
    vals = [jnp.asarray(rng.standard_normal(i.spec.shape), jnp.float32)
            for i in node.inputs]
    return len(measure.sweep_node(node, vals, backend, cache,
                                  warmup=warmup, iters=iters))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _smoke_workload(cfg: ServeConfig, n_requests: int, gen: int,
                    seed: int = 1) -> List[Tuple[np.ndarray, int]]:
    hi = min(24, cfg.max_seq - gen - 1)    # prompts leave room to decode
    if hi <= 4:
        raise ValueError(
            f"gen={gen} leaves no room for prompts within "
            f"max_seq={cfg.max_seq}; lower --gen or raise --max-seq")
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_requests):
        plen = int(rng.integers(4, hi))
        out.append((rng.integers(0, cfg.vocab, plen, dtype=np.int32)
                    .astype(np.int32), gen))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + strict measured-provenance audit + "
                         "deploy round-trip; what CI runs")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=128)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--json", help="write the serve summary to this path")
    ap.add_argument("--no-deploy-roundtrip", action="store_true",
                    help="skip the artifact round-trip leg of --smoke")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64,
                          max_seq=32, max_batch=4, slots=4,
                          backend=args.backend)
        args.requests, args.gen = min(args.requests, 6), min(args.gen, 6)
    else:
        cfg = ServeConfig(d_model=args.d_model, n_heads=args.n_heads,
                          n_layers=args.layers, vocab=args.vocab,
                          max_seq=args.max_seq, max_batch=args.max_batch,
                          slots=args.slots, backend=args.backend)

    server = SolServer(cfg, strict_provenance=True)
    workload = _smoke_workload(cfg, args.requests, args.gen)
    for prompt, g in workload:
        server.submit(prompt, g)

    t0 = time.perf_counter()
    counts = server.warm_autotune()
    print(f"[serve] autotune warmup on {cfg.backend}: "
          f"{counts['impls']} impl timings over {counts['nodes']} "
          f"(op, shape) keys ({counts['skipped']} already cached) in "
          f"{time.perf_counter() - t0:.1f}s")

    summary = server.run()
    print(f"[serve] {summary['requests']} requests, {summary['tokens']} "
          f"tokens in {summary['steps']} steps "
          f"({summary['tokens_per_s']:.1f} tok/s, one packed DMA per "
          f"step: {summary['dmas']})")
    print(f"[serve] latency p50/p99 = {summary['latency_ms']['p50']:.1f}/"
          f"{summary['latency_ms']['p99']:.1f} ms; ttft p50 = "
          f"{summary['ttft_ms']['p50']:.1f} ms; buckets "
          f"{summary['buckets']}")

    failures = []
    for bucket, rec in sorted(server.served_elections.items()):
        prov = rec["provenance"]
        for kind, impls in rec["by_op"].items():
            for name in impls:
                entry = prov.get(name, {})
                srcs = entry.get("sources", {})
                pins = entry.get("pinned", "")
                print(f"[serve] bucket {bucket} {kind} → {name} "
                      f"sources={srcs}"
                      + (f" pinned={pins}" if pins else ""))
                if set(srcs) - {"measured"} or not srcs:
                    failures.append(f"{bucket}:{kind}->{name}:{srcs}")
    if failures:
        print(f"[serve] unmeasured elections served: {failures}",
              file=sys.stderr)
        return 1

    if args.smoke and not args.no_deploy_roundtrip:
        arts = server.export_artifacts()
        replay = SolServer(cfg, deployed=arts, strict_provenance=True)
        reqs = [replay.submit(p, g) for p, g in workload]
        replay.run()
        live_by_rid = {r.rid: r.generated for r in server._finished}
        for r in reqs:
            if r.generated != live_by_rid[r.rid]:
                print(f"[serve] deploy round-trip DIVERGED for request "
                      f"{r.rid}: {r.generated} != {live_by_rid[r.rid]}",
                      file=sys.stderr)
                return 1
        print(f"[serve] deploy round-trip: {len(arts)} bucket artifacts "
              f"served {len(reqs)} requests bit-identically")
        replay.close()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[serve] wrote {args.json}")
    server.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
