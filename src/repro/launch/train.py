"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 128

Full substrate path: data pipeline → pjit train step (remat/ZeRO/compression
per flags) → async checkpointing → straggler monitor → restart-on-failure.
On this CPU container use --smoke (reduced config); the same flags drive the
production mesh on a real fleet.

SOL-pipeline path (``--sol``): the train step's forward AND backward ride
elected kernels —

    PYTHONPATH=src python -m repro.launch.train --smoke --sol

extracts a model-zoo block through ``optimize(training=True)``, warm-
autotunes every forward and backward impl of the graph's nodes, re-elects
from the measured cache, then HARD-ASSERTS that (a) the heavy families
elected non-reference backward kernels and (b) strict measured-provenance
holds for forward and backward elections alike, before running the training
loop.  CI runs exactly this command as the training-pipeline gate.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke
from ..data import DataConfig, DataLoader, SyntheticTokenDataset
from ..distributed import sharding as S
from ..distributed.steps import (StepOptions, init_train_state,
                                 make_train_step)
from ..models import backbone as B
from ..runtime import StragglerMonitor
from .mesh import make_debug_mesh, make_production_mesh


_SOL_HEAVY_KINDS = ("linear", "matmul", "attention", "rglru_scan",
                    "rwkv6_scan")


def _sol_zoo_model(name: str, d_model: int):
    from ..frontends import nn
    builders = {"transformer": lambda: nn.transformer_block(d_model=d_model),
                "griffin": lambda: nn.griffin_block(d_model=d_model),
                "rwkv6": lambda: nn.rwkv6_block(d_model=d_model)}
    if name not in builders:
        raise SystemExit(f"--sol-model must be one of {sorted(builders)}")
    return builders[name]()


def _node_vals(node, rng):
    """Synthetic operands for one graph node (float specs only — the zoo
    training graphs carry no integer operands)."""
    vals = []
    for i in node.inputs:
        x = rng.standard_normal(i.spec.shape).astype(np.float32)
        vals.append(jnp.asarray(x).astype(i.spec.dtype))
    return vals


def _warm_autotune(graph, backend, *, warmup: int = 1, iters: int = 3
                   ) -> int:
    """Sweep every unique (op, shape-bucket, dtype) node of the training
    graph — forward impls AND backward impls (recorded under the
    ``_bwd``-suffixed cache keys) — into the process autotune cache, the
    same dedup discipline ``SolServer.warm_autotune`` uses for serving."""
    from ..core import autotune as AT
    from ..core import measure as M
    from ..core.ir import SOURCE_OPS, OpKind

    cache = AT.get_cache()
    rng = np.random.default_rng(0)
    seen = set()
    swept = 0
    for n in graph.topo():
        if n.op in SOURCE_OPS or n.op is OpKind.OUTPUT:
            continue
        key = (n.op.value, AT.node_shape(n), n.spec.dtype)
        if key in seen:
            continue
        seen.add(key)
        vals = _node_vals(n, rng)
        M.sweep_node(n, vals, backend, cache, warmup=warmup, iters=iters)
        M.sweep_node_grad(n, vals, backend, cache, warmup=warmup,
                          iters=iters)
        swept += 1
    return swept


def _sol_main(args) -> None:
    from ..distributed.steps import StepOptions, make_sol_train_step
    from ..frontends.optimize import optimize

    d_model = 64 if args.smoke else 256
    seq = min(args.seq, 128) if args.smoke else args.seq
    batch = min(args.batch, 4) if args.smoke else args.batch
    model = _sol_zoo_model(args.sol_model, d_model)
    shape = (batch, seq, d_model)

    # cold compile → warm the cache on the real nodes → re-elect measured
    sm = optimize(model, shape, backend=args.sol_backend, training=True)
    swept = _warm_autotune(sm.graph, sm.backend)
    sm = optimize(model, shape, backend=args.sol_backend, training=True)
    by_kind = sm.impl_report(by_kind=True)
    print(f"[train --sol] warmed {swept} node buckets; elections:")
    for kind, impls in sorted(by_kind.items()):
        print(f"  {kind:>20}: {impls}")

    # gate 1: the heavy families must elect NON-REFERENCE backward kernels
    for kind in _SOL_HEAVY_KINDS:
        bwd = by_kind.get(f"{kind}_bwd")
        if bwd is None:
            continue                      # family absent from this model
        ref_only = [name for name in bwd if name.startswith("ref.")]
        if ref_only:
            raise SystemExit(
                f"[train --sol] FAIL: {kind}_bwd elected reference "
                f"backward(s) {ref_only} — expected a registered backward "
                f"kernel after warm_autotune")

    # gate 2: strict measured provenance, forward and backward alike
    kinds = tuple(k for k in by_kind
                  if k in _SOL_HEAVY_KINDS
                  or k.removesuffix("_bwd") in _SOL_HEAVY_KINDS)
    violations = sm.check_provenance(kinds=kinds, require=("measured",))
    if violations:
        raise SystemExit("[train --sol] FAIL: provenance violations:\n  "
                         + "\n  ".join(violations))
    print(f"[train --sol] strict provenance clean over {sorted(kinds)}")

    # train: fwd+bwd through the elected graph
    opts = StepOptions(lr=args.lr, warmup=max(args.steps // 10, 1),
                       total_steps=args.steps, zero=False)
    step_fn, init_state = make_sol_train_step(sm, opts)
    state = init_state()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    y = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    jitted = jax.jit(step_fn)
    losses = []
    for step in range(args.steps):
        state, metrics = jitted(state, {"x": x, "y": y})
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train --sol] step {step:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e}")
    first, last = losses[0], losses[-1]
    if not last < first:
        raise SystemExit(f"[train --sol] FAIL: loss did not improve "
                         f"({first:.4f} -> {last:.4f})")
    print(f"[train --sol] done: loss {first:.4f} -> {last:.4f} (improved), "
          f"fwd+bwd on elected kernels")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--sol", action="store_true",
                    help="train through the SOL pipeline: optimize("
                         "training=True) + warm_autotune + elected "
                         "fwd/bwd kernels")
    ap.add_argument("--sol-model", default="transformer",
                    help="model-zoo block for --sol "
                         "(transformer|griffin|rwkv6)")
    ap.add_argument("--sol-backend", default="pallas_interpret")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16"])
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.sol:
        _sol_main(args)
        return

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh \
        else make_debug_mesh(1, 1)
    opts = StepOptions(remat=not args.no_remat, microbatch=args.microbatch,
                       grad_compression=args.grad_compression,
                       zero=not args.no_zero, lr=args.lr,
                       warmup=max(args.steps // 10, 1),
                       total_steps=args.steps)

    print(f"[train] {cfg.name}: {B.count_params(cfg):,} params, "
          f"mesh {dict(mesh.shape)}")
    step_fn, state_specs = make_train_step(mesh, cfg, opts)
    state = init_train_state(cfg, opts, jax.random.PRNGKey(0))

    dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    dataset = SyntheticTokenDataset(dcfg)
    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
    monitor = StragglerMonitor(n_hosts=1)

    # resume if a checkpoint exists
    start = 0
    try:
        restored_step, restored = ckpt.restore_latest(
            jax.eval_shape(lambda: state))
        if restored is not None:
            state, start = restored, restored_step
            print(f"[train] resumed from step {start}")
    except Exception:
        pass

    loader = DataLoader(dataset, start_step=start)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    with mesh:
        t0 = time.time()
        losses = []
        for step in range(start, args.steps):
            batch = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            monitor.record_step({0: time.time() - t0})
            t0 = time.time()
            ckpt.maybe_save(step + 1, state)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
    ckpt.wait()
    loader.close()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
