"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 50 --batch 8 --seq 128

Full substrate path: data pipeline → pjit train step (remat/ZeRO/compression
per flags) → async checkpointing → straggler monitor → restart-on-failure.
On this CPU container use --smoke (reduced config); the same flags drive the
production mesh on a real fleet.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke
from ..data import DataConfig, DataLoader, SyntheticTokenDataset
from ..distributed import sharding as S
from ..distributed.steps import (StepOptions, init_train_state,
                                 make_train_step)
from ..models import backbone as B
from ..runtime import StragglerMonitor
from .mesh import make_debug_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16"])
    ap.add_argument("--no-zero", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh \
        else make_debug_mesh(1, 1)
    opts = StepOptions(remat=not args.no_remat, microbatch=args.microbatch,
                       grad_compression=args.grad_compression,
                       zero=not args.no_zero, lr=args.lr,
                       warmup=max(args.steps // 10, 1),
                       total_steps=args.steps)

    print(f"[train] {cfg.name}: {B.count_params(cfg):,} params, "
          f"mesh {dict(mesh.shape)}")
    step_fn, state_specs = make_train_step(mesh, cfg, opts)
    state = init_train_state(cfg, opts, jax.random.PRNGKey(0))

    dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    dataset = SyntheticTokenDataset(dcfg)
    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
    monitor = StragglerMonitor(n_hosts=1)

    # resume if a checkpoint exists
    start = 0
    try:
        restored_step, restored = ckpt.restore_latest(
            jax.eval_shape(lambda: state))
        if restored is not None:
            state, start = restored, restored_step
            print(f"[train] resumed from step {start}")
    except Exception:
        pass

    loader = DataLoader(dataset, start_step=start)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    with mesh:
        t0 = time.time()
        losses = []
        for step in range(start, args.steps):
            batch = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            monitor.record_step({0: time.time() - t0})
            t0 = time.time()
            ckpt.maybe_save(step + 1, state)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
    ckpt.wait()
    loader.close()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
