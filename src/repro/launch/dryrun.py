import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, record memory/cost/collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh 1pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are appended incrementally to ``results/dryrun.jsonl``; completed
cells are skipped on rerun (delete the file to redo).
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..backends.registry import TPU_V5E
from ..configs import ARCH_IDS, ALIASES, get_config
from ..distributed import sharding as S
from ..distributed.steps import (StepOptions, jit_serve_steps,
                                 make_train_step, train_state_shapes)
from ..models import backbone as B
from ..models.config import SHAPES
from . import specs as SP
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun.jsonl"

# per-arch training options: the big models need ZeRO + bf16 moments +
# remat to fit 16 GB/chip; bf16 gradient compression halves cross-pod traffic
TRAIN_OPTS = {
    # NOTE: microbatch>1 was tried for the big models and REFUTED on the
    # lowered IR: XLA re-runs the weight-gradient all-reduce and the expert
    # weight staging every microbatch (kimi collective 45s → 112s).  See
    # EXPERIMENTS.md §Perf moe-6.
    "kimi-k2-1t-a32b": StepOptions(remat=True, zero=True,
                                   moment_dtype="bfloat16",
                                   grad_compression="bf16"),
    "command-r-plus-104b": StepOptions(remat=True, zero=True,
                                       moment_dtype="bfloat16",
                                       grad_compression="bf16"),
    "internvl2-26b": StepOptions(remat=True, zero=True,
                                 moment_dtype="float32"),
}
DEFAULT_OPTS = StepOptions(remat=True, zero=True)

_COLL_RE = re.compile(
    r"(\w+\[[^\]]*\](?:, \w+\[[^\]]*\])*)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"^\s*%?\S+ = (\S+) (all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(type_str: str) -> int:
    """bytes of an HLO type string like 'bf16[256,1024]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, n_devices: int):
    """Scan optimized HLO for collectives; return per-kind result-bytes,
    op counts, and ring-model per-device ICI byte estimates."""
    kinds = {}
    ici_bytes = 0.0
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        g = n_devices
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            im = _IOTA_RE.search(line)
            if im:
                g = int(im.group(2))
        g = max(g, 1)
        d = kinds.setdefault(kind, {"count": 0, "bytes": 0, "ici_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += size
        if kind == "all-gather":
            t = size * (g - 1) / g
        elif kind == "all-reduce":
            t = 2.0 * size * (g - 1) / g
        elif kind == "reduce-scatter":
            t = size * (g - 1)          # result is the scattered shard
        elif kind == "all-to-all":
            t = size * (g - 1) / g
        else:                            # collective-permute
            t = float(size)
        d["ici_bytes"] += t
        ici_bytes += t
    return kinds, ici_bytes


def memory_summary(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    opts = TRAIN_OPTS.get(cfg.name, DEFAULT_OPTS)

    if shape.kind == "train":
        step_fn, state_specs = make_train_step(mesh, cfg, opts)
        state_shapes = train_state_shapes(cfg, opts)
        batch_shapes = SP.train_batch_specs(cfg, shape)
        batch_specs = S.batch_specs(mesh, cfg, batch_shapes)
        jitted = jax.jit(step_fn,
                         in_shardings=(S.named(mesh, state_specs),
                                       S.named(mesh, batch_specs)),
                         out_shardings=(S.named(mesh, state_specs), None),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_shapes, batch_shapes)
    elif shape.kind == "prefill":
        pshapes = B.param_specs(cfg)
        pspecs = S.param_specs(mesh, cfg, pshapes)
        batch_shapes = SP.prefill_batch_specs(cfg, shape)
        batch_specs = S.batch_specs(mesh, cfg, batch_shapes)
        dp = S.dp_axes(mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        out_spec = NamedSharding(mesh, P(
            S.shard_dim(mesh, shape.global_batch, dp), None, "model"))

        from ..distributed import ctx

        def prefill_fn(params, batch):
            with ctx.use_mesh(mesh):
                logits, _ = B.prefill(cfg, params, batch)
            return logits

        jitted = jax.jit(prefill_fn,
                         in_shardings=(S.named(mesh, pspecs),
                                       S.named(mesh, batch_specs)),
                         out_shardings=out_spec)
        with mesh:
            lowered = jitted.lower(pshapes, batch_shapes)
    else:  # decode
        pshapes = B.param_specs(cfg)
        jitted_decode, pspecs, cspecs = jit_serve_steps(
            mesh, cfg, shape.global_batch, shape.seq_len)
        cache, tokens, pos, enc_out = SP.decode_input_specs(cfg, shape)
        args = [pshapes, cache, tokens, pos]
        if enc_out is not None:
            args.append(enc_out)
        with mesh:
            lowered = jitted_decode.lower(*args)
    return lowered, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, mesh_name: str, results_path: Path):
    multi_pod = mesh_name == "2pod"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = SP.cell_is_applicable(cfg, shape)
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        _append(results_path, rec)
        print(f"[dryrun] SKIP {cfg.name} × {shape_name} × {mesh_name}: {why}")
        return rec

    t0 = time.time()
    try:
        lowered, mesh, cfg, shape = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        mem = memory_summary(compiled)
        n_dev = mesh.devices.size
        text = compiled.as_text()
        from . import hlo_analysis as HA
        loop_aware = HA.analyze(text, n_dev)
        rec.update(
            status="ok",
            n_devices=int(n_dev),
            hw=TPU_V5E.name,
            # roofline lower bound from the shared HardwareSpec cost model
            # (same terms the implementation-election pass uses)
            roofline_s=TPU_V5E.roofline_s(
                float(loop_aware["flops_per_device"]),
                float(loop_aware["hbm_bytes_per_device"]),
                float(loop_aware["ici_bytes_per_device"])),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            # loop-aware accounting (while bodies × trip count) — the
            # numbers the roofline uses
            flops_per_device=float(loop_aware["flops_per_device"]),
            hbm_bytes_per_device=float(loop_aware["hbm_bytes_per_device"]),
            ici_bytes_per_device=float(loop_aware["ici_bytes_per_device"]),
            collectives=loop_aware["collectives"],
            loops=loop_aware["loops"],
            # XLA's builtin (loop bodies counted once) for reference
            xla_flops_per_device=float(cost.get("flops", 0.0)),
            xla_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
            memory=mem,
            hlo_len=len(text),
        )
        print(f"[dryrun] OK {cfg.name} × {shape_name} × {mesh_name}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"flops/dev {rec['flops_per_device']:.3e} "
              f"hbm/dev {rec['hbm_bytes_per_device']/1e9:.1f}GB "
              f"ici/dev {rec['ici_bytes_per_device']/1e9:.2f}GB "
              f"temp {mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] FAIL {cfg.name} × {shape_name} × {mesh_name}: {e}")
    _append(results_path, rec)
    return rec


def _append(path: Path, rec: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as f:
        f.write(json.dumps(rec) + "\n")


def completed(path: Path):
    done = set()
    if path.exists():
        for line in path.read_text().splitlines():
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") in ("ok", "skipped"):
                done.add((r["arch"], r["shape"], r["mesh"]))
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="1pod", choices=["1pod", "2pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--results", default=str(RESULTS))
    ap.add_argument("--order", default="small-first",
                    choices=["small-first", "listed"])
    args = ap.parse_args()
    results_path = Path(args.results)
    meshes = ["1pod", "2pod"] if args.mesh == "both" else [args.mesh]

    if args.all:
        archs = list(ARCH_IDS)
        if args.order == "small-first":
            from ..models import backbone as BB
            archs.sort(key=lambda a: BB.count_params(get_config(a)))
        shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)

    done = completed(results_path)
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                cfg_name = get_config(arch).name
                if (cfg_name, shape_name, mesh_name) in done:
                    print(f"[dryrun] cached {cfg_name} × {shape_name} × "
                          f"{mesh_name}")
                    continue
                run_cell(arch, shape_name, mesh_name, results_path)


if __name__ == "__main__":
    main()
