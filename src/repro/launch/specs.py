"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

No device allocation — the dry-run lowers against these.  Modality
frontends are STUBS per the assignment: ``[audio]``/``[vlm]`` entries get
precomputed frame/patch embeddings as inputs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import backbone as B
from ..models.config import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    gb, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        batch["tokens"] = SDS((gb, s - cfg.n_patches), jnp.int32)
        batch["patches"] = SDS((gb, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        batch["labels"] = SDS((gb, s - cfg.n_patches), jnp.int32)
    else:
        batch["tokens"] = SDS((gb, s), jnp.int32)
        batch["labels"] = SDS((gb, s), jnp.int32)
    if cfg.frontend == "audio":
        batch["frames"] = SDS((gb, cfg.enc_dec.enc_seq, cfg.d_model),
                              jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b = train_batch_specs(cfg, shape)
    b.pop("labels", None)
    return b


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(cache, tokens, pos[, enc_out]) ShapeDtypeStructs."""
    gb, s = shape.global_batch, shape.seq_len
    cache = B.cache_specs(cfg, gb, s)
    tokens = SDS((gb, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    enc_out = None
    if cfg.enc_dec is not None:
        enc_out = SDS((gb, cfg.enc_dec.enc_seq, cfg.d_model), jnp.bfloat16)
    return cache, tokens, pos, enc_out


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    cache, tokens, pos, enc_out = decode_input_specs(cfg, shape)
    out = {"cache": cache, "tokens": tokens, "pos": pos}
    if enc_out is not None:
        out["enc_out"] = enc_out
    return out


def cell_is_applicable(cfg: ArchConfig, shape: ShapeConfig
                       ) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure/global full-attention architecture — "
                       "524k-token dense decode is not sub-quadratic "
                       "(see DESIGN.md §4)")
    return True, ""
