"""Serving fleet: N replica ``SolServer``s behind a request router with a
watcher-driven replica lifecycle (drain / evict / respawn / autoscale).

One ``SolServer`` is one *replica* — possibly a whole (data, model) mesh
(``ServeConfig.mesh``), whose shards live or die together, so the failure
domain is always the replica.  :class:`SolFleet` turns N of them into one
front-end in the aws-parallelcluster watcher idiom (nodewatcher /
sqswatcher: a periodic tick observes members, applies a membership
policy, and converges the fleet toward the desired size):

* **Router** — ``submit`` parks requests in a fleet-level queue;
  ``tick`` dispatches them to the replica with the lowest score, a
  combination of queue depth (in-flight / slots) and a per-replica
  TTFT EWMA, so slow replicas organically receive less traffic.
* **Watcher tick** — every tick steps each live replica and feeds its
  step clock into ``runtime/straggler.StragglerMonitor.record_step``.
  A ``rebalance`` verdict drains the replica's router share (no new
  traffic; after ``drain_cooldown`` ticks its monitor id is ``retire``d
  — fresh stats — and it rejoins).  An ``evict`` verdict drains, then
  evicts and respawns: drain → evict → respawn.  Two guards keep the
  health signal honest on a real clock: a replica's first
  ``join_grace`` serving steps are bucket-compile warmup and are not
  judged (the nodewatcher idiom of not health-checking a node still
  bootstrapping), and each step clock is clamped to ``spike_clip ×``
  the fleet baseline before recording, so a one-off compile/GC spike
  cannot trip an evict while a genuinely slow replica's EWMA still
  converges past the threshold.
* **Respawn** — replacement replicas come up through
  ``runtime/failures.run_with_restart``: the step function rebuilds the
  model from checkpointed params (``CheckpointManager`` restore on an
  injected/real bring-up failure) and the warmed autotune cache is
  process-wide, keyed on the mesh-tagged ``Backend.cache_name`` — so a
  replica respawned onto the same mesh shape re-enters
  ``strict_provenance`` serving without re-measuring a single bucket.
  Respawned replicas get FRESH ids, never reused: the straggler monitor
  auto-registers the new id and the old id was retired with the corpse.
* **Re-queue semantics** — when a replica dies (a restartable exception
  out of its step — :class:`ReplicaFailure` by default), its in-flight
  requests go back to the FRONT of the router queue carrying their
  original ``SamplingParams``.  Sampling is a pure function of
  (logits stream, seed) and every replica serves identical weights, so
  the re-run regenerates the identical token stream: completed output is
  token-identical to an undisturbed run, partial pre-kill output is
  discarded, nothing is dropped.
* **Autoscaling** — admission pressure: a fleet backlog above
  ``scale_up_backlog ×`` live capacity for ``scale_up_ticks`` ticks
  spawns a replica (up to ``max_replicas``); a sustained empty backlog
  with spare capacity retires the least-loaded replica gracefully
  (drain, then close) down to ``min_replicas``.
* **Fault injection** — a ``runtime/failures.FailureSimulator`` threads
  end-to-end: ``SolFleet(failure_sim=...)`` checks it each tick inside
  each replica's step scope (a scheduled tick kills the first live
  replica stepped that tick), and ``kill()`` injects a death directly.
  ``benchmarks/serving.py fleet`` replays an open-loop workload through
  this with one injected kill and records recovery time.

Single-process and cooperative: ``tick()`` runs every replica's scheduler
step inline, which keeps tests deterministic; on a real fleet the same
policy loop runs against remote step clocks.  Smoke run (what CI
executes): ``python -m repro.launch.serve --smoke --fleet 3``.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

from ..checkpoint.manager import CheckpointManager, save_checkpoint
from ..runtime.failures import (FailureSimulator, ReplicaFailure,
                                run_with_restart)
from ..runtime.straggler import StragglerMonitor
from .serve import (Request, SamplingParams, ServeConfig, SolServer,
                    build_lm, validate_prompt)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet sizing + watcher/router/autoscaler policy knobs."""

    n_replicas: int = 3            # desired size at bootstrap
    min_replicas: int = 1
    max_replicas: int = 8
    # router
    ttft_alpha: float = 0.2        # per-replica TTFT EWMA smoothing
    # straggler watcher (feeds runtime/straggler.StragglerMonitor)
    alpha: float = 0.2
    threshold: float = 2.0
    evict_threshold: float = 4.0
    warmup_steps: int = 10
    join_grace: int = 5            # a fresh replica's first serving steps
    #                                are compile warmup — not health-judged
    spike_clip: float = 5.0        # clamp a step clock to clip × the
    #                                FLEET baseline before the monitor
    drain_cooldown: int = 8        # ticks a rebalance-drain lasts
    drain_grace: int = 16          # ticks an evict-drain may take before
    #                                resident requests are re-queued
    # autoscaling: admission pressure on the fleet queue
    scale_up_backlog: float = 1.0  # backlog > factor·live·slots → pressure
    scale_up_ticks: int = 3
    scale_down_ticks: int = 10
    max_restarts: int = 10         # respawn retries (run_with_restart)

    def __post_init__(self):
        if not (1 <= self.min_replicas <= self.n_replicas
                <= self.max_replicas):
            raise ValueError(
                f"need 1 <= min_replicas <= n_replicas <= max_replicas, "
                f"got {self.min_replicas}/{self.n_replicas}/"
                f"{self.max_replicas}")


@dataclasses.dataclass
class FleetRequest:
    """The router-level handle: survives replica death (the per-replica
    ``Request`` handle is replaced on re-queue, the fleet one persists)."""

    fid: int
    prompt: np.ndarray
    max_new_tokens: int
    sampling: SamplingParams
    submitted: float
    replica: Optional[int] = None            # current replica id
    handle: Optional[Request] = None         # replica-level request
    generated: Optional[List[int]] = None    # set on completion
    requeues: int = 0
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.generated is not None


@dataclasses.dataclass
class Replica:
    """One fleet member.  ``id`` is fleet-unique and never reused — a
    respawn is a NEW member (fresh straggler stats, fresh server)."""

    id: int
    server: SolServer
    state: str = "up"              # up | draining | retiring
    drain_reason: str = ""         # rebalance | evict (while draining)
    drained_at: int = 0            # tick the drain started
    ttft_ewma: float = 0.0         # replica-local TTFT (router signal)
    serving_steps: int = 0         # steps that actually served work
    served: int = 0                # fleet requests completed here
    assigned: Dict[int, FleetRequest] = dataclasses.field(
        default_factory=dict)


class SolFleet:
    """N ``SolServer`` replicas, one router, one watcher loop."""

    def __init__(self, cfg: Optional[ServeConfig] = None,
                 fleet: Optional[FleetConfig] = None, *,
                 model=None,
                 strict_provenance: bool = False,
                 failure_sim: Optional[FailureSimulator] = None,
                 respawn_sim: Optional[FailureSimulator] = None,
                 restartable: Optional[
                     Callable[[BaseException], bool]] = None,
                 ckpt_dir: Optional[str] = None,
                 step_time_fn: Optional[
                     Callable[[Replica, float], float]] = None):
        self.cfg = cfg or ServeConfig()
        self.fleet_cfg = fleet or FleetConfig()
        self.strict_provenance = strict_provenance
        self.failure_sim = failure_sim
        self.respawn_sim = respawn_sim
        self._restartable = restartable or (
            lambda e: isinstance(e, ReplicaFailure))
        # test/benchmark hook: transform a replica's measured step clock
        # before it reaches the monitor (e.g. inflate one replica to force
        # a straggler verdict deterministically)
        self._step_time_fn = step_time_fn
        # fleet-shared weights: every replica (and every respawn) loads
        # THIS state dict, which is what makes re-queued requests
        # token-identical wherever they land
        src = model if model is not None else build_lm(self.cfg)
        self._params = {k: np.asarray(v)
                        for k, v in src.state_dict().items()}
        self._ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="sol_fleet_")
        save_checkpoint(self._ckpt_dir, 0, self._params)
        # interval is effectively ∞: run_with_restart's post-step
        # maybe_save must never try to serialize a live server object —
        # the params checkpoint written above is the restore source
        self._ckpt = CheckpointManager(self._ckpt_dir, interval=1 << 30,
                                       keep=2)
        f = self.fleet_cfg
        self.monitor = StragglerMonitor(
            0, alpha=f.alpha, threshold=f.threshold,
            evict_threshold=f.evict_threshold,
            warmup_steps=f.warmup_steps)
        self.replicas: Dict[int, Replica] = {}
        self._next_replica = 0
        self._desired = f.n_replicas
        self.pending: Deque[FleetRequest] = deque()
        self._requests: List[FleetRequest] = []
        self._next_fid = 0
        self._tick = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self._pressure_up = 0
        self._pressure_down = 0
        self.events: List[Dict[str, Any]] = []
        self.stats = {"ticks": 0, "kills": 0, "respawns": 0,
                      "requeued": 0, "evicted": 0, "drained": 0,
                      "rejoined": 0, "scale_ups": 0, "scale_downs": 0}
        for _ in range(f.n_replicas):
            self._spawn(reason="bootstrap")

    # -- membership ----------------------------------------------------------

    def _build_server(self, step: int, params) -> SolServer:
        """The respawn step function (``run_with_restart``): params in,
        audited replica server out.  A bring-up failure restores params
        from the fleet checkpoint and retries; the autotune cache needs no
        restore — it is process-wide and keyed on the mesh-tagged
        ``Backend.cache_name``, so strict provenance holds without
        re-measuring."""
        m = build_lm(self.cfg)
        m.load_state_dict(params)
        return SolServer(self.cfg, m,
                         strict_provenance=self.strict_provenance)

    def _spawn(self, *, reason: str) -> Replica:
        server, report = run_with_restart(
            self._build_server, self._params, 1, self._ckpt,
            failure_sim=None if reason == "bootstrap" else self.respawn_sim,
            max_restarts=self.fleet_cfg.max_restarts,
            restartable=self._restartable)
        rid = self._next_replica
        self._next_replica += 1
        rep = Replica(id=rid, server=server)
        self.replicas[rid] = rep
        self._event("spawn" if reason == "bootstrap" else "respawn",
                    replica=rid, reason=reason, restarts=report.restarts)
        if reason != "bootstrap":
            self.stats["respawns"] += 1
        return rep

    def _remove(self, rep: Replica, *, event: str, **kw) -> None:
        """Common corpse-handling: monitor id retired (stale EWMA must not
        skew the fleet baseline), server closed, membership dropped."""
        self.monitor.retire(rep.id)
        try:
            rep.server.close()
        except Exception:
            pass                     # a dead replica's queue may be broken
        self.replicas.pop(rep.id, None)
        self._event(event, replica=rep.id, **kw)

    def _on_replica_failure(self, rep: Replica, err: BaseException) -> None:
        """Replica death: re-queue its in-flight requests at the front of
        the router queue (original ``SamplingParams`` seeds → the re-run
        is token-identical), then drop the corpse.  The watcher phase of
        the next tick respawns up to the desired size."""
        self.stats["kills"] += 1
        self._requeue_in_flight(rep)
        self._remove(rep, event="kill", error=type(err).__name__)

    def _requeue_in_flight(self, rep: Replica) -> None:
        live = [f for f in rep.assigned.values() if not f.done]
        for freq in sorted(live, key=lambda f: f.fid, reverse=True):
            freq.handle = None
            freq.replica = None
            freq.requeues += 1
            self.stats["requeued"] += 1
            self.pending.appendleft(freq)
            self._event("requeue", fid=freq.fid, from_replica=rep.id)
        rep.assigned.clear()

    def kill(self, replica_id: Optional[int] = None, *,
             error: Optional[BaseException] = None) -> int:
        """Fault injection: kill one replica (default: the busiest) as if
        its mesh step had raised.  Used by the ``--fleet`` smoke and the
        benchmark's injected-kill replay."""
        if replica_id is not None:
            rep = self.replicas.get(replica_id)
        else:
            rep = max(self.replicas.values(),
                      key=lambda r: (r.server.depth, -r.id), default=None)
        if rep is None:
            raise ValueError(f"no replica to kill (id={replica_id})")
        rid = rep.id
        self._on_replica_failure(rep, error
                                 or ReplicaFailure("injected kill"))
        return rid

    # -- router --------------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               sampling: Optional[SamplingParams] = None) -> FleetRequest:
        prompt = validate_prompt(self.cfg, prompt)
        freq = FleetRequest(fid=self._next_fid, prompt=prompt,
                            max_new_tokens=max(1, int(max_new_tokens)),
                            sampling=sampling or SamplingParams(),
                            submitted=time.perf_counter())
        self._next_fid += 1
        self._requests.append(freq)
        self.pending.append(freq)
        return freq

    def _routable(self) -> List[Replica]:
        return [r for r in self.replicas.values() if r.state == "up"]

    def router_score(self, rep: Replica) -> float:
        """Lower is better: normalized queue depth plus the replica's
        TTFT-EWMA excess over the fastest replica's — a straggler that the
        monitor has not yet flagged already gets organically less
        traffic."""
        depth = rep.server.depth / max(1, self.cfg.slots)
        ewmas = [r.ttft_ewma for r in self._routable() if r.ttft_ewma > 0]
        base = min(ewmas) if ewmas else 0.0
        ttft = (rep.ttft_ewma / base - 1.0) \
            if base > 0 and rep.ttft_ewma > 0 else 0.0
        return depth + ttft

    def _route(self) -> None:
        while self.pending:
            cands = [r for r in self._routable()
                     if r.server.depth < self.cfg.slots]
            if not cands:
                return               # saturated: backlog = admission pressure
            rep = min(cands, key=lambda r: (self.router_score(r), r.id))
            freq = self.pending.popleft()
            freq.handle = rep.server.submit(freq.prompt,
                                            freq.max_new_tokens,
                                            sampling=freq.sampling)
            freq.replica = rep.id
            rep.assigned[freq.fid] = freq

    def _harvest(self, rep: Replica) -> None:
        f = self.fleet_cfg
        for fid in list(rep.assigned):
            freq = rep.assigned[fid]
            h = freq.handle
            if (freq.first_token_time is None
                    and h.first_token_time is not None):
                freq.first_token_time = h.first_token_time
                # replica-LOCAL ttft (replica submit → first token) is the
                # router's speed signal, unpolluted by fleet queueing
                local = h.first_token_time - h.submitted
                rep.ttft_ewma = local if rep.ttft_ewma == 0 else \
                    (1 - f.ttft_alpha) * rep.ttft_ewma + f.ttft_alpha * local
            if h.done:
                freq.generated = list(h.generated)
                freq.finished_time = h.finished_time
                rep.served += 1
                del rep.assigned[fid]

    # -- the watcher tick ----------------------------------------------------

    def tick(self) -> List[int]:
        """One watcher tick: route → step every replica (its step clock
        feeds the straggler monitor; a restartable exception is replica
        death) → harvest → membership policy (drain/evict/respawn) →
        autoscale.  Returns the ids of replicas that served work."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._tick += 1
        self.stats["ticks"] += 1
        self._route()
        f = self.fleet_cfg
        times: Dict[int, float] = {}
        stepped: List[int] = []
        for rep in list(self.replicas.values()):
            t0 = time.perf_counter()
            try:
                if self.failure_sim is not None:
                    # a tick scheduled in the simulator kills the first
                    # replica whose step scope checks it (ids ascend)
                    self.failure_sim.check(self._tick)
                served = rep.server.step()
            except Exception as e:
                if not self._restartable(e):
                    raise
                self._on_replica_failure(rep, e)
                continue
            if served:
                dt = time.perf_counter() - t0
                if self._step_time_fn is not None:
                    dt = self._step_time_fn(rep, dt)
                rep.serving_steps += 1
                stepped.append(rep.id)
                if rep.serving_steps > f.join_grace:
                    # spike clip vs the FLEET baseline: no single sample
                    # may record above clip × fleet-normal, so a one-off
                    # compile/GC spike cannot trip an evict (one clamped
                    # sample moves the EWMA to at most 1 + α(clip-1) ×
                    # baseline, under the rebalance threshold) while a
                    # genuine straggler's EWMA still converges to its
                    # clamped ratio and crosses ``evict_threshold``.
                    base = self.monitor.baseline()
                    if f.spike_clip > 0 and base > 0:
                        dt = min(dt, f.spike_clip * base)
                    times[rep.id] = dt
            self._harvest(rep)
        if times:
            # idle replicas contribute no sample: a drained replica's ~0s
            # no-op step must not make busy replicas look like stragglers
            self.monitor.record_step(times)
        self._apply_watcher_policy()
        self._autoscale()
        self._t_last = time.perf_counter()
        return stepped

    def _apply_watcher_policy(self) -> None:
        f = self.fleet_cfg
        flags = self.monitor.flagged()
        for rep in list(self.replicas.values()):
            verdict = flags.get(rep.id)
            if rep.state == "up" and verdict in ("rebalance", "evict"):
                rep.state = "draining"
                rep.drain_reason = verdict
                rep.drained_at = self._tick
                self.stats["drained"] += 1
                self._event("drain", replica=rep.id, verdict=verdict)
            elif (rep.state == "draining"
                    and rep.drain_reason == "rebalance"):
                if verdict == "evict":
                    rep.drain_reason = "evict"   # escalate mid-drain
                    self._event("drain", replica=rep.id, verdict="evict")
                elif self._tick - rep.drained_at >= f.drain_cooldown:
                    # second chance: rejoin under FRESH monitor stats
                    # (retire + auto-register) — if it is still slow it
                    # will be re-flagged after warmup_steps samples
                    self.monitor.retire(rep.id)
                    rep.state, rep.drain_reason = "up", ""
                    self.stats["rejoined"] += 1
                    self._event("rejoin", replica=rep.id)
            if rep.state == "draining" and rep.drain_reason == "evict":
                drained = rep.server.depth == 0
                if drained or self._tick - rep.drained_at >= f.drain_grace:
                    if not drained:      # grace expired: re-queue the rest
                        self._requeue_in_flight(rep)
                    self.stats["evicted"] += 1
                    self._remove(rep, event="evict", drained=drained)
            elif rep.state == "retiring" and rep.server.depth == 0:
                self._remove(rep, event="retire")
        # converge membership toward the desired size (replaces dead and
        # evicted replicas; retiring ones no longer count)
        while len([r for r in self.replicas.values()
                   if r.state != "retiring"]) < self._desired:
            self._spawn(reason="replace")

    def _autoscale(self) -> None:
        """Admission-pressure policy: the fleet queue is what requests
        wait in when every routable replica is slot-saturated, so its
        sustained depth is the scale-up signal; a sustained empty queue
        with spare slot capacity scales down."""
        f = self.fleet_cfg
        live = self._routable()
        capacity = max(1, len(live)) * self.cfg.slots
        backlog = len(self.pending)
        in_flight = sum(r.server.depth for r in live)
        if backlog > f.scale_up_backlog * capacity:
            self._pressure_up += 1
            self._pressure_down = 0
        elif (backlog == 0 and len(live) > 1
                and in_flight <= (len(live) - 1) * self.cfg.slots // 2):
            self._pressure_down += 1
            self._pressure_up = 0
        else:
            self._pressure_up = self._pressure_down = 0
        if (self._pressure_up >= f.scale_up_ticks
                and self._desired < f.max_replicas):
            self._desired += 1
            self._pressure_up = 0
            self.stats["scale_ups"] += 1
            self._event("scale_up", desired=self._desired)
            self._spawn(reason="autoscale")
        if (self._pressure_down >= f.scale_down_ticks
                and self._desired > f.min_replicas and live):
            self._desired -= 1
            self._pressure_down = 0
            self.stats["scale_downs"] += 1
            victim = min(live, key=lambda r: (r.server.depth, -r.id))
            victim.state = "retiring"
            self._event("scale_down", replica=victim.id,
                        desired=self._desired)

    # -- driving -------------------------------------------------------------

    def run(self, max_ticks: int = 100_000) -> Dict[str, Any]:
        """Tick until every submitted request has completed."""
        start = self._tick
        while self.pending or any(r.assigned
                                  for r in self.replicas.values()):
            if self._tick - start >= max_ticks:
                raise RuntimeError(f"fleet exceeded {max_ticks} ticks with "
                                   f"requests still in flight")
            self.tick()
        return self.summary()

    def close(self) -> None:
        for rep in list(self.replicas.values()):
            try:
                rep.server.close()
            except Exception:
                pass
        self.replicas.clear()

    def warm_autotune(self, max_len: Optional[int] = None, *,
                      warmup: int = 1, iters: int = 3) -> Dict[str, int]:
        """Warm the election cache for every bucket the fleet workload can
        produce.  Measurements land in the process-wide autotune cache
        keyed on the (mesh-tagged) ``Backend.cache_name``, so ONE warming
        covers every replica — including any respawned later onto the same
        mesh shape, which is why respawn never re-measures."""
        if max_len is None:
            live = [fr for fr in self._requests if not fr.done]
            if not live:
                raise ValueError("no requests to derive the bucket space "
                                 "from; pass max_len explicitly")
            max_len = max(min(self.cfg.max_seq,
                              len(fr.prompt) + fr.max_new_tokens)
                          for fr in live)
        rep = next(iter(self.replicas.values()), None)
        if rep is None:
            raise RuntimeError("fleet has no replicas to warm through")
        return rep.server.warm_autotune(max_len, warmup=warmup,
                                        iters=iters)

    # -- reporting -----------------------------------------------------------

    def _event(self, kind: str, **kw) -> None:
        self.events.append({"t": time.perf_counter(), "tick": self._tick,
                            "event": kind, **kw})

    def recovery_times(self) -> List[float]:
        """Seconds from each kill/evict to the respawn that replaced it
        (event-log pairing, in order)."""
        out = []
        deaths: Deque[float] = deque()
        for ev in self.events:
            if ev["event"] in ("kill", "evict"):
                deaths.append(ev["t"])
            elif ev["event"] == "respawn" and deaths:
                out.append(ev["t"] - deaths.popleft())
        return out

    def summary(self) -> Dict[str, Any]:
        done = [fr for fr in self._requests if fr.done]
        lat = [1e3 * (fr.finished_time - fr.submitted) for fr in done
               if fr.finished_time is not None]
        ttft = [1e3 * (fr.first_token_time - fr.submitted) for fr in done
                if fr.first_token_time is not None]
        tokens = sum(len(fr.generated) for fr in done)
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None
                else 0.0)

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        recov = self.recovery_times()
        return {
            "replicas": len(self.replicas),
            "desired": self._desired,
            "requests": len(done),
            "in_flight": len(self._requests) - len(done),
            "tokens": tokens,
            "tokens_per_s": tokens / wall if wall else 0.0,
            "ticks": self.stats["ticks"],
            "latency_ms": {"p50": pct(lat, 50), "p99": pct(lat, 99)},
            "ttft_ms": {"p50": pct(ttft, 50), "p99": pct(ttft, 99)},
            "requeued": self.stats["requeued"],
            "kills": self.stats["kills"],
            "evicted": self.stats["evicted"],
            "respawns": self.stats["respawns"],
            "drained": self.stats["drained"],
            "rejoined": self.stats["rejoined"],
            "scale_ups": self.stats["scale_ups"],
            "scale_downs": self.stats["scale_downs"],
            "recovery_s": {"max": max(recov) if recov else 0.0,
                           "events": len(recov)},
            "served_by": {r.id: r.served
                          for r in self.replicas.values()},
        }
