"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports FLOPs/bytes/collectives for scanned-layer models by the trip
count (≈ n_layers).  This module re-derives the three roofline terms from
the HLO text with loop multiplicity:

  * computations are parsed into blocks; while ops give (condition, body)
    edges; trip counts are read from the loop-condition's compare constant;
  * multipliers propagate ENTRY → callees (while body/cond ×trips, call /
    conditional ×1, fusion/reduce-apply ×1 for flops but excluded from the
    traffic model — fusion internals live in registers/VMEM);
  * dot FLOPs = 2 · |result| · |contracting dims| (from operand shapes);
  * HBM traffic = Σ over traffic ops (result + distinct operand bytes), the
    same convention XLA's HloCostAnalysis uses;
  * collective ICI bytes use ring models on the replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

# computation headers start at column 0: "%name (params...) -> type {"
_COMP_START = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{\s*$")
_OP_HEAD = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DIMS_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([\d,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([\d,]*)\}"),
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(t: str) -> int:
    total = 0
    for m in _ARRAY_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_array(t: str) -> Tuple[Optional[str], List[int]]:
    m = _ARRAY_RE.search(t)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = dataclasses.field(default_factory=list)
    types: Dict[str, str] = dataclasses.field(default_factory=dict)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_HEAD.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        # split operands from attrs at the paren matching "opcode("
        start = m.end()            # index just past the '('
        depth = 1
        i = start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operand_str = line[start:i - 1]
        attrs = line[i:]
        operands = _OPERAND_RE.findall(operand_str)
        op = Op(name, type_str.strip(), opcode, operands, attrs, line)
        cur.ops.append(op)
        cur.types[name] = op.type_str
    return comps


def _callee_edges(op: Op) -> List[Tuple[str, str]]:
    """(kind, computation-name) edges from an op."""
    edges = []
    for kw, kind in (("body=", "while_body"), ("condition=", "while_cond"),
                     ("calls=", "fusion"), ("to_apply=", "apply")):
        for m in re.finditer(re.escape(kw) + r"\{?%?([\w\.\-]+)", op.attrs):
            edges.append((kind, m.group(1)))
    if op.opcode == "conditional":
        for m in re.finditer(r"branch_computations=\{([^}]*)\}", op.attrs):
            for n in _OPERAND_RE.findall(m.group(1)):
                edges.append(("call", n))
        for m in re.finditer(r"(?:true|false)_computation=%?([\w\.\-]+)",
                             op.attrs):
            edges.append(("call", m.group(1)))
    return edges


_TRIP_RE = re.compile(r"known_trip_count\\?\":\{\\?\"n\\?\":\\?\"(\d+)")


def _op_trip_count(op: Op, comps: Dict[str, "Computation"]) -> int:
    """Trip count of a while op: XLA records it in backend_config
    (known_trip_count); fall back to the condition's compare constant."""
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    cond = next((c for k, c in _callee_edges(op) if k == "while_cond"), None)
    if cond in comps:
        best = 1
        for cop in comps[cond].ops:
            for mm in _CONST_RE.finditer(cop.line):
                best = max(best, int(mm.group(1)))
        return best
    return 1


def compute_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {n: 0.0 for n in comps}
    kind_of: Dict[str, str] = {n: "top" for n in comps}
    if entry is None:
        return {n: 1.0 for n in comps}
    mult[entry] = 1.0
    # topological-ish propagation: iterate to fixpoint (call graph is a DAG)
    for _ in range(64):
        changed = False
        new = dict(mult)
        for n in comps:
            new[n] = 1.0 if n == entry else 0.0
        for n, comp in comps.items():
            m = mult.get(n, 0.0)
            if m <= 0:
                continue
            for op in comp.ops:
                for kind, callee in _callee_edges(op):
                    if callee not in comps:
                        continue
                    k = m
                    if kind in ("while_body", "while_cond"):
                        trips = _op_trip_count(op, comps)
                        k = m * max(trips, 1)
                        kind_of[callee] = "loop"
                    elif kind == "fusion":
                        kind_of[callee] = "fusion"
                    elif kind == "apply":
                        kind_of[callee] = "apply"
                    else:
                        kind_of.setdefault(callee, "call")
                    new[callee] = new.get(callee, 0.0) + k
        if new != mult:
            mult = new
            changed = True
        if not changed:
            break
    mult["__kinds__"] = kind_of  # type: ignore
    return mult


def dot_flops(op: Op, types: Dict[str, str]) -> float:
    _, rdims = _first_array(op.type_str)
    out = 1.0
    for d in rdims:
        out *= d
    lhs = op.operands[0] if op.operands else None
    lhs_t = types.get(lhs, "")
    _, ldims = _first_array(lhs_t)
    cm = _DIMS_RE["lhs_c"].search(op.attrs)
    contract = 1.0
    if cm and ldims:
        for i in cm.group(1).split(","):
            if i and int(i) < len(ldims):
                contract *= ldims[int(i)]
    return 2.0 * out * contract


def conv_flops(op: Op, types: Dict[str, str]) -> float:
    """2 · |out| · Cin/g · prod(kernel spatial) — approximate via rhs shape."""
    _, rdims = _first_array(op.type_str)
    out = 1.0
    for d in rdims:
        out *= d
    rhs_t = types.get(op.operands[1], "") if len(op.operands) > 1 else ""
    _, kdims = _first_array(rhs_t)
    k = 1.0
    for d in kdims[:-1]:   # all but output-feature dim (approximation)
        k *= d
    return 2.0 * out * k


# per-element FLOP weights for elementwise HLO opcodes (transcendentals
# modelled at polynomial-approximation cost); pure data movement (copy,
# convert, broadcast, ...) and everything unlisted count zero
_EW_FLOP_WEIGHTS = {
    "add": 1, "subtract": 1, "multiply": 1, "maximum": 1, "minimum": 1,
    "abs": 1, "negate": 1, "compare": 1, "select": 1, "and": 1, "or": 1,
    "xor": 1, "not": 1, "sign": 1, "floor": 1, "ceil": 1, "clamp": 2,
    "round-nearest-afz": 1, "round-nearest-even": 1,
    "divide": 4, "remainder": 4, "sqrt": 4, "rsqrt": 4, "cbrt": 8,
    "exponential": 8, "exponential-minus-one": 8, "log": 8,
    "log-plus-one": 8, "tanh": 8, "logistic": 8, "sine": 8, "cosine": 8,
    "atan2": 12, "power": 10, "erf": 10,
}


def elementwise_profile(text: str) -> Tuple[float, float]:
    """Whole-module elementwise work: ``(ew_flops, ew_elements)`` summed with
    loop multiplicity, *including* fusion bodies (where XLA puts almost every
    elementwise op).  The ratio is the element-weighted mean FLOPs per
    elementwise element — the measured replacement for the DFP cost model's
    nominal per-element constant (``core.passes.calibrate_ew_flops``).

    ``analyze`` folds the same accounting into its single pass
    (``ew_flops``/``ew_elements`` in its result) — prefer those fields when
    you already pay for an ``analyze`` call."""
    res = analyze(text, 1)
    return res["ew_flops"], res["ew_elements"]


def collective_traffic(op: Op, n_devices: int) -> Tuple[str, float, float]:
    kind = op.opcode.replace("-start", "")
    size = _type_bytes(op.type_str)
    if op.opcode.endswith("-start") and op.type_str.startswith("("):
        size /= 2.0          # start tuples carry (operand, result) buffers
    g = n_devices
    gm = _GROUPS_RE.search(op.attrs)
    if gm:
        g = len(gm.group(1).split(","))
    else:
        im = _IOTA_RE.search(op.attrs)
        if im:
            g = int(im.group(2))
    g = max(g, 1)
    if kind == "all-gather":
        t = size * (g - 1) / g
    elif kind == "all-reduce":
        t = 2.0 * size * (g - 1) / g
    elif kind == "reduce-scatter":
        t = size * (g - 1)
    elif kind == "all-to-all":
        t = size * (g - 1) / g
    else:
        t = float(size)
    return kind, float(size), t


_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "call", "conditional", "after-all",
               "iota", "partition-id", "replica-id"}


def _op_traffic(op: Op, comp: Computation, comps: Dict[str, Computation]
                ) -> float:
    """HBM bytes for one top-level op, slice-aware:
      * dynamic-slice reads only the slice (result bytes ×2: read+write);
      * dynamic-update-slice writes only the update (update bytes ×2);
      * fusions are inspected: params consumed solely by dynamic-slice count
        as slice bytes; a dynamic-update-slice root counts as update bytes
        (XLA aliases the buffer in-place inside loop bodies);
      * everything else: result + distinct operand bytes (XLA's own
        HloCostAnalysis convention)."""
    if op.opcode == "dynamic-slice":
        return 2.0 * _type_bytes(op.type_str)
    if op.opcode == "dynamic-update-slice":
        upd = comp.types.get(op.operands[1], "") if len(op.operands) > 1 else ""
        return 2.0 * _type_bytes(upd)
    if op.opcode == "fusion":
        callee = next((c for k, c in _callee_edges(op) if k == "fusion"),
                      None)
        if callee in comps:
            return _fusion_traffic(op, comp, comps[callee])
    b = float(_type_bytes(op.type_str))
    for o in set(op.operands):
        b += _type_bytes(comp.types.get(o, ""))
    return b


_PURE_CONVERT_OPS = {"parameter", "convert", "bitcast", "reshape",
                     "constant", "broadcast"}


def _fusion_traffic(op: Op, comp: Computation, fused: Computation) -> float:
    non_param = [f for f in fused.ops if f.opcode != "parameter"]
    # pure dtype-convert fusions are CPU-backend artifacts (XLA:CPU upcasts
    # bf16 dots to f32); a TPU MXU program reads bf16 directly — skip them.
    # The converted value is still charged where it is consumed (dot operand).
    if non_param and all(f.opcode in _PURE_CONVERT_OPS for f in non_param):
        return 0.0
    # convert-of-slice fusions: charge the slice read only (on TPU the
    # consumer dot reads the weight slice directly, no materialized convert)
    if non_param and all(f.opcode in _PURE_CONVERT_OPS
                         or f.opcode == "dynamic-slice" for f in non_param):
        return float(sum(_type_bytes(f.type_str) for f in non_param
                         if f.opcode == "dynamic-slice"))

    defs: Dict[str, Op] = {f.name: f for f in fused.ops}
    uses: Dict[str, List[Op]] = {}
    for fop in fused.ops:
        for o in fop.operands:
            uses.setdefault(o, []).append(fop)

    PURE = {"convert", "bitcast", "reshape", "copy", "transpose"}

    def terminals(name: str) -> List[Tuple[Op, str]]:
        """Non-pure consumers reachable through pure unary chains, as
        (consumer, operand-name-at-consumption)."""
        out: List[Tuple[Op, str]] = []
        frontier = [name]
        seen = set()
        while frontier:
            n = frontier.pop()
            if n in seen:
                continue
            seen.add(n)
            for u in uses.get(n, []):
                if u.opcode in PURE:
                    frontier.append(u.name)
                else:
                    out.append((u, n))
        return out

    dus_ops = [f for f in fused.ops if f.opcode == "dynamic-update-slice"]

    total = 0.0
    for fop in fused.ops:
        if fop.opcode != "parameter":
            continue
        terms = terminals(fop.name)
        if terms and all(
                (t.opcode == "dynamic-slice" and t.operands
                 and t.operands[0] == via)
                or (t.opcode == "dynamic-update-slice" and t.operands
                    and t.operands[0] == via)
                for t, via in terms):
            # consumed only as slice reads / in-place DUS bases
            total += sum(_type_bytes(t.type_str) for t, _ in terms
                         if t.opcode == "dynamic-slice")
        else:
            total += _type_bytes(fop.type_str)

    # result side: a DUS (possibly wrapped in converts) writes only the slice
    if dus_ops:
        for d in dus_ops:
            if len(d.operands) > 1:
                upd = d.operands[1]
                total += _type_bytes(
                    defs[upd].type_str if upd in defs else
                    fused.types.get(upd, ""))
    else:
        total += _type_bytes(op.type_str)
    return total


def analyze(text: str, n_devices: int) -> Dict[str, object]:
    comps = parse_module(text)
    mult = compute_multipliers(comps)
    kinds = mult.pop("__kinds__", {})  # type: ignore

    flops = 0.0
    traffic = 0.0
    ici = 0.0
    ew_flops = 0.0
    ew_elements = 0.0
    coll: Dict[str, Dict[str, float]] = {}
    loops: List[Dict[str, object]] = []

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        is_fusion = kinds.get(name) in ("fusion", "apply")
        for op in comp.ops:
            if op.opcode == "dot":
                flops += m * dot_flops(op, comp.types)
            elif op.opcode == "convolution":
                flops += m * conv_flops(op, comp.types)
            ew_w = _EW_FLOP_WEIGHTS.get(op.opcode)
            if ew_w is not None:        # counted inside fusion bodies too
                _, dims = _first_array(op.type_str)
                n_elem = 1.0
                for d in dims:
                    n_elem *= d
                ew_flops += m * n_elem * ew_w
                ew_elements += m * n_elem
            if is_fusion:
                continue
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                kind, size, t = collective_traffic(op, n_devices)
                d = coll.setdefault(kind, {"count": 0, "bytes": 0.0,
                                           "ici_bytes": 0.0})
                d["count"] += m
                d["bytes"] += m * size
                d["ici_bytes"] += m * t
                ici += m * t
                continue
            if op.opcode in _NO_TRAFFIC or op.opcode.endswith("-done"):
                continue
            traffic += m * _op_traffic(op, comp, comps)
        for op in comp.ops:
            if op.opcode == "while":
                loops.append({"in": name,
                              "trips": _op_trip_count(op, comps)})

    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": traffic,
        "ici_bytes_per_device": ici,
        "ew_flops": ew_flops,
        "ew_elements": ew_elements,
        "collectives": coll,
        "loops": loops,
        "n_computations": len(comps),
    }
