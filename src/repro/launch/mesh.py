"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is the
DCN-ish outer axis (gradient all-reduce crosses it; everything
bandwidth-hungry stays inside a pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run via "
            f"launch/dryrun.py which forces XLA_FLAGS host device count")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny (data, model) mesh for tests and debug serving.  Validates the
    device count like :func:`make_production_mesh` — a short slice would
    otherwise hand back a silently smaller mesh and every divisibility
    decision downstream would be made against the wrong axis sizes."""
    need = data * model
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {(data, model)} needs {need} devices, have {len(devs)} — "
            f"run via launch/dryrun.py which forces XLA_FLAGS host device "
            f"count")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devs[:need])
