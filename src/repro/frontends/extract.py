"""Graph extraction: framework modules → SOL IR (paper Sec. III-A,
'extracts the computation graph from the framework').

Extraction is driven by an **emitter registry**, mirroring how kernels
register implementations in the backend dispatch table: one emitter per
framework module type, looked up by exact type then MRO, so new layer kinds
plug into the middleware without touching this file's core walk (the 2022
follow-up paper's maintenance-overhead point).  An emitter receives the
module, the current IR node(s) and an :class:`EmitContext` and returns the
module's output node — containers (`Sequential`, `Residual`) recurse, so
transformer and recurrent blocks extract as genuine multi-input graphs, not
linear chains.

Parameters are registered under their framework dotted names so the SolModel
keeps sharing the framework's parameter storage (paper Listing 2:
'param_0 = ... managed by framework').
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple, Type

import numpy as np

from ..core import ir
from ..core.ir import Graph, Node, OpKind, TensorSpec
from . import nn


class UnsupportedModuleError(TypeError):
    """Raised when no emitter is registered for a module type.  Names the
    offending module's path in the tree and the registered emitters, so the
    fix (``@register_emitter(MyModule)``) is one error message away."""


# ---------------------------------------------------------------------------
# the emitter registry
# ---------------------------------------------------------------------------

# fn(module, ctx, x: Node, path: str) -> Node  (path is the dotted prefix of
# the module in the tree, '' for the root, used for parameter names)
EmitterFn = Callable[[nn.Module, "EmitContext", Node, str], Node]

_EMITTERS: Dict[Type[nn.Module], EmitterFn] = {}

# decode-mode overrides: modules whose forward emitter is sequence-dependent
# but which know how to emit a single-token step against a cache input
# (MultiHeadAttention → DECODE_ATTENTION).  Looked up before _EMITTERS when
# ctx.mode == 'decode'.
_DECODE_EMITTERS: Dict[Type[nn.Module], EmitterFn] = {}

# modules whose forward emitter mixes information across sequence positions
# (scans, token shifts): without a decode emitter they CANNOT be served
# incrementally — a per-token re-emit would silently drop history, so decode
# extraction refuses them loudly instead.
_SEQUENCE_MODULES: set = set()


def register_emitter(*module_types: Type[nn.Module]
                     ) -> Callable[[EmitterFn], EmitterFn]:
    """Register an extraction emitter for one or more module types — the
    frontend analogue of ``backends.registry.register_impl``.  Subclasses
    inherit an emitter through the MRO unless they register their own."""
    def deco(fn: EmitterFn) -> EmitterFn:
        for t in module_types:
            _EMITTERS[t] = fn
        return fn
    return deco


def register_decode_emitter(*module_types: Type[nn.Module]
                            ) -> Callable[[EmitterFn], EmitterFn]:
    """Register a single-token decode emitter: ``x`` is the (B, 1, D) step
    input, and the emitter may create per-layer cache inputs via
    ``ctx.kv_input`` and record this step's cache rows via
    ``ctx.kv_outputs``.  Implies the module is sequence-dependent."""
    def deco(fn: EmitterFn) -> EmitterFn:
        for t in module_types:
            _DECODE_EMITTERS[t] = fn
            _SEQUENCE_MODULES.add(t)
        return fn
    return deco


def mark_sequence_module(*module_types: Type[nn.Module]) -> None:
    """Declare module types position-*dependent* without providing a decode
    emitter: decode extraction will refuse them instead of silently reusing
    the (wrong for one-token steps) forward emitter.  Position-wise modules
    (Linear, LayerNorm, activations, containers) need no declaration."""
    _SEQUENCE_MODULES.update(module_types)


def registered_emitters() -> List[str]:
    """Names of all module types with an emitter (the supported-module set)."""
    return sorted(t.__name__ for t in _EMITTERS)


def _emitter_for(m: nn.Module) -> EmitterFn | None:
    for t in type(m).__mro__:
        if t in _EMITTERS:
            return _EMITTERS[t]
    return None


class EmitContext:
    """Per-extraction state: the parameter table plus node builders shared by
    every emitter.

    ``mode`` selects how sequence layers extract:

    * ``'forward'`` — the training/offline graph (PR 1-4 behaviour);
    * ``'prefill'`` — same compute, but attention layers additionally record
      their per-layer (k, v) projections in ``kv_outputs`` so the server can
      seed each request's KV-cache slot from the prompt forward;
    * ``'decode'``  — single-token step: attention layers read a cache input
      (created via :meth:`kv_input`, ragged lengths in :attr:`lens`) and emit
      ``DECODE_ATTENTION``; sequence-dependent modules without a decode
      emitter are refused.
    """

    def __init__(self, dtype: str = "float32", mode: str = "forward",
                 max_seq: int = 0):
        self.dtype = dtype
        self.mode = mode
        self.max_seq = max_seq
        self.params: Dict[str, Node] = {}
        self.kv_inputs: List[Node] = []    # decode: per-layer cache inputs
        self.kv_outputs: List[Node] = []   # per-layer (k, v) rows, in layer
                                           # order, aligned with kv_inputs
        self.lens: Node | None = None      # decode: (B,) int32 cache lengths

    def emit(self, m: nn.Module, x: Node, path: str = "") -> Node:
        if self.mode == "decode":
            for t in type(m).__mro__:
                if t in _DECODE_EMITTERS:
                    return _DECODE_EMITTERS[t](m, self, x, path)
            if any(t in _SEQUENCE_MODULES for t in type(m).__mro__):
                raise UnsupportedModuleError(
                    f"{type(m).__name__} at "
                    f"{path.rstrip('.') or '<root>'} mixes information "
                    f"across sequence positions and has no decode emitter: "
                    f"its forward emitter would silently drop history in a "
                    f"single-token step.  Add one with frontends.extract."
                    f"register_decode_emitter({type(m).__name__}), or serve "
                    f"this model with decode=False (full re-forward).")
        fn = _emitter_for(m)
        if fn is None:
            raise UnsupportedModuleError(
                f"no emitter registered for {type(m).__name__} at "
                f"{path.rstrip('.') or '<root>'} in the module tree; "
                f"registered emitters: {', '.join(registered_emitters())}. "
                f"Add one with frontends.extract."
                f"register_emitter({type(m).__name__}).")
        return fn(m, self, x, path)

    def kv_input(self, shape: Tuple[int, ...], name: str) -> Node:
        """A decode-mode cache input (one per cached tensor per layer); the
        server binds it to the rows gathered from the request's SlotArena
        slot, zero-padded up to the cache bucket."""
        n = ir.input_node(shape, self.dtype, name=name)
        self.kv_inputs.append(n)
        return n

    def param(self, name: str, arr) -> Node:
        if name in self.params:        # same framework storage → same node
            return self.params[name]
        n = ir.param_node(tuple(arr.shape), self.dtype, name=name)
        self.params[name] = n
        return n

    def const(self, shape: Tuple[int, ...], fill: float = 0.0,
              dtype: str | None = None) -> Node:
        return ir.const_node(shape, fill, dtype or self.dtype)

    def matmul(self, x: Node, w: Node) -> Node:
        """x @ w with w in (in, out) layout — the sequence layers' io
        projections."""
        shape = x.spec.shape[:-1] + (w.spec.shape[-1],)
        return Node(OpKind.MATMUL, [x, w], TensorSpec(shape, self.dtype))

    def reshape(self, x: Node, shape: Tuple[int, ...]) -> Node:
        return Node(OpKind.RESHAPE, [x], TensorSpec(tuple(shape), self.dtype),
                    attrs={"shape": tuple(shape)})

    def unary(self, op: OpKind, x: Node, **attrs) -> Node:
        return Node(op, [x], TensorSpec(x.spec.shape, self.dtype),
                    attrs=attrs)

    def binary(self, op: OpKind, a: Node, b: Node) -> Node:
        shape = np.broadcast_shapes(a.spec.shape, b.spec.shape)
        return Node(op, [a, b], TensorSpec(tuple(shape), self.dtype))


# ---------------------------------------------------------------------------
# container emitters
# ---------------------------------------------------------------------------

@register_emitter(nn.Sequential)
def _emit_sequential(m: nn.Sequential, ctx: EmitContext, x: Node,
                     path: str) -> Node:
    cur = x
    for idx, child in enumerate(m):
        cur = ctx.emit(child, cur, f"{path}{idx}.")
    return cur


@register_emitter(nn.Residual)
def _emit_residual(m: nn.Residual, ctx: EmitContext, x: Node,
                   path: str) -> Node:
    # the multi-input node: skip + transformed branch
    return ctx.binary(OpKind.ADD, x, _emit_sequential(m, ctx, x, path))


# ---------------------------------------------------------------------------
# layer emitters (the paper's CNN/MLP scope)
# ---------------------------------------------------------------------------

def _out_shape_conv(x: Tuple[int, ...], m: nn.Conv2d) -> Tuple[int, ...]:
    a = m.attrs
    h = (x[2] + 2 * a["padding"] - a["kernel"]) // a["stride"] + 1
    w = (x[3] + 2 * a["padding"] - a["kernel"]) // a["stride"] + 1
    return (x[0], a["out_ch"], h, w)


def _out_shape_pool(x: Tuple[int, ...], k: int, s: int) -> Tuple[int, ...]:
    return (x[0], x[1], (x[2] - k) // s + 1, (x[3] - k) // s + 1)


@register_emitter(nn.Linear)
def _emit_linear(m: nn.Linear, ctx: EmitContext, x: Node, path: str) -> Node:
    w = ctx.param(path + "weight", m._params["weight"])
    shape = x.spec.shape[:-1] + (m.out_features,)
    cur = Node(OpKind.LINEAR, [x, w], TensorSpec(shape, ctx.dtype),
               attrs={"out_features": m.out_features})
    if m.has_bias:
        b = ctx.param(path + "bias", m._params["bias"])
        cur = Node(OpKind.BIAS_ADD, [cur, b], TensorSpec(shape, ctx.dtype),
                   attrs={"axis": -1})
    return cur


@register_emitter(nn.Conv2d)
def _emit_conv2d(m: nn.Conv2d, ctx: EmitContext, x: Node, path: str) -> Node:
    w = ctx.param(path + "weight", m._params["weight"])
    shape = _out_shape_conv(x.spec.shape, m)
    cur = Node(OpKind.CONV2D, [x, w], TensorSpec(shape, ctx.dtype),
               attrs={"stride": m.attrs["stride"],
                      "padding": m.attrs["padding"],
                      "groups": m.attrs["groups"],
                      "out_channels": m.attrs["out_ch"]})
    if m.has_bias:
        b = ctx.param(path + "bias", m._params["bias"])
        cur = Node(OpKind.BIAS_ADD, [cur, b], TensorSpec(shape, ctx.dtype),
                   attrs={"axis": 1})
    return cur


@register_emitter(nn.ReLU)
def _emit_relu(m, ctx, x, path):
    return ctx.unary(OpKind.RELU, x)


@register_emitter(nn.GELU)
def _emit_gelu(m, ctx, x, path):
    return ctx.unary(OpKind.GELU, x)


@register_emitter(nn.MaxPool2d)
def _emit_maxpool(m: nn.MaxPool2d, ctx, x, path):
    shape = _out_shape_pool(x.spec.shape, m.kernel, m.stride)
    return Node(OpKind.MAXPOOL, [x], TensorSpec(shape, ctx.dtype),
                attrs={"kernel": m.kernel, "stride": m.stride})


@register_emitter(nn.AvgPool2d)
def _emit_avgpool(m: nn.AvgPool2d, ctx, x, path):
    shape = _out_shape_pool(x.spec.shape, m.kernel, m.stride)
    return Node(OpKind.AVGPOOL, [x], TensorSpec(shape, ctx.dtype),
                attrs={"kernel": m.kernel, "stride": m.stride})


@register_emitter(nn.GlobalAvgPool)
def _emit_globalpool(m, ctx, x, path):
    return Node(OpKind.GLOBALPOOL, [x],
                TensorSpec(x.spec.shape[:2], ctx.dtype))


@register_emitter(nn.Flatten)
def _emit_flatten(m, ctx, x, path):
    flat = 1
    for s in x.spec.shape[1:]:
        flat *= s
    return Node(OpKind.FLATTEN, [x],
                TensorSpec((x.spec.shape[0], flat), ctx.dtype))


@register_emitter(nn.LayerNorm)
def _emit_layernorm(m: nn.LayerNorm, ctx, x, path):
    g = ctx.param(path + "weight", m._params["weight"])
    b = ctx.param(path + "bias", m._params["bias"])
    return Node(OpKind.LAYERNORM, [x, g, b],
                TensorSpec(x.spec.shape, ctx.dtype))


@register_emitter(nn.BatchNorm2d)
def _emit_batchnorm(m: nn.BatchNorm2d, ctx, x, path):
    ps = [ctx.param(path + n, m._params[n]) for n in
          ("weight", "bias", "running_mean", "running_var")]
    return Node(OpKind.BATCHNORM, [x] + ps, TensorSpec(x.spec.shape,
                                                       ctx.dtype))


@register_emitter(nn.Dropout)
def _emit_dropout(m: nn.Dropout, ctx, x, path):
    return ctx.unary(OpKind.DROPOUT, x, p=m.p)


# ---------------------------------------------------------------------------
# sequence-layer emitters: ATTENTION / RGLRU_SCAN / RWKV6_SCAN
# ---------------------------------------------------------------------------

@register_emitter(nn.MultiHeadAttention)
def _emit_attention(m: nn.MultiHeadAttention, ctx: EmitContext, x: Node,
                    path: str) -> Node:
    b, s, _ = x.spec.shape
    hd = m.head_dim
    q = ctx.reshape(ctx.matmul(x, ctx.param(path + "wq", m._params["wq"])),
                    (b, s, m.n_heads, hd))
    k = ctx.reshape(ctx.matmul(x, ctx.param(path + "wk", m._params["wk"])),
                    (b, s, m.n_kv_heads, hd))
    v = ctx.reshape(ctx.matmul(x, ctx.param(path + "wv", m._params["wv"])),
                    (b, s, m.n_kv_heads, hd))
    att = Node(OpKind.ATTENTION, [q, k, v],
               TensorSpec((b, s, m.n_heads, hd), ctx.dtype),
               attrs={"causal": m.causal, "window": m.window, "cap": m.cap})
    if ctx.mode == "prefill":       # expose this layer's cache rows so the
        ctx.kv_outputs += [k, v]    # server can seed the request's KV slot
    o = ctx.reshape(att, (b, s, m.n_heads * hd))
    return ctx.matmul(o, ctx.param(path + "wo", m._params["wo"]))


@register_decode_emitter(nn.MultiHeadAttention)
def _emit_attention_decode(m: nn.MultiHeadAttention, ctx: EmitContext,
                           x: Node, path: str) -> Node:
    """Single-token step: project q/k/v for the one new position, attend the
    query against this layer's cache input plus the new (k, v) pair via
    DECODE_ATTENTION, and record the pair in ``kv_outputs`` so the server
    appends it to the slot's cache at position ``lens[b]``."""
    if not m.causal:
        raise UnsupportedModuleError(
            f"MultiHeadAttention at {path.rstrip('.') or '<root>'} is "
            f"non-causal: a bidirectional layer cannot be decoded "
            f"incrementally; serve with decode=False.")
    b, s, _ = x.spec.shape
    if s != 1:
        raise ValueError(f"decode extraction expects a single-token step, "
                         f"got sequence length {s}")
    hd = m.head_dim
    q = ctx.reshape(ctx.matmul(x, ctx.param(path + "wq", m._params["wq"])),
                    (b, 1, m.n_heads, hd))
    k_new = ctx.reshape(
        ctx.matmul(x, ctx.param(path + "wk", m._params["wk"])),
        (b, 1, m.n_kv_heads, hd))
    v_new = ctx.reshape(
        ctx.matmul(x, ctx.param(path + "wv", m._params["wv"])),
        (b, 1, m.n_kv_heads, hd))
    cshape = (b, ctx.max_seq, m.n_kv_heads, hd)
    k_cache = ctx.kv_input(cshape, name=f"{path}k_cache")
    v_cache = ctx.kv_input(cshape, name=f"{path}v_cache")
    att = Node(OpKind.DECODE_ATTENTION,
               [q, k_cache, v_cache, k_new, v_new, ctx.lens],
               TensorSpec((b, 1, m.n_heads, hd), ctx.dtype),
               attrs={"window": m.window, "cap": m.cap})
    ctx.kv_outputs += [k_new, v_new]
    o = ctx.reshape(att, (b, 1, m.n_heads * hd))
    return ctx.matmul(o, ctx.param(path + "wo", m._params["wo"]))


@register_emitter(nn.RGLRU)
def _emit_rglru(m: nn.RGLRU, ctx: EmitContext, x: Node, path: str) -> Node:
    """models.recurrent.rglru_gates + the RGLRU_SCAN kernel node:
    a = exp(-c·softplus(λ)·sigmoid(x·wa)); b = √(1-a²)·sigmoid(x·wx)·x."""
    from ..models.recurrent import RGLRU_C
    bsz, s, d = x.spec.shape
    wa = ctx.param(path + "wa", m._params["wa"])
    wx = ctx.param(path + "wx", m._params["wx"])
    lam = ctx.param(path + "lam", m._params["lam"])
    r = ctx.unary(OpKind.SIGMOID, ctx.matmul(x, wa))
    i = ctx.unary(OpKind.SIGMOID, ctx.matmul(x, wx))
    decay = ctx.unary(OpKind.SCALE, ctx.unary(OpKind.SOFTPLUS, lam),
                      value=-RGLRU_C)
    a = ctx.unary(OpKind.EXP, ctx.binary(OpKind.MUL, r, decay))
    one_minus_a2 = ctx.binary(OpKind.SUB, ctx.const((1,), 1.0),
                              ctx.binary(OpKind.MUL, a, a))
    gate = ctx.unary(OpKind.SQRT, one_minus_a2, min=1e-12)
    bb = ctx.binary(OpKind.MUL, ctx.binary(OpKind.MUL, gate, i), x)
    h0 = ctx.const((bsz, d), 0.0)
    return Node(OpKind.RGLRU_SCAN, [a, bb, h0],
                TensorSpec((bsz, s, d), ctx.dtype))


@register_emitter(nn.RWKV6TimeMix)
def _emit_rwkv6(m: nn.RWKV6TimeMix, ctx: EmitContext, x: Node,
                path: str) -> Node:
    """models.recurrent.rwkv_time_mix_seq as a graph: token-shift lerp with
    per-target LoRA mixes → r/k/v/decay projections → RWKV6_SCAN → per-head
    groupnorm → silu gate → output projection."""
    bsz, s, d = x.spec.shape
    h, hd = m.n_heads, d // m.n_heads
    P = lambda name: ctx.param(path + name, m._params[name])

    xs = ctx.unary(OpKind.TIME_SHIFT, x)
    dx = ctx.binary(OpKind.SUB, xs, x)
    xm = ctx.binary(OpKind.ADD, x,
                    ctx.binary(OpKind.MUL, dx, P("mu_x")))

    def lora(src: Node, t: str) -> Node:
        inner = ctx.unary(OpKind.TANH, ctx.matmul(src, P(f"lora_a_{t}")))
        return ctx.matmul(inner, P(f"lora_b_{t}"))

    def mixed(t: str) -> Node:
        mix = ctx.binary(OpKind.ADD, P(f"mu_{t}"), lora(xm, t))
        return ctx.binary(OpKind.ADD, x, ctx.binary(OpKind.MUL, dx, mix))

    r = ctx.reshape(ctx.matmul(mixed("r"), P("wr")), (bsz, s, h, hd))
    k = ctx.reshape(ctx.matmul(mixed("k"), P("wk")), (bsz, s, h, hd))
    v = ctx.reshape(ctx.matmul(mixed("v"), P("wv")), (bsz, s, h, hd))
    g = ctx.unary(OpKind.SILU, ctx.matmul(mixed("g"), P("wg")))
    # decay: logw = -exp(w0 + lora_w(m_w)) ≤ 0
    wsum = ctx.binary(OpKind.ADD, P("w0"), lora(mixed("w"), "w"))
    logw = ctx.reshape(ctx.unary(OpKind.SCALE, ctx.unary(OpKind.EXP, wsum),
                                 value=-1.0), (bsz, s, h, hd))
    u = ctx.reshape(P("u"), (h, hd))
    s0 = ctx.const((bsz, h, hd, hd), 0.0)
    o = Node(OpKind.RWKV6_SCAN, [r, k, v, logw, u, s0],
             TensorSpec((bsz, s, h, hd), ctx.dtype))
    # per-head groupnorm == layernorm over the trailing head dim
    gn = Node(OpKind.LAYERNORM, [o, ctx.const((hd,), 1.0),
                                 ctx.const((hd,), 0.0)],
              TensorSpec((bsz, s, h, hd), ctx.dtype), attrs={"eps": 64e-5})
    flat = ctx.reshape(gn, (bsz, s, d))
    scaled = ctx.binary(OpKind.ADD,
                        ctx.binary(OpKind.MUL, flat, P("gn_gain")),
                        P("gn_bias"))
    return ctx.matmul(ctx.binary(OpKind.MUL, scaled, g), P("wo"))


# the recurrent layers have no decode emitter (their state would need its
# own arena region); declaring them sequence-dependent makes decode
# extraction refuse them loudly instead of emitting a history-free step.
mark_sequence_module(nn.RGLRU, nn.RWKV6TimeMix)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def extract(model: nn.Module, input_shape: Tuple[int, ...],
            dtype: str = "float32") -> Graph:
    rank = len(input_shape)
    dims = {4: ir.NCHW(), 3: ir.BSD(), 2: ir.NF()}.get(rank, ())
    x = ir.input_node(input_shape, dtype, dims, name="input")
    ctx = EmitContext(dtype)
    out = ctx.emit(model, x, "")
    g = Graph(inputs=[x], outputs=[out], params=ctx.params)
    g.validate()
    return g


def extract_prefill(model: nn.Module, input_shape: Tuple[int, ...],
                    dtype: str = "float32") -> Graph:
    """The serving prefill program: identical compute to :func:`extract`,
    but every attention layer's (k, v) projections join the graph outputs —
    ``outputs = [logits, k_0, v_0, k_1, v_1, ...]`` in layer order — so one
    prompt forward both produces next-token logits and seeds the request's
    KV-cache slot."""
    x = ir.input_node(input_shape, dtype, ir.BSD(), name="input")
    ctx = EmitContext(dtype, mode="prefill")
    out = ctx.emit(model, x, "")
    g = Graph(inputs=[x], outputs=[out] + ctx.kv_outputs, params=ctx.params)
    g.validate()
    return g


def extract_decode(model: nn.Module, batch: int, max_seq: int,
                   d_model: int, dtype: str = "float32") -> Graph:
    """The serving decode program: one token per resident sequence.

    ``inputs  = [x (B, 1, D), lens (B,) int32, k_cache_0, v_cache_0, ...]``
    ``outputs = [logits (B, 1, V), k_new_0, v_new_0, ...]``

    Cache inputs are (B, max_seq, KV, hd) with rows ``[0, lens[b])`` valid;
    the new (k, v) outputs are the rows the server appends at position
    ``lens[b]`` after the step.  Sequence-dependent modules without a decode
    emitter raise :class:`UnsupportedModuleError`."""
    x = ir.input_node((batch, 1, d_model), dtype, ir.BSD(), name="step")
    lens = ir.input_node((batch,), "int32", name="lens")
    ctx = EmitContext(dtype, mode="decode", max_seq=max_seq)
    ctx.lens = lens
    out = ctx.emit(model, x, "")
    g = Graph(inputs=[x, lens] + ctx.kv_inputs,
              outputs=[out] + ctx.kv_outputs, params=ctx.params)
    g.validate()
    return g
