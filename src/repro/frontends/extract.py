"""Graph extraction: framework modules → SOL IR (paper Sec. III-A,
'extracts the computation graph from the framework').

Walks the module tree structurally (the torch.jit-trace analogue) and emits
one IR node per layer, with parameters registered under their framework
dotted names so the SolModel can keep sharing the framework's parameter
storage (paper Listing 2: 'param_0 = ... managed by framework')."""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..core import ir
from ..core.ir import Graph, Node, OpKind, TensorSpec
from . import nn


def _out_shape_conv(x: Tuple[int, ...], m: nn.Conv2d) -> Tuple[int, ...]:
    a = m.attrs
    h = (x[2] + 2 * a["padding"] - a["kernel"]) // a["stride"] + 1
    w = (x[3] + 2 * a["padding"] - a["kernel"]) // a["stride"] + 1
    return (x[0], a["out_ch"], h, w)


def _out_shape_pool(x: Tuple[int, ...], k: int, s: int) -> Tuple[int, ...]:
    return (x[0], x[1], (x[2] - k) // s + 1, (x[3] - k) // s + 1)


def extract(model: nn.Sequential, input_shape: Tuple[int, ...],
            dtype: str = "float32") -> Graph:
    if not isinstance(model, nn.Sequential):
        raise TypeError("extraction currently covers Sequential models "
                        "(the paper's CNN/MLP scope)")
    dims = ir.NCHW() if len(input_shape) == 4 else ir.NF()
    x = ir.input_node(input_shape, dtype, dims, name="input")
    params: Dict[str, Node] = {}
    cur = x
    shape = tuple(input_shape)

    def param(name: str, arr) -> Node:
        n = ir.param_node(tuple(arr.shape), dtype, name=name)
        params[name] = n
        return n

    for idx, m in enumerate(model):
        pfx = f"{idx}."
        if isinstance(m, nn.Linear):
            w = param(pfx + "weight", m._params["weight"])
            ins = [cur, w]
            shape = shape[:-1] + (m.out_features,)
            cur = Node(OpKind.LINEAR, ins, TensorSpec(shape, dtype),
                       attrs={"out_features": m.out_features})
            if m.has_bias:
                b = param(pfx + "bias", m._params["bias"])
                cur = Node(OpKind.BIAS_ADD, [cur, b],
                           TensorSpec(shape, dtype), attrs={"axis": -1})
        elif isinstance(m, nn.Conv2d):
            w = param(pfx + "weight", m._params["weight"])
            shape = _out_shape_conv(shape, m)
            cur = Node(OpKind.CONV2D, [cur, w], TensorSpec(shape, dtype),
                       attrs={"stride": m.attrs["stride"],
                              "padding": m.attrs["padding"],
                              "groups": m.attrs["groups"],
                              "out_channels": m.attrs["out_ch"]})
            if m.has_bias:
                b = param(pfx + "bias", m._params["bias"])
                cur = Node(OpKind.BIAS_ADD, [cur, b],
                           TensorSpec(shape, dtype), attrs={"axis": 1})
        elif isinstance(m, nn.ReLU):
            cur = Node(OpKind.RELU, [cur], TensorSpec(shape, dtype))
        elif isinstance(m, nn.GELU):
            cur = Node(OpKind.GELU, [cur], TensorSpec(shape, dtype))
        elif isinstance(m, nn.MaxPool2d):
            shape = _out_shape_pool(shape, m.kernel, m.stride)
            cur = Node(OpKind.MAXPOOL, [cur], TensorSpec(shape, dtype),
                       attrs={"kernel": m.kernel, "stride": m.stride})
        elif isinstance(m, nn.AvgPool2d):
            shape = _out_shape_pool(shape, m.kernel, m.stride)
            cur = Node(OpKind.AVGPOOL, [cur], TensorSpec(shape, dtype),
                       attrs={"kernel": m.kernel, "stride": m.stride})
        elif isinstance(m, nn.GlobalAvgPool):
            shape = shape[:2]
            cur = Node(OpKind.GLOBALPOOL, [cur], TensorSpec(shape, dtype))
        elif isinstance(m, nn.Flatten):
            flat = 1
            for s in shape[1:]:
                flat *= s
            shape = (shape[0], flat)
            cur = Node(OpKind.FLATTEN, [cur], TensorSpec(shape, dtype))
        elif isinstance(m, nn.LayerNorm):
            g = param(pfx + "weight", m._params["weight"])
            b = param(pfx + "bias", m._params["bias"])
            cur = Node(OpKind.LAYERNORM, [cur, g, b],
                       TensorSpec(shape, dtype))
        elif isinstance(m, nn.BatchNorm2d):
            ps = [param(pfx + n, m._params[n]) for n in
                  ("weight", "bias", "running_mean", "running_var")]
            cur = Node(OpKind.BATCHNORM, [cur] + ps, TensorSpec(shape, dtype))
        elif isinstance(m, nn.Dropout):
            cur = Node(OpKind.DROPOUT, [cur], TensorSpec(shape, dtype),
                       attrs={"p": m.p})
        elif isinstance(m, nn.Sequential):
            raise TypeError("nested Sequential: flatten before extraction")
        else:
            raise TypeError(f"unsupported layer for extraction: {type(m)}")
    g = Graph(inputs=[x], outputs=[cur], params=params)
    g.validate()
    return g
