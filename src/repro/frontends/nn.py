"""The 'AI framework' stand-in (paper's PyTorch role).

A deliberately torch-like eager module system: each layer executes as its
own jit-compiled call (op-at-a-time dispatch — the same execution model
that makes eager PyTorch leave fusion opportunities on the table).  SOL
extracts the graph from these modules (extract.py), optimizes it, and
injects a SolModel back (optimize.py) — without touching this file: the
framework's source code never changes, which is the paper's whole point.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class Module:
    """Eager module: owns parameters (host-visible), executes op-by-op."""

    def __init__(self):
        self._params: Dict[str, Array] = {}
        self._children: Dict[str, "Module"] = {}
        self.training = False
        self._version = 0           # bumped on parameter mutation

    # -- parameter plumbing ---------------------------------------------------
    def register(self, name: str, value: Array) -> None:
        self._params[name] = value

    def add_module(self, name: str, mod: "Module") -> None:
        self._children[name] = mod

    def named_parameters(self, prefix: str = "") -> Dict[str, Array]:
        out = {prefix + k: v for k, v in self._params.items()}
        for n, c in self._children.items():
            out.update(c.named_parameters(prefix + n + "."))
        return out

    def load_state_dict(self, sd: Dict[str, Array]) -> None:
        for k, v in sd.items():
            self._set_param(k, v)
        self.bump_version()

    def state_dict(self) -> Dict[str, Array]:
        return self.named_parameters()

    def _set_param(self, dotted: str, value: Array) -> None:
        parts = dotted.split(".")
        mod: Module = self
        for p in parts[:-1]:
            mod = mod._children[p]
        mod._params[parts[-1]] = value

    def bump_version(self) -> None:
        self._version += 1
        for c in self._children.values():
            c.bump_version()

    @property
    def version(self) -> int:
        return self._version

    def train(self, mode: bool = True) -> "Module":
        self.training = mode
        for c in self._children.values():
            c.train(mode)
        return self

    def eval(self) -> "Module":
        return self.train(False)

    def __call__(self, x: Array) -> Array:
        return self.forward(x)

    def forward(self, x: Array) -> Array:    # pragma: no cover - abstract
        raise NotImplementedError


def _kaiming(key, shape, fan_in):
    return jax.random.normal(key, shape) * math.sqrt(2.0 / fan_in)


_key_counter = [0]


def _next_key():
    _key_counter[0] += 1
    return jax.random.PRNGKey(_key_counter[0])


class Linear(Module):
    def __init__(self, in_features: int, out_features: int,
                 bias: bool = True):
        super().__init__()
        self.in_features = in_features
        self.out_features = out_features
        # framework-native layout: (out, in) — torch convention; SOL's
        # layout pass may elect (in, out) per backend
        self.register("weight", _kaiming(_next_key(),
                                         (out_features, in_features),
                                         in_features))
        self.has_bias = bias
        if bias:
            self.register("bias", jnp.zeros((out_features,)))

    def forward(self, x: Array) -> Array:
        y = _eager_linear(x, self._params["weight"])
        if self.has_bias:
            y = _eager_add_vec(y, self._params["bias"])
        return y


class Conv2d(Module):
    def __init__(self, in_ch: int, out_ch: int, kernel: int, stride: int = 1,
                 padding: int = 0, groups: int = 1, bias: bool = True):
        super().__init__()
        self.attrs = dict(in_ch=in_ch, out_ch=out_ch, kernel=kernel,
                          stride=stride, padding=padding, groups=groups)
        fan_in = in_ch // groups * kernel * kernel
        self.register("weight", _kaiming(
            _next_key(), (out_ch, in_ch // groups, kernel, kernel), fan_in))
        self.has_bias = bias
        if bias:
            self.register("bias", jnp.zeros((out_ch,)))

    def forward(self, x: Array) -> Array:
        a = self.attrs
        y = _eager_conv(x, self._params["weight"], a["stride"],
                        a["padding"], a["groups"])
        if self.has_bias:
            y = _eager_add_chan(y, self._params["bias"])
        return y


class ReLU(Module):
    def forward(self, x: Array) -> Array:
        return _eager_relu(x)


class GELU(Module):
    def forward(self, x: Array) -> Array:
        return _eager_gelu(x)


class MaxPool2d(Module):
    def __init__(self, kernel: int, stride: Optional[int] = None):
        super().__init__()
        self.kernel = kernel
        self.stride = stride or kernel

    def forward(self, x: Array) -> Array:
        return _eager_maxpool(x, self.kernel, self.stride)


class AvgPool2d(Module):
    def __init__(self, kernel: int, stride: Optional[int] = None):
        super().__init__()
        self.kernel = kernel
        self.stride = stride or kernel

    def forward(self, x: Array) -> Array:
        return _eager_avgpool(x, self.kernel, self.stride)


class GlobalAvgPool(Module):
    def forward(self, x: Array) -> Array:
        return _eager_globalpool(x)


class Flatten(Module):
    def forward(self, x: Array) -> Array:
        return x.reshape(x.shape[0], -1)


class LayerNorm(Module):
    def __init__(self, dim: int):
        super().__init__()
        self.dim = dim
        self.register("weight", jnp.ones((dim,)))
        self.register("bias", jnp.zeros((dim,)))

    def forward(self, x: Array) -> Array:
        return _eager_layernorm(x, self._params["weight"],
                                self._params["bias"])


class BatchNorm2d(Module):
    def __init__(self, ch: int):
        super().__init__()
        self.ch = ch
        self.register("weight", jnp.ones((ch,)))
        self.register("bias", jnp.zeros((ch,)))
        self.register("running_mean", jnp.zeros((ch,)))
        self.register("running_var", jnp.ones((ch,)))

    def forward(self, x: Array) -> Array:
        p = self._params
        return _eager_batchnorm(x, p["weight"], p["bias"],
                                p["running_mean"], p["running_var"])


class Dropout(Module):
    def __init__(self, p: float = 0.1):
        super().__init__()
        self.p = p

    def forward(self, x: Array) -> Array:
        return x                     # inference identity (eager reference)


class Sequential(Module):
    def __init__(self, *mods: Module):
        super().__init__()
        self.mods = list(mods)
        for i, m in enumerate(mods):
            self.add_module(str(i), m)

    def forward(self, x: Array) -> Array:
        for m in self.mods:
            x = m(x)
        return x

    def __iter__(self):
        return iter(self.mods)


# -- eager op-at-a-time kernels (each a separate jit = dispatch per layer) ----

@jax.jit
def _eager_linear(x, w):
    return x @ w.T


@jax.jit
def _eager_add_vec(x, b):
    return x + b


@jax.jit
def _eager_add_chan(x, b):
    return x + b[None, :, None, None]


@functools.partial(jax.jit, static_argnames=("stride", "padding", "groups"))
def _eager_conv(x, w, stride, padding, groups):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), ((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups)


@jax.jit
def _eager_relu(x):
    return jnp.maximum(x, 0.0)


@jax.jit
def _eager_gelu(x):
    return jax.nn.gelu(x)


@functools.partial(jax.jit, static_argnames=("k", "s"))
def _eager_maxpool(x, k, s):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, k, k), (1, 1, s, s), "VALID")


@functools.partial(jax.jit, static_argnames=("k", "s"))
def _eager_avgpool(x, k, s):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add,
                                 (1, 1, k, k), (1, 1, s, s), "VALID") / (k * k)


@jax.jit
def _eager_globalpool(x):
    return x.mean(axis=(2, 3))


@jax.jit
def _eager_layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


@jax.jit
def _eager_batchnorm(x, g, b, m, v):
    inv = jax.lax.rsqrt(v + 1e-5) * g
    return (x - m[None, :, None, None]) * inv[None, :, None, None] \
        + b[None, :, None, None]


# -- model zoo (paper benchmarks: MLP + CNNs) ---------------------------------

def mlp_8192(n_layers: int = 3, features: int = 8192,
             in_features: int = 8192, classes: int = 1000) -> Sequential:
    """The paper's MLP: 3 layers, 8192 features, ReLU."""
    mods: List[Module] = []
    d = in_features
    for _ in range(n_layers - 1):
        mods += [Linear(d, features), ReLU()]
        d = features
    mods.append(Linear(d, classes))
    return Sequential(*mods)


def small_cnn(in_ch: int = 3, classes: int = 10) -> Sequential:
    """VGG-flavoured small CNN (conv-relu-pool blocks → MLP head)."""
    return Sequential(
        Conv2d(in_ch, 32, 3, padding=1), ReLU(), MaxPool2d(2),
        Conv2d(32, 64, 3, padding=1), ReLU(), MaxPool2d(2),
        Conv2d(64, 128, 3, padding=1), BatchNorm2d(128), ReLU(),
        GlobalAvgPool(), Flatten(),
        Linear(128, 256), ReLU(), Dropout(0.1),
        Linear(256, classes),
    )


def depthwise_cnn(in_ch: int = 3, classes: int = 10) -> Sequential:
    """MobileNet-flavoured: depthwise convs (groups == channels) — the
    paper's special case that routes to the DFP module as WeightedPooling."""
    return Sequential(
        Conv2d(in_ch, 32, 3, padding=1), ReLU(),
        Conv2d(32, 32, 3, padding=1, groups=32, bias=False),   # depthwise
        Conv2d(32, 64, 1), ReLU(), MaxPool2d(2),
        Conv2d(64, 64, 3, padding=1, groups=64, bias=False),   # depthwise
        Conv2d(64, 128, 1), ReLU(),
        GlobalAvgPool(), Flatten(), Linear(128, classes),
    )
