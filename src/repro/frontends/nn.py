"""The 'AI framework' stand-in (paper's PyTorch role).

A deliberately torch-like eager module system: each layer executes as its
own jit-compiled call (op-at-a-time dispatch — the same execution model
that makes eager PyTorch leave fusion opportunities on the table).  SOL
extracts the graph from these modules (extract.py), optimizes it, and
injects a SolModel back (optimize.py) — without touching this file: the
framework's source code never changes, which is the paper's whole point.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class Module:
    """Eager module: owns parameters (host-visible), executes op-by-op."""

    def __init__(self):
        self._params: Dict[str, Array] = {}
        self._children: Dict[str, "Module"] = {}
        self.training = False
        self._version = 0           # bumped on parameter mutation

    # -- parameter plumbing ---------------------------------------------------
    def register(self, name: str, value: Array) -> None:
        self._params[name] = value

    def add_module(self, name: str, mod: "Module") -> None:
        self._children[name] = mod

    def named_parameters(self, prefix: str = "") -> Dict[str, Array]:
        out = {prefix + k: v for k, v in self._params.items()}
        for n, c in self._children.items():
            out.update(c.named_parameters(prefix + n + "."))
        return out

    def load_state_dict(self, sd: Dict[str, Array]) -> None:
        for k, v in sd.items():
            self._set_param(k, v)
        self.bump_version()

    def state_dict(self) -> Dict[str, Array]:
        return self.named_parameters()

    def _set_param(self, dotted: str, value: Array) -> None:
        parts = dotted.split(".")
        mod: Module = self
        for p in parts[:-1]:
            mod = mod._children[p]
        mod._params[parts[-1]] = value

    def bump_version(self) -> None:
        self._version += 1
        for c in self._children.values():
            c.bump_version()

    @property
    def version(self) -> int:
        return self._version

    def train(self, mode: bool = True) -> "Module":
        self.training = mode
        for c in self._children.values():
            c.train(mode)
        return self

    def eval(self) -> "Module":
        return self.train(False)

    def __call__(self, *xs: Array) -> Array:
        # variadic pass-through: SolModel's serving programs (prefill/decode)
        # take multiple inputs; plain layers keep their single-x forward
        return self.forward(*xs)

    def forward(self, x: Array) -> Array:    # pragma: no cover - abstract
        raise NotImplementedError


def _kaiming(key, shape, fan_in):
    return jax.random.normal(key, shape) * math.sqrt(2.0 / fan_in)


_key_counter = [0]


def _next_key():
    _key_counter[0] += 1
    return jax.random.PRNGKey(_key_counter[0])


class Linear(Module):
    def __init__(self, in_features: int, out_features: int,
                 bias: bool = True):
        super().__init__()
        self.in_features = in_features
        self.out_features = out_features
        # framework-native layout: (out, in) — torch convention; SOL's
        # layout pass may elect (in, out) per backend
        self.register("weight", _kaiming(_next_key(),
                                         (out_features, in_features),
                                         in_features))
        self.has_bias = bias
        if bias:
            self.register("bias", jnp.zeros((out_features,)))

    def forward(self, x: Array) -> Array:
        y = _eager_linear(x, self._params["weight"])
        if self.has_bias:
            y = _eager_add_vec(y, self._params["bias"])
        return y


class Conv2d(Module):
    def __init__(self, in_ch: int, out_ch: int, kernel: int, stride: int = 1,
                 padding: int = 0, groups: int = 1, bias: bool = True):
        super().__init__()
        self.attrs = dict(in_ch=in_ch, out_ch=out_ch, kernel=kernel,
                          stride=stride, padding=padding, groups=groups)
        fan_in = in_ch // groups * kernel * kernel
        self.register("weight", _kaiming(
            _next_key(), (out_ch, in_ch // groups, kernel, kernel), fan_in))
        self.has_bias = bias
        if bias:
            self.register("bias", jnp.zeros((out_ch,)))

    def forward(self, x: Array) -> Array:
        a = self.attrs
        y = _eager_conv(x, self._params["weight"], a["stride"],
                        a["padding"], a["groups"])
        if self.has_bias:
            y = _eager_add_chan(y, self._params["bias"])
        return y


class ReLU(Module):
    def forward(self, x: Array) -> Array:
        return _eager_relu(x)


class GELU(Module):
    def forward(self, x: Array) -> Array:
        return _eager_gelu(x)


class MaxPool2d(Module):
    def __init__(self, kernel: int, stride: Optional[int] = None):
        super().__init__()
        self.kernel = kernel
        self.stride = stride or kernel

    def forward(self, x: Array) -> Array:
        return _eager_maxpool(x, self.kernel, self.stride)


class AvgPool2d(Module):
    def __init__(self, kernel: int, stride: Optional[int] = None):
        super().__init__()
        self.kernel = kernel
        self.stride = stride or kernel

    def forward(self, x: Array) -> Array:
        return _eager_avgpool(x, self.kernel, self.stride)


class GlobalAvgPool(Module):
    def forward(self, x: Array) -> Array:
        return _eager_globalpool(x)


class Flatten(Module):
    def forward(self, x: Array) -> Array:
        return x.reshape(x.shape[0], -1)


class LayerNorm(Module):
    def __init__(self, dim: int):
        super().__init__()
        self.dim = dim
        self.register("weight", jnp.ones((dim,)))
        self.register("bias", jnp.zeros((dim,)))

    def forward(self, x: Array) -> Array:
        return _eager_layernorm(x, self._params["weight"],
                                self._params["bias"])


class BatchNorm2d(Module):
    def __init__(self, ch: int):
        super().__init__()
        self.ch = ch
        self.register("weight", jnp.ones((ch,)))
        self.register("bias", jnp.zeros((ch,)))
        self.register("running_mean", jnp.zeros((ch,)))
        self.register("running_var", jnp.ones((ch,)))

    def forward(self, x: Array) -> Array:
        p = self._params
        return _eager_batchnorm(x, p["weight"], p["bias"],
                                p["running_mean"], p["running_var"])


class Dropout(Module):
    def __init__(self, p: float = 0.1):
        super().__init__()
        self.p = p

    def forward(self, x: Array) -> Array:
        return x                     # inference identity (eager reference)


class Sequential(Module):
    def __init__(self, *mods: Module):
        super().__init__()
        self.mods = list(mods)
        for i, m in enumerate(mods):
            self.add_module(str(i), m)

    def forward(self, x: Array) -> Array:
        for m in self.mods:
            x = m(x)
        return x

    def __iter__(self):
        return iter(self.mods)


class Residual(Sequential):
    """y = x + chain(x): the multi-input container (transformer / recurrent
    blocks) — extraction emits the inner chain plus an ADD with the skip."""

    def forward(self, x: Array) -> Array:
        y = x
        for m in self.mods:
            y = m(y)
        return x + y


# -- sequence layers (attention + linear recurrences) -------------------------
#
# Eager forwards delegate to the models/ reference functions (flash_mha,
# rglru_seq, rwkv_time_mix_seq); their extraction emitters produce
# ATTENTION / RGLRU_SCAN / RWKV6_SCAN graph nodes so the dispatch table can
# elect the Pallas kernels (see frontends/extract.py).

class MultiHeadAttention(Module):
    """Bias-free multi-head attention with GQA, sliding window and logit
    softcap.  Weights are stored (in, out) — the sequence layers follow the
    io layout so projections extract as MATMUL nodes."""

    def __init__(self, d_model: int, n_heads: int,
                 n_kv_heads: Optional[int] = None, causal: bool = True,
                 window: int = 0, cap: float = 0.0):
        super().__init__()
        if d_model % n_heads:
            raise ValueError(f"d_model {d_model} not divisible by {n_heads}")
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_kv_heads = n_kv_heads or n_heads
        if n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        self.head_dim = d_model // n_heads
        self.causal, self.window, self.cap = causal, window, cap
        hd = self.head_dim
        self.register("wq", _kaiming(_next_key(), (d_model, n_heads * hd),
                                     d_model))
        self.register("wk", _kaiming(_next_key(),
                                     (d_model, self.n_kv_heads * hd), d_model))
        self.register("wv", _kaiming(_next_key(),
                                     (d_model, self.n_kv_heads * hd), d_model))
        self.register("wo", _kaiming(_next_key(), (n_heads * hd, d_model),
                                     n_heads * hd))

    def forward(self, x: Array) -> Array:
        from ..models.flash import flash_mha
        b, s, _ = x.shape
        p = self._params
        hd = self.head_dim
        q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(
            b, s, self.n_heads, hd)
        k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(
            b, s, self.n_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(
            b, s, self.n_kv_heads, hd)
        o = flash_mha(q, k, v, self.causal, self.window, self.cap)
        return jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1), p["wo"])


class RGLRU(Module):
    """Griffin's real-gated linear recurrent unit (the recurrence only):
    h_t = a_t·h_{t-1} + b_t with input/recurrence gates over x: (B,S,D)."""

    def __init__(self, dim: int):
        super().__init__()
        self.dim = dim
        self.register("wa", _kaiming(_next_key(), (dim, dim), dim))
        self.register("wx", _kaiming(_next_key(), (dim, dim), dim))
        # lam init: softplus(lam) ∈ ~(0.3, 1.2) → decay a well inside (0, 1)
        self.register("lam", jax.random.uniform(
            _next_key(), (dim,), minval=0.0, maxval=1.0))

    def forward(self, x: Array) -> Array:
        from ..models.recurrent import rglru_seq
        return rglru_seq(self._params, x)[0]


class RWKV6TimeMix(Module):
    """RWKV6 (Finch) time mix: data-dependent token-shift lerp + LoRA decay
    feeding the WKV linear recurrence, per-head groupnorm, silu gate."""

    def __init__(self, dim: int, n_heads: int, lora_rank: int = 4):
        super().__init__()
        if dim % n_heads:
            raise ValueError(f"dim {dim} not divisible by {n_heads} heads")
        self.dim = dim
        self.n_heads = n_heads
        self.lora_rank = lora_rank
        u01 = lambda shape: jax.random.uniform(_next_key(), shape)
        nrm = lambda shape, s: jax.random.normal(_next_key(), shape) * s
        self.register("mu_x", u01((dim,)))
        for t in ("r", "k", "v", "w", "g"):
            self.register(f"mu_{t}", u01((dim,)))
            self.register(f"lora_a_{t}", nrm((dim, lora_rank), 0.1))
            self.register(f"lora_b_{t}", nrm((lora_rank, dim), 0.1))
        self.register("w0", nrm((dim,), 0.3) - 2.0)   # decay exp(-e^{w0}) ≈ .9
        self.register("u", nrm((dim,), 0.5))
        for t in ("r", "k", "v", "g", "o"):
            self.register(f"w{t}", _kaiming(_next_key(), (dim, dim), dim))
        self.register("gn_gain", 1.0 + nrm((dim,), 0.1))
        self.register("gn_bias", nrm((dim,), 0.1))

    def forward(self, x: Array) -> Array:
        from ..models.recurrent import rwkv_time_mix_seq
        return rwkv_time_mix_seq(self._params, x, self.n_heads)[0]


# -- eager op-at-a-time kernels (each a separate jit = dispatch per layer) ----

@jax.jit
def _eager_linear(x, w):
    return x @ w.T


@jax.jit
def _eager_add_vec(x, b):
    return x + b


@jax.jit
def _eager_add_chan(x, b):
    return x + b[None, :, None, None]


@functools.partial(jax.jit, static_argnames=("stride", "padding", "groups"))
def _eager_conv(x, w, stride, padding, groups):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), ((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups)


@jax.jit
def _eager_relu(x):
    return jnp.maximum(x, 0.0)


@jax.jit
def _eager_gelu(x):
    return jax.nn.gelu(x)


@functools.partial(jax.jit, static_argnames=("k", "s"))
def _eager_maxpool(x, k, s):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, k, k), (1, 1, s, s), "VALID")


@functools.partial(jax.jit, static_argnames=("k", "s"))
def _eager_avgpool(x, k, s):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add,
                                 (1, 1, k, k), (1, 1, s, s), "VALID") / (k * k)


@jax.jit
def _eager_globalpool(x):
    return x.mean(axis=(2, 3))


@jax.jit
def _eager_layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


@jax.jit
def _eager_batchnorm(x, g, b, m, v):
    inv = jax.lax.rsqrt(v + 1e-5) * g
    return (x - m[None, :, None, None]) * inv[None, :, None, None] \
        + b[None, :, None, None]


# -- model zoo (paper benchmarks: MLP + CNNs) ---------------------------------

def mlp_8192(n_layers: int = 3, features: int = 8192,
             in_features: int = 8192, classes: int = 1000) -> Sequential:
    """The paper's MLP: 3 layers, 8192 features, ReLU."""
    mods: List[Module] = []
    d = in_features
    for _ in range(n_layers - 1):
        mods += [Linear(d, features), ReLU()]
        d = features
    mods.append(Linear(d, classes))
    return Sequential(*mods)


def small_cnn(in_ch: int = 3, classes: int = 10) -> Sequential:
    """VGG-flavoured small CNN (conv-relu-pool blocks → MLP head)."""
    return Sequential(
        Conv2d(in_ch, 32, 3, padding=1), ReLU(), MaxPool2d(2),
        Conv2d(32, 64, 3, padding=1), ReLU(), MaxPool2d(2),
        Conv2d(64, 128, 3, padding=1), BatchNorm2d(128), ReLU(),
        GlobalAvgPool(), Flatten(),
        Linear(128, 256), ReLU(), Dropout(0.1),
        Linear(256, classes),
    )


def transformer_block(d_model: int = 64, n_heads: int = 4,
                      n_kv_heads: Optional[int] = None,
                      mlp_mult: int = 4, causal: bool = True) -> Sequential:
    """Pre-norm transformer block: attention + MLP, both residual."""
    return Sequential(
        Residual(LayerNorm(d_model),
                 MultiHeadAttention(d_model, n_heads, n_kv_heads,
                                    causal=causal)),
        Residual(LayerNorm(d_model), Linear(d_model, mlp_mult * d_model),
                 GELU(), Linear(mlp_mult * d_model, d_model)),
    )


def griffin_block(d_model: int = 64, mlp_mult: int = 2) -> Sequential:
    """RecurrentGemma/Griffin-style block: RG-LRU recurrence + MLP."""
    return Sequential(
        Residual(LayerNorm(d_model), RGLRU(d_model)),
        Residual(LayerNorm(d_model), Linear(d_model, mlp_mult * d_model),
                 GELU(), Linear(mlp_mult * d_model, d_model)),
    )


def rwkv6_block(d_model: int = 64, n_heads: int = 4,
                mlp_mult: int = 2) -> Sequential:
    """RWKV6 (Finch) block: time mix + MLP, both residual."""
    return Sequential(
        Residual(LayerNorm(d_model), RWKV6TimeMix(d_model, n_heads)),
        Residual(LayerNorm(d_model), Linear(d_model, mlp_mult * d_model),
                 GELU(), Linear(mlp_mult * d_model, d_model)),
    )


def depthwise_cnn(in_ch: int = 3, classes: int = 10) -> Sequential:
    """MobileNet-flavoured: depthwise convs (groups == channels) — the
    paper's special case that routes to the DFP module as WeightedPooling."""
    return Sequential(
        Conv2d(in_ch, 32, 3, padding=1), ReLU(),
        Conv2d(32, 32, 3, padding=1, groups=32, bias=False),   # depthwise
        Conv2d(32, 64, 1), ReLU(), MaxPool2d(2),
        Conv2d(64, 64, 3, padding=1, groups=64, bias=False),   # depthwise
        Conv2d(64, 128, 1), ReLU(),
        GlobalAvgPool(), Flatten(), Linear(128, classes),
    )
