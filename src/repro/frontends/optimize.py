"""sol.optimize — the paper's user-facing entry point (Listing 1):

    sol_model = sol.optimize(py_model, input_shape)
    sol_model.load_state_dict(py_model.state_dict())
    y = sol_model(x)

The returned SolModel behaves like a framework module (Listing 2): its
parameters stay *framework-managed* (shared storage, version-tracked) while
forward executes SOL's optimized, whole-graph-compiled code.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..backends import Backend, get_backend
from ..core import passes
from ..core.executor import lower_graph
from . import nn
from .extract import extract
from .offload import device as device_api


class SolModel(nn.Module):
    """The custom model SOL injects into the framework (paper Listing 2)."""

    def __init__(self, source: nn.Module, graph, backend, fn, mesh=None):
        super().__init__()
        self._source = source
        self.graph = graph
        self.backend = backend
        self._fn = fn                      # jit'd whole-graph executable
        self.mesh = mesh                   # None = single device
        self._ctx_version = -1
        self._ctx_params: Optional[Dict[str, Any]] = None

    def _params_for_call(self) -> Dict[str, Any]:
        """Offloading context: parameters are cached on the target device and
        re-staged only when the framework-side values change (version bump) —
        the paper's context-caching that limits host↔device memcopies to
        input/output (Sec. V-A).  On a mesh, each parameter is placed with
        the NamedSharding the rule engine assigned it (column/row TP shards
        land directly on their owners; replicated params broadcast once)."""
        v = (self._source.version, device_api.state)
        if self._ctx_params is None or self._ctx_version != v:
            sd = self._source.state_dict()
            if self.mesh is not None:
                from jax.sharding import NamedSharding
                self._ctx_params = {
                    k: jax.device_put(
                        jnp.asarray(sd[k]),
                        NamedSharding(self.mesh, self.graph.param_specs[k]))
                    for k in self.graph.params}
            else:
                self._ctx_params = device_api.stage_params(
                    {k: sd[k] for k in self.graph.params})
            self._ctx_version = v
        return self._ctx_params

    def load_state_dict(self, sd: Dict[str, Any]) -> None:
        self._source.load_state_dict(sd)

    def state_dict(self) -> Dict[str, Any]:
        return self._source.state_dict()

    def forward(self, *xs) -> Any:
        params = self._params_for_call()
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            staged = [jax.device_put(jnp.asarray(x),
                                     NamedSharding(self.mesh, spec))
                      for x, spec in zip(xs, self.graph.input_specs)]
        else:
            staged = [device_api.stage_input(x) for x in xs]
        y = self._fn(params, *staged)
        if isinstance(y, tuple):     # multi-output graphs (serving prefill/
            return tuple(device_api.fetch_output(o) for o in y)  # decode)
        return device_api.fetch_output(y)

    def stats(self) -> Dict[str, int]:
        return self.graph.stats()

    def impl_report(self, by_kind: bool = False,
                    provenance: bool = False,
                    sol: bool = False) -> Any:
        """Elected-implementation report.  Default: a flat histogram
        (impl name → node count).  With ``by_kind=True``: a per-OpKind
        breakdown ``{op value → {impl name → count}}`` showing which flavour
        the election pass chose for each kind of node on this backend.
        With ``provenance=True``: ``{impl name → {"count": n, "sources":
        {"measured"|"calibrated"|"analytical" → n}, "pinned": [cfg, ...]}}``
        — whether each election came from autotune-cache measurements or the
        cost model, plus any tuned kernel configs the measured elections
        pinned on the nodes (``"pinned"`` only appears when non-empty).
        With ``sol=True``: the speed-of-light view (``core.sol``) — one dict
        per elected node, ranked worst gap first, with the roofline
        ``bound_us``, the measured (or calibrated-estimate) ``us``, their
        ``ratio`` (measured ÷ speed-of-light bound; 1.0 = at the hardware
        limit) and the ``confidence``/``source`` provenance tags — how far
        each elected kernel sits from what the hardware allows."""
        if sol:
            from ..core import autotune
            from ..core import sol as sol_mod
            rows = sol_mod.node_rows(self.graph, self.backend,
                                     autotune.get_cache())
            return [r.to_json() for r in sol_mod.rank(rows)]
        if provenance:
            prov = getattr(self.graph, "election_provenance", {})
            pins = getattr(self.graph, "election_pinned", {})
            out = {}
            for name, count in getattr(self.graph, "elections", {}).items():
                entry = {"count": count,
                         "sources": dict(prov.get(name, {}))}
                if pins.get(name):
                    entry["pinned"] = [tuple(c) for c in pins[name]]
                out[name] = entry
            return out
        if by_kind:
            return {op: dict(impls) for op, impls in
                    getattr(self.graph, "elections_by_op", {}).items()}
        return dict(getattr(self.graph, "elections", {}))

    def check_provenance(self,
                         kinds: Tuple[str, ...] = ("linear", "matmul",
                                                   "attention"),
                         require: Tuple[str, ...] = ("measured",)
                         ) -> list:
        """Serving audit: every node of the given OpKinds must have been
        elected from an allowed provenance source (default: autotune-cache
        measurements).  Returns a list of violation strings — empty means
        every dispatch of those kinds runs an impl the measurement data
        actually elected, not a silent roofline fallback."""
        return provenance_violations(self.impl_report(by_kind=True),
                                     self.impl_report(provenance=True),
                                     kinds=kinds, require=require)


def provenance_violations(by_op: Dict[str, Any], prov: Dict[str, Any],
                          kinds: Tuple[str, ...] = ("linear", "matmul",
                                                    "attention"),
                          require: Tuple[str, ...] = ("measured",)) -> list:
    """Shared audit over the two ``impl_report`` views (works for a live
    ``SolModel`` and a ``DeployedModel`` alike): for each elected impl of
    the target OpKinds, every recorded election source must be in
    ``require``.  An impl with no provenance at all is also a violation —
    silence is not evidence."""
    out = []
    for kind in kinds:
        for impl_name in (by_op.get(kind) or {}):
            sources = (prov.get(impl_name) or {}).get("sources", {})
            bad = {s: n for s, n in sources.items()
                   if s not in require and n}
            if not sources:
                out.append(f"{kind}→{impl_name}: no election provenance "
                           f"recorded")
            elif bad:
                out.append(f"{kind}→{impl_name}: elected via {bad}, "
                           f"require {tuple(require)}")
    return out


def optimize(model: nn.Module, input_shape: Tuple[int, ...], *,
             backend: str | Backend = "xla", training: bool = False,
             dtype: str = "float32", mesh=None) -> SolModel:
    """Extract → optimize → codegen → inject.  ≤1 line for the user.

    With ``mesh`` (a ``jax.sharding.Mesh``) the elected graph compiles
    under ``shard_map``: the TP/DP rule engine partitions it first
    (``distributed.sharding.shard_graph``), so the whole pipeline —
    elections, autotune lookups, Tunable pinning — runs on per-shard
    shapes."""
    graph = extract(model, input_shape, dtype)
    return compile_graph(model, graph, backend, training=training, mesh=mesh)


def compile_graph(model: nn.Module, graph, backend: str | Backend = "xla",
                  *, training: bool = False, mesh=None) -> SolModel:
    """Optimize → codegen → inject for a pre-built graph (the serving
    prefill/decode programs come from ``extract_prefill``/``extract_decode``
    rather than the plain ``extract``); the same pipeline and lowering as
    :func:`optimize`.

    Mesh mode partitions the graph BEFORE ``run_pipeline`` and qualifies the
    backend's autotune-cache key (``mesh_backend``), then wraps the lowered
    executable in ``shard_map`` with the specs the rule engine derived —
    row-parallel psums lower inside the mapped function (executor), and
    shard_map's ``out_specs`` express the gathers at the graph edges."""
    bk = backend if isinstance(backend, Backend) else get_backend(backend)
    if mesh is None:
        graph = passes.run_pipeline(graph, bk, training=training)
        raw_fn = lower_graph(graph, bk, differentiable=training)
        return SolModel(model, graph, bk, jax.jit(raw_fn))

    from ..distributed import sharding as shd
    graph = shd.shard_graph(graph, mesh)
    bk = shd.mesh_backend(bk, mesh)
    graph = passes.run_pipeline(graph, bk, training=training)
    raw_fn = lower_graph(graph, bk, differentiable=training)
    out_specs = (graph.output_specs[0] if len(graph.output_specs) == 1
                 else tuple(graph.output_specs))
    sharded = shd.shard_map(
        raw_fn, mesh=mesh,
        in_specs=(dict(graph.param_specs), *graph.input_specs),
        out_specs=out_specs, **shd.SHARD_MAP_NOCHECK)
    return SolModel(model, graph, bk, jax.jit(sharded), mesh=mesh)
