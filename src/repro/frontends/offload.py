"""Offloading strategies (paper Sec. V).

**Transparent offloading**: ``sol.device.set(DEVICE, IDX)`` once; inputs
live on the host; SOL notices the placement mismatch, stages inputs/params
to the target device (packed transfers for many small tensors), runs there,
returns host outputs.  The framework never learns the device exists.
Params are cached in an offloading context (see SolModel) — great for
inference, pays gradient round-trips in training.

**Native offloading**: SOL shares the framework's device memory space —
params are already framework-device buffers; no staging, no copies; the
optimizer update runs device-side.  (The paper's PyTorch-dispatch-table
registration has no JAX analogue — JAX's extension point IS shared buffers
+ donation; see DESIGN.md §2.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from ..runtime import packed as P


@dataclasses.dataclass
class _DeviceState:
    kind: str = "cpu"
    index: int = 0
    mode: str = "native"       # 'native' | 'transparent'

    @property
    def jax_device(self):
        devs = jax.devices()
        return devs[min(self.index, len(devs) - 1)]


class _DeviceAPI:
    """sol.device — the paper's one-call device selection."""

    def __init__(self):
        self.state = _DeviceState()
        self.transfer_stats = {"staged_params": 0, "packed_transfers": 0,
                               "direct_transfers": 0}

    def set(self, kind: str, index: int = 0, *,
            mode: str = "transparent") -> None:
        self.state = _DeviceState(kind, index, mode)

    def stage_params(self, params: Dict[str, Any]) -> Dict[str, Any]:
        dev = self.state.jax_device
        if self.state.mode == "native":
            # native: buffers are already device-resident framework arrays
            return {k: jax.device_put(v, dev) for k, v in params.items()}
        # transparent: pack the many small host tensors into one transfer
        keys = list(params)
        small = [k for k in keys if np.asarray(params[k]).nbytes < 1 << 20]
        big = [k for k in keys if k not in small]
        out: Dict[str, Any] = {}
        if small:
            arrs = P.transfer([np.asarray(params[k]) for k in small], dev)
            out.update(dict(zip(small, arrs)))
            self.transfer_stats["packed_transfers"] += 1
        for k in big:
            out[k] = jax.device_put(np.asarray(params[k]), dev)
            self.transfer_stats["direct_transfers"] += 1
        self.transfer_stats["staged_params"] += len(keys)
        return out

    def stage_input(self, x: Any) -> Any:
        if self.state.mode == "transparent":
            return jax.device_put(np.asarray(x), self.state.jax_device)
        return x

    def fetch_output(self, y: Any) -> Any:
        if self.state.mode == "transparent":
            return np.asarray(jax.device_get(y))
        return y


device = _DeviceAPI()
