"""sol.deploy — deployment mode (paper Sec. III-C): extract the NN into a
framework-free artifact.

JAX analogue: AOT-export the optimized whole-graph executable via
``jax.export`` (StableHLO bytes + a tiny loader) — the artifact depends on
neither the frontend module system nor the SOL compiler, mirroring the
paper's 'minimalistic library without framework or SOL dependencies'.

The artifact is a zip with three members:

* ``graph.stablehlo``   — the serialized exported executable;
* ``params/<i>.npy``    — one ``.npy`` per parameter leaf, in flatten order
  (parameters may be an arbitrarily nested dict pytree, not just a flat
  dict — the manifest records the tree so load reconstructs it exactly);
* ``manifest.json``     — the parameter tree (shapes/dtypes/leaf indices)
  plus the election metadata of the graph that was exported (impl
  histogram, per-OpKind breakdown, provenance, pinned tunable configs), so
  a server running from the artifact can still audit WHICH implementations
  it is serving — ``DeployedModel.impl_report`` mirrors
  ``SolModel.impl_report``.

Loading stages every parameter host→device exactly ONCE, through
``runtime.packed.transfer`` (one packed DMA for the many small leaves);
``__call__`` then reuses the device-resident buffers instead of re-uploading
host arrays per call.
"""
from __future__ import annotations

import io
import json
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import export as jexport

from ..runtime import packed
from .optimize import SolModel

MANIFEST_SCHEMA = 2


def deploy(sol_model: SolModel,
           input_shape: Optional[Tuple[int, ...]] = None,
           dtype=jnp.float32) -> bytes:
    """Serialize (weights + compiled graph + election metadata) into a
    single artifact.  With ``input_shape=None`` the input specs (shapes AND
    dtypes, e.g. the decode program's int32 ``lens``) are derived from the
    graph's input nodes — required for multi-input graphs like the serving
    decode program."""
    if getattr(sol_model, "mesh", None) is not None:
        raise RuntimeError(
            "deploy: mesh-compiled SolModels cannot be exported — the "
            "artifact format stages params onto one device and the graph's "
            "specs are per-shard local shapes; compile with mesh=None for "
            "artifact export, or serve the mesh model live")
    g = sol_model.graph
    elections = {
        "elections": dict(getattr(g, "elections", {})),
        "by_op": {op: dict(v) for op, v in
                  getattr(g, "elections_by_op", {}).items()},
        "provenance": {k: dict(v) for k, v in
                       getattr(g, "election_provenance", {}).items()},
        "pinned": {k: [list(c) for c in v] for k, v in
                   getattr(g, "election_pinned", {}).items()},
    }
    if input_shape is not None:
        x_specs = [jax.ShapeDtypeStruct(tuple(input_shape), dtype)]
    else:
        x_specs = [jax.ShapeDtypeStruct(tuple(i.spec.shape),
                                        jnp.dtype(i.spec.dtype))
                   for i in g.inputs]
    return export_fn(sol_model._fn, sol_model._params_for_call(),
                     *x_specs, elections=elections)


def export_fn(fn, params, *x_specs: jax.ShapeDtypeStruct,
              elections: Optional[Dict[str, Any]] = None) -> bytes:
    """Export ``fn(params, *xs)`` plus ``params`` — any (possibly nested)
    dict pytree of arrays — into the artifact format.  ``deploy`` is the
    SolModel front door; this is the general entry point."""
    p_spec = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype),
        params)
    exp = jexport.export(jax.jit(fn))(p_spec, *x_specs)

    leaves: List[np.ndarray] = []
    tree = _tree_spec(params, leaves)
    buf = io.BytesIO()
    with zipfile.ZipFile(buf, "w") as z:
        z.writestr("graph.stablehlo", exp.serialize())
        for i, arr in enumerate(leaves):
            z.writestr(f"params/{i}.npy", _npy_bytes(arr))
        manifest = {"schema": MANIFEST_SCHEMA, "tree": tree,
                    "elections": elections or {}}
        z.writestr("manifest.json", json.dumps(manifest))
    return buf.getvalue()


def _tree_spec(p, leaves: List[np.ndarray]):
    """Mirror the params pytree as a JSON structure; array leaves become
    ``{"__leaf__": idx, shape, dtype}`` markers and are appended to
    ``leaves`` in deterministic (insertion-order) flatten order."""
    if isinstance(p, dict):
        return {k: _tree_spec(v, leaves) for k, v in p.items()}
    arr = np.asarray(p)
    leaves.append(arr)
    return {"__leaf__": len(leaves) - 1,
            "shape": list(arr.shape), "dtype": str(arr.dtype)}


def _tree_build(spec, staged: List[jax.Array]):
    if isinstance(spec, dict) and isinstance(spec.get("__leaf__"), int):
        return staged[spec["__leaf__"]]
    return {k: _tree_build(v, staged) for k, v in spec.items()}


def _npy_bytes(arr: np.ndarray) -> bytes:
    b = io.BytesIO()
    np.save(b, arr)
    return b.getvalue()


class DeployedModel:
    """Loader for the artifact — no SOL / frontend imports needed beyond
    jax itself (``runtime.packed`` is a 70-line staging helper).

    Parameters are device-put exactly once, here at load time, as one
    packed transfer; every ``__call__`` reuses the staged device buffers."""

    def __init__(self, blob: bytes, device=None):
        z = zipfile.ZipFile(io.BytesIO(blob))
        exp = jexport.deserialize(z.read("graph.stablehlo"))
        manifest = json.loads(z.read("manifest.json"))
        if manifest.get("schema") != MANIFEST_SCHEMA:
            raise ValueError(
                f"artifact manifest schema "
                f"{manifest.get('schema')!r} != {MANIFEST_SCHEMA} — "
                f"written by an incompatible deploy version; re-export "
                f"the artifact")
        if "tree" not in manifest:
            raise ValueError(
                "artifact manifest has no parameter tree (corrupt "
                "artifact?)")
        n_leaves = _count_leaves(manifest["tree"])
        host = [np.load(io.BytesIO(z.read(f"params/{i}.npy")))
                for i in range(n_leaves)]
        staged = packed.transfer(host, device) if host else []
        self.params = _tree_build(manifest["tree"], staged)
        self.staged_leaves = len(staged)
        self._elections = manifest.get("elections") or {}
        self._call = exp.call

    def __call__(self, *xs) -> Any:
        return self._call(self.params, *xs)

    # -- election metadata (mirrors SolModel.impl_report) -------------------
    def impl_report(self, by_kind: bool = False,
                    provenance: bool = False) -> Dict[str, Any]:
        """The exported graph's elected-implementation report, recovered
        from the artifact manifest — same shapes of output as
        ``SolModel.impl_report``, so serving audits work identically on a
        live model and a deployed artifact."""
        e = self._elections
        if provenance:
            out = {}
            for name, count in (e.get("elections") or {}).items():
                entry = {"count": count,
                         "sources": dict((e.get("provenance") or {})
                                         .get(name, {}))}
                pins = (e.get("pinned") or {}).get(name)
                if pins:
                    entry["pinned"] = [tuple(c) for c in pins]
                out[name] = entry
            return out
        if by_kind:
            return {op: dict(v) for op, v in (e.get("by_op") or {}).items()}
        return dict(e.get("elections") or {})


def load(blob: bytes, device=None) -> DeployedModel:
    return DeployedModel(blob, device)


def _count_leaves(spec) -> int:
    if isinstance(spec, dict) and isinstance(spec.get("__leaf__"), int):
        return 1
    return sum(_count_leaves(v) for v in spec.values())
