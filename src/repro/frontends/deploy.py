"""sol.deploy — deployment mode (paper Sec. III-C): extract the NN into a
framework-free artifact.

JAX analogue: AOT-export the optimized whole-graph executable via
``jax.export`` (StableHLO bytes + a tiny loader) — the artifact depends on
neither the frontend module system nor the SOL compiler, mirroring the
paper's 'minimalistic library without framework or SOL dependencies'."""
from __future__ import annotations

import io
import json
import zipfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import export as jexport

from .optimize import SolModel


def deploy(sol_model: SolModel, input_shape: Tuple[int, ...],
           dtype=jnp.float32) -> bytes:
    """Serialize (weights + compiled graph) into a single artifact."""
    params = sol_model._params_for_call()
    x_spec = jax.ShapeDtypeStruct(input_shape, dtype)
    p_spec = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    exp = jexport.export(jax.jit(sol_model._fn))(p_spec, x_spec)
    blob = exp.serialize()

    buf = io.BytesIO()
    with zipfile.ZipFile(buf, "w") as z:
        z.writestr("graph.stablehlo", blob)
        manifest = {"params": {}}
        for k, v in params.items():
            arr = np.asarray(v)
            manifest["params"][k] = {"shape": list(arr.shape),
                                     "dtype": str(arr.dtype)}
            z.writestr(f"params/{k}.npy", _npy_bytes(arr))
        z.writestr("manifest.json", json.dumps(manifest))
    return buf.getvalue()


def _npy_bytes(arr: np.ndarray) -> bytes:
    b = io.BytesIO()
    np.save(b, arr)
    return b.getvalue()


class DeployedModel:
    """Loader for the artifact — no SOL / frontend imports needed beyond
    jax itself."""

    def __init__(self, blob: bytes):
        z = zipfile.ZipFile(io.BytesIO(blob))
        exp = jexport.deserialize(z.read("graph.stablehlo"))
        manifest = json.loads(z.read("manifest.json"))
        self.params = {
            k: np.load(io.BytesIO(z.read(f"params/{k}.npy")))
            for k in manifest["params"]}
        self._call = exp.call

    def __call__(self, x) -> Any:
        return self._call(self.params, x)


def load(blob: bytes) -> DeployedModel:
    return DeployedModel(blob)
