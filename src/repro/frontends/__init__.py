from . import nn, extract, offload, deploy
from .optimize import optimize, SolModel
from .offload import device as device_api

__all__ = ["nn", "extract", "offload", "deploy", "optimize", "SolModel"]
