"""Data pipeline: deterministic synthetic token stream with sharded,
prefetching host loading.

Deterministic per (seed, step) — a restart resumes from any step without
replaying the stream (the checkpoint stores only the step counter), and an
elastic re-shard keeps sample assignment stable because indexing is by
global sample id, not worker id.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..models.config import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    prefetch: int = 2


class SyntheticTokenDataset:
    """Zipf-ish synthetic tokens with enough structure that loss decreases:
    each sequence is a Markov chain whose transition row is derived from a
    per-(seed, step, sample) counter-based RNG (stateless → seekable)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int, sample: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, sample]))

    def sample(self, step: int, sample_id: int) -> np.ndarray:
        rng = self._rng(step, sample_id)
        v = self.cfg.vocab
        s = self.cfg.seq_len
        # zipf marginals + short-range structure (periodic motif insertion)
        toks = (rng.zipf(1.3, size=s + 1) - 1) % v
        motif = (rng.zipf(1.3, size=8) - 1) % v
        start = int(rng.integers(0, max(1, s - 64)))
        for r in range(4):
            o = start + r * 8
            if o + 8 <= s + 1:
                toks[o:o + 8] = motif
        return toks.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        gb = self.cfg.global_batch
        seqs = np.stack([self.sample(step, i) for i in range(gb)])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


class DataLoader:
    """Background-thread prefetching loader (the host-side analogue of the
    paper's async memcopy queue: batches are staged while step N computes)."""

    def __init__(self, dataset: SyntheticTokenDataset, start_step: int = 0,
                 extras: Optional[Dict[str, Any]] = None):
        self.dataset = dataset
        self.step = start_step
        self.extras = extras or {}
        self._q: "queue.Queue" = queue.Queue(maxsize=dataset.cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.dataset.batch(step)
            batch.update(self.extras)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_batch_shapes(cfg: ArchConfig, shape: ShapeConfig):
    from ..launch.specs import train_batch_specs
    return train_batch_specs(cfg, shape)
