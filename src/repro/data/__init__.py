from .pipeline import (DataConfig, SyntheticTokenDataset, DataLoader,
                       make_batch_shapes)

__all__ = ["DataConfig", "SyntheticTokenDataset", "DataLoader",
           "make_batch_shapes"]
