"""repro — SOL (Weber & Huici, 2020) reproduced as a JAX/TPU middleware,
scaled to multi-pod meshes.  See DESIGN.md for the paper→TPU mapping."""

__version__ = "1.0.0"
