"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv frontend (STUB: input_specs supplies precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""
from ..models.config import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                        # decoder layers
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    layer_pattern=("attn",),
    enc_dec=EncDecConfig(n_enc_layers=4, enc_seq=1500),
    frontend="audio",
    ffn="gelu",
    norm="layernorm",
    rope_theta=10000.0,
    subquadratic=False,
    source="arXiv:2212.04356; unverified",
)
