"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attn, 1:2 (Griffin).
[arXiv:2402.19427; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,                            # MQA in Griffin's local attention
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    layer_pattern=("rglru", "rglru", "local"),  # 2 recurrent : 1 local attn
    window=2048,
    d_rnn=4096,
    ffn="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10000.0,
    subquadratic=True,                 # O(1) state + bounded window
    source="arXiv:2402.19427; unverified",
)
