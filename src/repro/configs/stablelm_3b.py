"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=6912,
    vocab=50304,
    head_dim=80,
    layer_pattern=("attn",),
    ffn="swiglu",
    norm="layernorm",
    qkv_bias=False,
    rope_theta=10000.0,
    subquadratic=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
