"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    layer_pattern=("local", "attn"),   # alternating local/global (1:1)
    window=4096,
    softcap_attn=50.0,
    softcap_final=30.0,
    post_norms=True,                   # gemma2 post-layernorms
    ffn="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10000.0,
    subquadratic=False,                # global layers are full attention
    source="arXiv:2408.00118; hf",
)
