"""Assigned architecture configs (+ the paper's own CNN/MLP benchmarks).

``get_config(name)`` returns the exact full-size ArchConfig;
``get_smoke(name)`` the reduced same-family smoke config.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ArchConfig, reduced

ARCH_IDS: List[str] = [
    "stablelm_3b",
    "command_r_plus_104b",
    "qwen2_1_5b",
    "gemma2_9b",
    "recurrentgemma_9b",
    "whisper_tiny",
    "kimi_k2_1t_a32b",
    "olmoe_1b_7b",
    "rwkv6_1_6b",
    "internvl2_26b",
]

# assignment-sheet id -> module name
ALIASES = {
    "stablelm-3b": "stablelm_3b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma2-9b": "gemma2_9b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-tiny": "whisper_tiny",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "internvl2-26b": "internvl2_26b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    return reduced(get_config(name))


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
