"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend (STUB: input_specs supplies precomputed
patch embeddings) + InternLM2 backbone.  [arXiv:2404.16821; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=16384,
    vocab=92553,                       # padded to 92672 (model axis 16 | 128)
    head_dim=128,
    layer_pattern=("attn",),
    frontend="vision",
    n_patches=256,
    ffn="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    subquadratic=False,
    source="arXiv:2404.16821; hf",
)
