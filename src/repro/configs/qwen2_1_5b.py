"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias.  [arXiv:2407.10671; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    layer_pattern=("attn",),
    ffn="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    subquadratic=False,
    source="arXiv:2407.10671; hf",
)
