"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8.  [arXiv:2409.02060; hf]"""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,                         # per-expert FFN hidden dim
    vocab=50304,
    head_dim=128,
    layer_pattern=("attn",),
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024,
                  n_dense_layers=0, capacity_factor=1.25, group_size=1024),
    ffn="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    subquadratic=False,
    source="arXiv:2409.02060; hf",
)
