"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                        # 2048 / rwkv_head_dim(64)
    n_kv=32,
    d_ff=7168,                         # channel-mix hidden dim
    vocab=65536,
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
    norm="layernorm",
    subquadratic=True,                 # attention-free, O(1) state
    source="arXiv:2404.05892; unverified",
)
