"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table).
Layer 0 is dense (K2 style); d_ff=2048 is the per-expert hidden dim.
[arXiv:2501.kimi2; unverified]"""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv=8,
    d_ff=2048,                         # per-expert FFN hidden dim
    vocab=163840,
    head_dim=128,
    layer_pattern=("attn",),
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                  n_dense_layers=1, d_ff_dense=18432,
                  capacity_factor=1.25, group_size=1024),
    ffn="swiglu",
    norm="rmsnorm",
    rope_theta=50000.0,
    subquadratic=False,
    source="arXiv:2501.kimi2; unverified",
)
