"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel attn+FFN blocks.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
    layer_pattern=("attn",),
    ffn="swiglu",
    norm="layernorm",
    parallel_block=True,     # Cohere-style parallel attention/FFN
    qkv_bias=False,
    tie_embeddings=True,     # Command-R ties input/output embeddings
    rope_theta=75000.0,
    subquadratic=False,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
