"""SOL device backends (Sec. IV of the paper).

A backend is a small table: per-op implementations for the two optimizing
modules plus layout preferences.  The paper's point — that a backend is ≤3 kLOC
because DFP codegen is shared and only 'flavours' differ — maps here to:
backends share all lowering logic in ``core.executor`` and only override

  * ``dfp_impl``   — how a DFP fusion group is executed
                     ('compose' = XLA fusion; 'pallas' = the dfp_fused kernel,
                     interpret-mode on CPU, compiled on real TPU),
  * ``dnn_impl``   — how Linear/Conv are executed (jnp.dot_general einsum vs
                     the Pallas matmul kernel),
  * layout preferences (the paper: Linear weights (out,in) on CPU but
    (in,out) on SX-Aurora; here: einsum operand order / conv layouts),
  * hardware constants used by the cost model / roofline.

Backends:
  ``xla``              — pure jnp; runs anywhere; the dry-run/production path
                         (XLA:TPU does its own fusion — this is the DNN-library
                         analogue of "use the vendor stack").
  ``pallas_interpret`` — TPU Pallas kernels executed with interpret=True on
                         CPU; used for kernel validation in this container.
  ``pallas_tpu``       — TPU Pallas kernels, compiled (requires real TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from ..core.ir import Module, Node, OpKind


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float        # FLOP/s per chip
    hbm_bandwidth: float          # bytes/s per chip
    ici_bandwidth: float          # bytes/s per link
    hbm_bytes: int                # capacity per chip
    vmem_bytes: int               # on-chip scratch
    mxu_dim: int = 128            # systolic array tile
    lanes: int = 128              # VPU lane count
    sublanes: int = 8


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024 ** 3,
    vmem_bytes=128 * 1024 ** 2,
)

HOST_CPU = HardwareSpec(
    name="host_cpu",
    peak_flops_bf16=0.2e12,
    hbm_bandwidth=40e9,
    ici_bandwidth=10e9,
    hbm_bytes=64 * 1024 ** 3,
    vmem_bytes=32 * 1024 ** 2,   # ~LLC slice; DFP cache-residency analogue
)


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    dfp_impl: str                 # 'compose' | 'pallas'
    dnn_impl: str                 # 'einsum'  | 'pallas'
    interpret: bool               # Pallas interpret mode
    hw: HardwareSpec
    # layout preferences — the paper's per-device layout election
    linear_weight_layout: str     # 'oi' (out,in) vs 'io' (in,out)
    conv_layout: str              # 'nchw' vs 'nhwc'

    def preferred_layout(self, node: Node) -> str:
        if node.op in (OpKind.LINEAR, OpKind.MATMUL):
            return self.linear_weight_layout
        if node.op is OpKind.CONV2D:
            return self.conv_layout
        return self.conv_layout  # DFP ops follow the surrounding data layout

    def impl_for(self, node: Node) -> str:
        if node.module is Module.DNN:
            return self.dnn_impl
        return self.dfp_impl


_REGISTRY: Dict[str, Backend] = {}


def register_backend(b: Backend) -> Backend:
    _REGISTRY[b.name] = b
    return b


def get_backend(name: str) -> Backend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_backends() -> Dict[str, Backend]:
    return dict(_REGISTRY)


# CPU-like backend: XLA does the fusion, einsum hits the host BLAS.  Mirrors
# the paper's X86 backend (ISPC + DNNL) in role: 'vendor stack does the work'.
register_backend(Backend(
    name="xla",
    dfp_impl="compose",
    dnn_impl="einsum",
    interpret=False,
    hw=TPU_V5E,                 # production target of the lowered program
    linear_weight_layout="oi",  # paper: (out,in) fastest on CPUs
    conv_layout="nchw",
))

# TPU Pallas kernels validated on CPU via interpret mode.
register_backend(Backend(
    name="pallas_interpret",
    dfp_impl="pallas",
    dnn_impl="einsum",          # MXU matmul stays on XLA in interpret mode
    interpret=True,
    hw=TPU_V5E,
    linear_weight_layout="io",  # paper: (in,out) on the long-vector machine;
    conv_layout="nhwc",         # TPU prefers minor-most channels (lane dim)
))

# Real-TPU backend: same kernels, compiled.
register_backend(Backend(
    name="pallas_tpu",
    dfp_impl="pallas",
    dnn_impl="pallas",
    interpret=False,
    hw=TPU_V5E,
    linear_weight_layout="io",
    conv_layout="nhwc",
))
