"""SOL device backends (Sec. IV of the paper).

The paper's point — that a backend is ≤3 kLOC because DFP codegen is shared
and only per-op 'flavours' differ — is realised here as a **per-op dispatch
table with capability-based fallback**.  A backend no longer carries static
``dfp_impl``/``dnn_impl`` strings; instead each (backend, OpKind) pair maps to
a list of :class:`Impl` entries and the executor resolves ``node → impl``
through a documented fallback chain:

  tier 0  backend-specific kernel   (``register_impl(backend, op, fn)``)
  tier 1  shared Pallas kernel      (``register_shared_impl`` — admitted only
                                     when the impl's ``requires`` capabilities
                                     are a subset of the backend's)
  tier 2  XLA/jnp reference         (``register_reference_impl`` — always
                                     available; registered by core.executor)

Adding a device backend therefore means: one ``register_backend`` call with a
:class:`HardwareSpec`, plus optional ``register_impl`` overrides — and **zero
edits to core.executor** (see ``backends/host_cpu.py`` for the proof).

Backends also keep the paper's per-device layout preferences (Linear weights
(out,in) on CPUs vs (in,out) on the long-vector machine; NCHW vs NHWC convs)
and the hardware constants the cost model / roofline uses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.autotune import Tunable
from ..core.ir import Node, OpKind


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float        # FLOP/s per chip
    hbm_bandwidth: float          # bytes/s per chip
    ici_bandwidth: float          # bytes/s per link
    hbm_bytes: int                # capacity per chip
    vmem_bytes: int               # on-chip scratch
    mxu_dim: int = 128            # systolic array tile
    lanes: int = 128              # VPU lane count
    sublanes: int = 8

    # roofline terms — shared by the implementation-election pass
    # (core.passes), benchmarks/roofline.py and launch/dryrun.py
    def compute_s(self, flops: float) -> float:
        return flops / self.peak_flops_bf16

    def memory_s(self, nbytes: float) -> float:
        return nbytes / self.hbm_bandwidth

    def collective_s(self, nbytes: float) -> float:
        return nbytes / self.ici_bandwidth

    def roofline_s(self, flops: float, nbytes: float,
                   ici_bytes: float = 0.0) -> float:
        """Time lower bound: the dominant of compute / memory / interconnect."""
        return max(self.compute_s(flops), self.memory_s(nbytes),
                   self.collective_s(ici_bytes))


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024 ** 3,
    vmem_bytes=128 * 1024 ** 2,
)

HOST_CPU = HardwareSpec(
    name="host_cpu",
    peak_flops_bf16=0.2e12,
    hbm_bandwidth=40e9,
    ici_bandwidth=10e9,
    hbm_bytes=64 * 1024 ** 3,
    vmem_bytes=32 * 1024 ** 2,   # ~LLC slice; DFP cache-residency analogue
    mxu_dim=16,                  # AVX-512-ish tile, no systolic array
    lanes=16,
    sublanes=1,
)


# ---------------------------------------------------------------------------
# per-op implementations
# ---------------------------------------------------------------------------

# fn(node, vals, backend) -> Array; vals are the lowered inputs of the node
# (for FUSED nodes: the side inputs, in node.inputs order).
ImplFn = Callable[[Node, Sequence[Any], "Backend"], Any]

# grad_fn(node, res, ct, backend) -> tuple of cotangents, one per node input
# (entries for integer-dtype inputs are ignored by the executor, which
# substitutes float0 zeros).  ``res`` is the residual pair saved by the
# forward pass of the executor's ``jax.custom_vjp`` wrapper:
# ``(primal_inputs_tuple, primal_output)``.  Backward impls are free to
# recompute anything else they need from the primals (remat-style).
GradFn = Callable[[Node, Tuple[Tuple[Any, ...], Any], Any, "Backend"], Any]

TIER_BACKEND = 0      # backend-specific kernel
TIER_SHARED = 1       # shared Pallas kernel (capability-gated)
TIER_REFERENCE = 2    # XLA/jnp reference lowering


@dataclasses.dataclass(frozen=True)
class Impl:
    """One implementation 'flavour' of an op (the paper's per-device kernel
    choice, e.g. Listing 3's AveragePooling variants)."""

    name: str                                    # e.g. "pallas.dfp_fused"
    op: OpKind
    fn: ImplFn
    tier: int
    requires: frozenset = frozenset()            # backend capabilities needed
    supports: Optional[Callable[[Node], bool]] = None   # per-node capability
    backend: Optional[str] = None                # tier-0 owner; None = any
    # memory behaviour for the roofline cost model: 'streamed' impls touch
    # HBM once per input/output (depth-first); 'roundtrip' impls materialize
    # every intermediate (op-at-a-time composition).
    memory: str = "streamed"
    # tuning declaration (core.autotune.Tunable): candidate config space +
    # the node.attrs key measured winners are pinned under
    tunable: Optional[Tunable] = None

    def admissible(self, backend: "Backend", node: Node) -> bool:
        if self.backend is not None and self.backend != backend.name:
            return False    # another backend's private kernel
        if not self.requires <= backend.capabilities:
            return False
        if self.supports is not None and not self.supports(node):
            return False
        return True


_BACKEND_IMPLS: Dict[Tuple[str, OpKind], List[Impl]] = {}
_SHARED_IMPLS: Dict[OpKind, List[Impl]] = {}
_REFERENCE_IMPLS: Dict[OpKind, Impl] = {}
_IMPLS_BY_NAME: Dict[str, Impl] = {}

# backward (gradient) dispatch tables — same Impl dataclass, same tiers, same
# capability gating, but the stored ``fn`` follows the GradFn signature.  Kept
# as parallel tables (not a slot on the forward Impl) so a node's forward and
# backward elections are independent: the fastest forward kernel and the
# fastest backward kernel need not come from the same family member, and the
# autotune cache keys them separately (op key ``f"{op.value}_bwd"``).
_GRAD_BACKEND_IMPLS: Dict[Tuple[str, OpKind], List[Impl]] = {}
_GRAD_SHARED_IMPLS: Dict[OpKind, List[Impl]] = {}
_GRAD_REFERENCE_IMPLS: Dict[OpKind, Impl] = {}
_GRAD_IMPLS_BY_NAME: Dict[str, Impl] = {}


def _index(impl: Impl) -> Impl:
    _IMPLS_BY_NAME[impl.name] = impl
    return impl


def register_impl(backend: str, op: OpKind, fn: ImplFn, *,
                  name: Optional[str] = None,
                  supports: Optional[Callable[[Node], bool]] = None,
                  memory: str = "streamed",
                  tunable: Optional[Tunable] = None) -> Impl:
    """Register a backend-specific implementation (tier 0).  Newest wins
    within the tier, so a later registration overrides an earlier one."""
    impl = _index(Impl(name or f"{backend}.{op.value}", op, fn, TIER_BACKEND,
                       supports=supports, backend=backend, memory=memory,
                       tunable=tunable))
    _BACKEND_IMPLS.setdefault((backend, op), []).insert(0, impl)
    return impl


def register_shared_impl(op: OpKind, fn: ImplFn, *, name: str,
                         requires: Sequence[str] = (),
                         supports: Optional[Callable[[Node], bool]] = None,
                         memory: str = "streamed",
                         tunable: Optional[Tunable] = None) -> Impl:
    """Register a shared kernel (tier 1), admitted for any backend whose
    capabilities cover ``requires``."""
    impl = _index(Impl(name, op, fn, TIER_SHARED,
                       requires=frozenset(requires), supports=supports,
                       memory=memory, tunable=tunable))
    _SHARED_IMPLS.setdefault(op, []).insert(0, impl)
    return impl


def register_reference_impl(op: OpKind, fn: ImplFn, *,
                            name: Optional[str] = None,
                            memory: str = "streamed") -> Impl:
    """Register the always-available XLA/jnp reference (tier 2)."""
    impl = _index(Impl(name or f"ref.{op.value}", op, fn, TIER_REFERENCE,
                       memory=memory))
    _REFERENCE_IMPLS[op] = impl
    return impl


def get_impl(name: str) -> Optional[Impl]:
    _load_entry_points()
    return _IMPLS_BY_NAME.get(name)


_ENTRY_POINTS_STATE = "unloaded"     # unloaded | loading | loaded


def _load_entry_points() -> None:
    """Import the modules that populate the dispatch table: the executor's
    reference lowerings and the five kernel entry points (each ops.py
    registers its own impls at import).  A failed import resets the state so
    the real error resurfaces on the next dispatch call instead of leaving a
    silently half-populated table."""
    global _ENTRY_POINTS_STATE
    if _ENTRY_POINTS_STATE != "unloaded":
        return
    _ENTRY_POINTS_STATE = "loading"
    try:
        from ..core import executor
        executor._register_reference_impls()
        from ..kernels.avgpool import ops as _a              # noqa: F401
        from ..kernels.decode_attention import ops as _da    # noqa: F401
        from ..kernels.dfp_fused import ops as _d            # noqa: F401
        from ..kernels.flash_attention import ops as _f      # noqa: F401
        from ..kernels.matmul import ops as _m               # noqa: F401
        from ..kernels.rglru_scan import ops as _g           # noqa: F401
        from ..kernels.rwkv6_scan import ops as _r           # noqa: F401
        # backward entry points (each grad.py registers its impls at import)
        from ..kernels.avgpool import grad as _ag            # noqa: F401
        from ..kernels.decode_attention import grad as _dcg  # noqa: F401
        from ..kernels.dfp_fused import grad as _dg          # noqa: F401
        from ..kernels.flash_attention import grad as _fg    # noqa: F401
        from ..kernels.matmul import grad as _mg             # noqa: F401
        from ..kernels.rglru_scan import grad as _gg         # noqa: F401
        from ..kernels.rwkv6_scan import grad as _rg         # noqa: F401
    except BaseException:
        _ENTRY_POINTS_STATE = "unloaded"
        raise
    _ENTRY_POINTS_STATE = "loaded"


def tunables_for(op: OpKind) -> List[Tunable]:
    """Every Tunable any impl (any backend, any tier) declares for ``op`` —
    the election pass clears all of them before pinning, so re-electing a
    graph on a backend where the tuned impl is inadmissible still drops the
    stale pin."""
    _load_entry_points()
    out: List[Tunable] = []
    for (_b, o), impls in _BACKEND_IMPLS.items():
        if o is op:
            out += [i.tunable for i in impls if i.tunable is not None]
    out += [i.tunable for i in _SHARED_IMPLS.get(op, ())
            if i.tunable is not None]
    return out


def candidates(backend: "Backend", node: Node) -> List[Impl]:
    """All admissible impls for (backend, node) in fallback-chain order:
    backend-specific → shared → reference."""
    _load_entry_points()
    out: List[Impl] = []
    for impl in _BACKEND_IMPLS.get((backend.name, node.op), []):
        if impl.admissible(backend, node):
            out.append(impl)
    for impl in _SHARED_IMPLS.get(node.op, []):
        if impl.admissible(backend, node):
            out.append(impl)
    ref = _REFERENCE_IMPLS.get(node.op)
    if ref is not None and ref.admissible(backend, node):
        out.append(ref)
    return out


def resolve(backend: "Backend", node: Node) -> Impl:
    """First admissible impl in the fallback chain; the executor uses this
    when the election pass did not annotate the node."""
    cands = candidates(backend, node)
    if not cands:
        raise NotImplementedError(
            f"no implementation of {node.op} for backend {backend.name!r}")
    return cands[0]


# ---------------------------------------------------------------------------
# backward implementations — first-class registry citizens (ISSUE 10)
# ---------------------------------------------------------------------------

GRAD_SUFFIX = "_bwd"


def grad_cache_op(op: OpKind) -> str:
    """Autotune-cache op key for a backward impl of ``op`` — suffixed so
    backward timings/configs never collide with forward entries."""
    return f"{op.value}{GRAD_SUFFIX}"


def register_grad_impl(backend: str, op: OpKind, fn: GradFn, *,
                       name: Optional[str] = None,
                       supports: Optional[Callable[[Node], bool]] = None,
                       memory: str = "streamed",
                       tunable: Optional[Tunable] = None) -> Impl:
    """Register a backend-specific backward kernel (tier 0)."""
    impl = Impl(name or f"{backend}.{op.value}{GRAD_SUFFIX}", op, fn,
                TIER_BACKEND, supports=supports, backend=backend,
                memory=memory, tunable=tunable)
    _GRAD_IMPLS_BY_NAME[impl.name] = impl
    _GRAD_BACKEND_IMPLS.setdefault((backend, op), []).insert(0, impl)
    return impl


def register_shared_grad_impl(op: OpKind, fn: GradFn, *, name: str,
                              requires: Sequence[str] = (),
                              supports: Optional[Callable[[Node], bool]] = None,
                              memory: str = "streamed",
                              tunable: Optional[Tunable] = None) -> Impl:
    """Register a shared backward kernel (tier 1, capability-gated)."""
    impl = Impl(name, op, fn, TIER_SHARED, requires=frozenset(requires),
                supports=supports, memory=memory, tunable=tunable)
    _GRAD_IMPLS_BY_NAME[impl.name] = impl
    _GRAD_SHARED_IMPLS.setdefault(op, []).insert(0, impl)
    return impl


def register_reference_grad_impl(op: OpKind, fn: GradFn, *,
                                 name: Optional[str] = None,
                                 memory: str = "roundtrip") -> Impl:
    """Register the always-available backward reference (tier 2) — usually
    ``jax.vjp`` of the forward reference lowering, recomputed from primals."""
    impl = Impl(name or f"ref.{op.value}{GRAD_SUFFIX}", op, fn,
                TIER_REFERENCE, memory=memory)
    _GRAD_IMPLS_BY_NAME[impl.name] = impl
    _GRAD_REFERENCE_IMPLS[op] = impl
    return impl


def get_grad_impl(name: str) -> Optional[Impl]:
    _load_entry_points()
    return _GRAD_IMPLS_BY_NAME.get(name)


def grad_tunables_for(op: OpKind) -> List[Tunable]:
    """Every Tunable any backward impl declares for ``op`` (cleared before
    the backward election pins its winner)."""
    _load_entry_points()
    out: List[Tunable] = []
    for (_b, o), impls in _GRAD_BACKEND_IMPLS.items():
        if o is op:
            out += [i.tunable for i in impls if i.tunable is not None]
    out += [i.tunable for i in _GRAD_SHARED_IMPLS.get(op, ())
            if i.tunable is not None]
    return out


def grad_candidates(backend: "Backend", node: Node) -> List[Impl]:
    """Admissible backward impls for (backend, node) that may stand for
    election: backend-specific first, then shared.

    The reference backward (``jax.vjp`` of the op's reference forward) is
    deliberately NOT a candidate when any kernel-tier backward is
    admissible: it materializes the intermediates the kernels exist to
    avoid (the S×S attention matrix, every recurrent hidden state), so a
    timing race on a dev box would elect it at toy shapes and then blow
    device memory at real ones.  It remains the capability *fallback* —
    when no kernel backward is admissible it is returned alone, keeping
    every op differentiable on every backend."""
    _load_entry_points()
    out: List[Impl] = []
    for impl in _GRAD_BACKEND_IMPLS.get((backend.name, node.op), []):
        if impl.admissible(backend, node):
            out.append(impl)
    for impl in _GRAD_SHARED_IMPLS.get(node.op, []):
        if impl.admissible(backend, node):
            out.append(impl)
    if not out:
        ref = _GRAD_REFERENCE_IMPLS.get(node.op)
        if ref is not None and ref.admissible(backend, node):
            out.append(ref)
    return out


def resolve_grad(backend: "Backend", node: Node) -> Optional[Impl]:
    """First admissible backward impl, or None — an op with no registered
    backward differentiates through its (jnp) forward impl via plain JAX AD,
    so absence is not an error."""
    cands = grad_candidates(backend, node)
    return cands[0] if cands else None


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    interpret: bool               # Pallas interpret mode
    hw: HardwareSpec
    # layout preferences — the paper's per-device layout election
    linear_weight_layout: str     # 'oi' (out,in) vs 'io' (in,out)
    conv_layout: str              # 'nchw' vs 'nhwc'
    # capability set gating shared impls ('pallas' admits the Pallas kernels,
    # 'mxu' the systolic-array matmul path, ...)
    capabilities: frozenset = frozenset({"xla"})
    # mesh qualifier for the autotune cache (``distributed.sharding.
    # mesh_backend`` sets it, e.g. "data2model2").  Dispatch-table matching
    # stays on ``name`` — a mesh view admits exactly the impls the flat
    # backend does — but every cache read/write goes through ``cache_name``,
    # so per-shard (post-partition) timings can NEVER collide with
    # global-shape timings of the flat backend: a local pow2 shape divided
    # by a pow2 mesh axis lands in some other global bucket, and only the
    # qualifier keeps those two worlds apart.
    shard_tag: str = ""

    @property
    def cache_name(self) -> str:
        """The autotune-cache backend key: ``name`` on a single device,
        ``name@shard_tag`` under a mesh — measured timings, pinned Tunable
        configs and ``strict_provenance`` all key on per-shard shapes via
        this name, never on the flat backend's global-shape entries."""
        return f"{self.name}@{self.shard_tag}" if self.shard_tag else self.name

    def preferred_layout(self, node: Node) -> str:
        if node.op in (OpKind.LINEAR, OpKind.MATMUL):
            return self.linear_weight_layout
        if node.op is OpKind.CONV2D:
            return self.conv_layout
        return self.conv_layout  # DFP ops follow the surrounding data layout

    def candidates(self, node: Node) -> List[Impl]:
        return candidates(self, node)

    def resolve(self, node: Node) -> Impl:
        return resolve(self, node)


_REGISTRY: Dict[str, Backend] = {}


def register_backend(b: Backend) -> Backend:
    _REGISTRY[b.name] = b
    return b


def get_backend(name: str) -> Backend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def set_layout_preference(name: str, *, linear: Optional[str] = None,
                          conv: Optional[str] = None) -> Backend:
    """Session-scoped layout override: re-register ``name`` with measured
    layout winners (``benchmarks/layouts.py --apply`` feeds the benchmark's
    elected layouts back here, replacing the static strings)."""
    b = get_backend(name)
    return register_backend(dataclasses.replace(
        b,
        linear_weight_layout=linear or b.linear_weight_layout,
        conv_layout=conv or b.conv_layout))


def available_backends() -> Dict[str, Backend]:
    return dict(_REGISTRY)


# CPU-like backend: XLA does the fusion, einsum hits the host BLAS.  Mirrors
# the paper's X86 backend (ISPC + DNNL) in role: 'vendor stack does the work'.
register_backend(Backend(
    name="xla",
    interpret=False,
    hw=TPU_V5E,                 # production target of the lowered program
    linear_weight_layout="oi",  # paper: (out,in) fastest on CPUs
    conv_layout="nchw",
    capabilities=frozenset({"xla"}),
))

# TPU Pallas kernels validated on CPU via interpret mode — including the
# MXU matmul path, so 'mxu'-gated impls are electable and testable off-TPU.
register_backend(Backend(
    name="pallas_interpret",
    interpret=True,
    hw=TPU_V5E,
    linear_weight_layout="io",  # paper: (in,out) on the long-vector machine;
    conv_layout="nhwc",         # TPU prefers minor-most channels (lane dim)
    capabilities=frozenset({"xla", "pallas", "mxu"}),
))

# Real-TPU backend: same kernels, compiled.
register_backend(Backend(
    name="pallas_tpu",
    interpret=False,
    hw=TPU_V5E,
    linear_weight_layout="io",
    conv_layout="nhwc",
    capabilities=frozenset({"xla", "pallas", "mxu"}),
))
