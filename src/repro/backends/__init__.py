from .registry import (Backend, get_backend, available_backends,
                       register_backend)

__all__ = ["Backend", "get_backend", "available_backends", "register_backend"]
