from .registry import (Backend, HardwareSpec, Impl, available_backends,
                       candidates, get_backend, get_impl, register_backend,
                       register_impl, register_reference_impl,
                       register_shared_impl, resolve, set_layout_preference)
from . import host_cpu as _host_cpu   # registers the host_cpu backend

__all__ = ["Backend", "HardwareSpec", "Impl", "available_backends",
           "candidates", "get_backend", "get_impl", "register_backend",
           "register_impl", "register_reference_impl",
           "register_shared_impl", "resolve", "set_layout_preference"]
