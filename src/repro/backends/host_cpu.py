"""The ``host_cpu`` backend — the paper's SX-Aurora exercise replayed on our
codebase: prove that standing up a device backend costs a handful of
declarations, because all lowering logic is shared and only per-op 'flavours'
differ (paper Sec. IV, 'a backend is ≤3 kLOC').

Everything here goes through the public dispatch table — ``register_backend``
plus ``register_impl`` — with **zero edits to core.executor**:

  * its own :class:`HardwareSpec` (host memory hierarchy, no MXU),
  * (out,in) Linear weight layout and NCHW convs (paper: fastest on CPUs),
  * DFP fusion groups fall back to the reference 'compose' flavour (XLA
    fuses the chain — the vendor-stack path; no 'pallas' capability),
  * two tier-0 overrides showing per-op flavour election: a BLAS-shaped
    Linear (explicit (out,in) contraction) and an im2col-free NCHW conv.

All overrides are numerically identical to the reference tier (the parity
test pins host_cpu vs xla to atol 1e-5)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.ir import Node, OpKind
from .registry import (HOST_CPU, Backend, register_backend, register_impl)

Array = jax.Array


host_cpu = register_backend(Backend(
    name="host_cpu",
    interpret=False,
    hw=HOST_CPU,
    linear_weight_layout="oi",   # paper: (out,in) fastest on CPUs
    conv_layout="nchw",
    capabilities=frozenset({"xla"}),   # no Pallas: DFP groups compose
))


def _linear_oi(n: Node, vals: Sequence[Array], backend: Backend) -> Array:
    """BLAS-shaped Linear: keep weights (out,in) and contract x @ W^T, the
    GEMM orientation host BLAS libraries prefer (paper Sec. III-A)."""
    x, w = vals[0], vals[1]
    if w.shape[0] != n.attrs["out_features"]:
        w = w.T                       # graph stored (in,out): restore (out,in)
    y = x @ w.T
    if len(vals) > 2 and vals[2] is not None:
        y = y + vals[2]
    return y


def _conv2d_nchw(n: Node, vals: Sequence[Array], backend: Backend) -> Array:
    """NCHW conv with explicit dimension numbers — the layout host conv
    libraries (DNNL in the paper's X86 backend) default to."""
    x, w = vals[0], vals[1]
    stride = n.attrs.get("stride", 1)
    padding = n.attrs.get("padding", 0)
    strides = (stride, stride) if isinstance(stride, int) else stride
    pads = ((padding, padding), (padding, padding)) \
        if isinstance(padding, int) else padding
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pads,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=n.attrs.get("groups", 1))
    if len(vals) > 2 and vals[2] is not None:
        y = y + vals[2][None, :, None, None]
    return y


register_impl("host_cpu", OpKind.LINEAR, _linear_oi,
              name="host_cpu.linear_oi",
              supports=lambda n: len(n.inputs) >= 2)
register_impl("host_cpu", OpKind.CONV2D, _conv2d_nchw,
              name="host_cpu.conv2d_nchw",
              supports=lambda n: len(n.spec.shape) == 4)
