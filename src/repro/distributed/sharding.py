"""Sharding rule engine: TP/EP/SP/DP PartitionSpecs with divisibility
fallbacks.

Philosophy (the SOL layout pass, at mesh scale): every parameter / cache
tensor is assigned a layout by *name + rank* rules, with hard divisibility
guards — a dim is only sharded when its size divides the mesh axis, else the
engine falls back (heads → head_dim → sequence → replicate).  This is what
makes one rule table serve 10 architectures.

Mesh axes: ``model`` (TP/EP/SP) and ``data`` (+ leading ``pod``) for DP.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import backbone as B
from ..models.config import ArchConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _div(size: int, n: int) -> bool:
    return n > 0 and size % n == 0


def shard_dim(mesh: Mesh, size: int, axes):
    """axes if divisible else None (the engine's universal fallback)."""
    return axes if _div(size, axis_size(mesh, axes)) else None


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# 2-D weights sharded on the output (column-parallel)
_COL = {"wq", "wk", "wv", "wg", "wu", "w_in", "w_gate", "ck", "cr",
        "xwq", "xwk", "xwv", "w1", "wr"}
# 2-D weights sharded on the input (row-parallel)
_ROW = {"wo", "wd", "w_out", "cv", "xwo", "w2"}
# 1-D tensors following a column-parallel output
_COL_BIAS = {"bq", "bk", "bv", "b1", "conv_b", "lam"}
_REPLICATED = {"gain", "bias", "bo", "b2", "router", "u", "w0",
               "gn_gain", "gn_bias", "enc_pos"}


def param_spec(mesh: Mesh, cfg: ArchConfig, path: Tuple[str, ...],
               shape: Tuple[int, ...]) -> P:
    name = path[-1]
    stacked = path[0] == "macro"          # leading n_macro scan dim
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    m = "model"

    def mk(*spec):
        return P(*(lead + spec))

    parent = path[-2] if len(path) >= 2 else ""
    if parent == "moe" or (len(path) >= 2 and "moe" in path):
        if name == "router":
            return mk(None, None)
        # experts (E, D, F) / (E, F, D): expert-parallel on model
        return mk(shard_dim(mesh, body[0], m), None, None)
    if name == "embed":
        return P(shard_dim(mesh, shape[0], m), None)
    if name == "lm_head":
        return P(None, shard_dim(mesh, shape[1], m))
    if name in _COL and len(body) == 2:
        return mk(None, shard_dim(mesh, body[1], m))
    if name in _ROW and len(body) == 2:
        return mk(shard_dim(mesh, body[0], m), None)
    if name == "conv_w":                   # (W, dr)
        return mk(None, shard_dim(mesh, body[1], m))
    if name in ("wa", "wx"):               # (dr, dr) RG-LRU gates
        return mk(None, shard_dim(mesh, body[1], m))
    if name in _COL_BIAS and len(body) == 1:
        return mk(shard_dim(mesh, body[0], m))
    if name.startswith("lora_") or name.startswith("mu_"):
        return mk(*(None,) * len(body))
    if name in _REPLICATED or len(body) == 1:
        return mk(*(None,) * len(body))
    return mk(*(None,) * len(body))


def param_specs(mesh: Mesh, cfg: ArchConfig, params_tree) -> Any:
    """PartitionSpec pytree matching a params(-shaped) pytree."""
    def walk(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p.idx) for p in path)
        return param_spec(mesh, cfg, names, tuple(leaf.shape))
    return jax.tree_util.tree_map_with_path(walk, params_tree)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(mesh: Mesh, cfg: ArchConfig, batch_tree) -> Any:
    dp = dp_axes(mesh)

    def walk(path, leaf):
        b = shard_dim(mesh, leaf.shape[0], dp)
        return P(b, *(None,) * (len(leaf.shape) - 1))
    return jax.tree_util.tree_map_with_path(walk, batch_tree)


def cache_specs(mesh: Mesh, cfg: ArchConfig, cache_tree) -> Any:
    """KV caches: batch on data; kv-heads on model when divisible, else
    sequence-sharded (SP / flash-decoding); recurrent states: channels/heads
    on model."""
    dp = dp_axes(mesh)
    m = "model"

    def walk(path, leaf):
        names = [p.key if hasattr(p, "key") else "" for p in path]
        stacked = names and names[0] == "macro"
        shape = leaf.shape[1:] if stacked else leaf.shape
        lead = (None,) if stacked else ()

        def mk(*spec):
            return P(*(lead + spec))

        last = names[-1] if names else ""
        bspec = shard_dim(mesh, shape[0], dp)
        if last == "S":            # rwkv state (B, H, hd, hd)
            return mk(bspec, shard_dim(mesh, shape[1], m), None, None)
        if last == "h":            # rglru hidden (B, dr)
            return mk(bspec, shard_dim(mesh, shape[1], m))
        if last == "conv":         # (B, W-1, dr)
            return mk(bspec, None, shard_dim(mesh, shape[2], m))
        if last in ("last_x", "last_xc"):
            return mk(bspec, None)
        if len(shape) == 4:        # attention kv cache (B, S, KV, hd)
            kv_ax = shard_dim(mesh, shape[2], m)
            if kv_ax is not None:
                return mk(bspec, None, kv_ax, None)
            return mk(bspec, shard_dim(mesh, shape[1], m), None, None)
        return mk(bspec, *(None,) * (len(shape) - 1))
    return jax.tree_util.tree_map_with_path(walk, cache_tree)


def named(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
