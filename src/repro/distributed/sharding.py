"""Sharding rule engine: TP/EP/SP/DP PartitionSpecs with divisibility
fallbacks.

Philosophy (the SOL layout pass, at mesh scale): every parameter / cache
tensor is assigned a layout by *name + rank* rules, with hard divisibility
guards — a dim is only sharded when its size divides the mesh axis, else the
engine falls back (heads → head_dim → sequence → replicate).  This is what
makes one rule table serve 10 architectures.

Mesh axes: ``model`` (TP/EP/SP) and ``data`` (+ leading ``pod``) for DP.

Two consumers share the rule table:

* the training path (``param_specs`` / ``batch_specs`` / ``cache_specs``)
  assigns PartitionSpecs to params/batch/cache pytrees of
  ``models/backbone.py``;
* the middleware path (:func:`shard_graph`) threads the SAME name+rank
  rules through a SOL IR graph: it propagates a PartitionSpec per node in
  topo order (Megatron-style TP for attention/MLP pairs, DP on the batch
  axis), rewrites every ``node.spec`` to the per-shard LOCAL shape, and
  annotates row-parallel matmuls with the psum the executor lowers inside
  ``shard_map``.  Because elections/autotuning run on the rewritten graph,
  measured timings and pinned Tunable configs key on post-partition shapes
  (see ``Backend.cache_name``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import backbone as B
from ..models.config import ArchConfig

# jax moved shard_map out of experimental (>=0.6) and renamed check_rep →
# check_vma, on independent schedules — detect the kwarg from the signature
# rather than inferring it from where shard_map lives
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                    # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map
try:
    import inspect as _inspect
    _sm_params = _inspect.signature(shard_map).parameters
    SHARD_MAP_NOCHECK = ({"check_vma": False} if "check_vma" in _sm_params
                         else {"check_rep": False} if "check_rep" in _sm_params
                         else {})
except (TypeError, ValueError):          # unintrospectable wrapper
    SHARD_MAP_NOCHECK = {}


class ShardingError(ValueError):
    """A graph cannot be partitioned as requested (a sharded dim reaches an
    op that needs it whole, or head counts do not divide the model axis).
    The message names the node and the fix — never a silent wrong answer."""


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _div(size: int, n: int) -> bool:
    return n > 0 and size % n == 0


def shard_dim(mesh: Mesh, size: int, axes):
    """axes if divisible else None (the engine's universal fallback)."""
    return axes if _div(size, axis_size(mesh, axes)) else None


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# 2-D weights sharded on the output (column-parallel)
_COL = {"wq", "wk", "wv", "wg", "wu", "w_in", "w_gate", "ck", "cr",
        "xwq", "xwk", "xwv", "w1", "wr"}
# 2-D weights sharded on the input (row-parallel)
_ROW = {"wo", "wd", "w_out", "cv", "xwo", "w2"}
# 1-D tensors following a column-parallel output
_COL_BIAS = {"bq", "bk", "bv", "b1", "conv_b", "lam"}
_REPLICATED = {"gain", "bias", "bo", "b2", "router", "u", "w0",
               "gn_gain", "gn_bias", "enc_pos"}


def param_spec(mesh: Mesh, cfg: ArchConfig, path: Tuple[str, ...],
               shape: Tuple[int, ...]) -> P:
    name = path[-1]
    stacked = path[0] == "macro"          # leading n_macro scan dim
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    m = "model"

    def mk(*spec):
        return P(*(lead + spec))

    parent = path[-2] if len(path) >= 2 else ""
    if parent == "moe" or (len(path) >= 2 and "moe" in path):
        if name == "router":
            return mk(None, None)
        # experts (E, D, F) / (E, F, D): expert-parallel on model
        return mk(shard_dim(mesh, body[0], m), None, None)
    if name == "embed":
        return P(shard_dim(mesh, shape[0], m), None)
    if name == "lm_head":
        return P(None, shard_dim(mesh, shape[1], m))
    if name in _COL and len(body) == 2:
        return mk(None, shard_dim(mesh, body[1], m))
    if name in _ROW and len(body) == 2:
        return mk(shard_dim(mesh, body[0], m), None)
    if name == "conv_w":                   # (W, dr)
        return mk(None, shard_dim(mesh, body[1], m))
    if name in ("wa", "wx"):               # (dr, dr) RG-LRU gates
        return mk(None, shard_dim(mesh, body[1], m))
    if name in _COL_BIAS and len(body) == 1:
        return mk(shard_dim(mesh, body[0], m))
    if name.startswith("lora_") or name.startswith("mu_"):
        return mk(*(None,) * len(body))
    if name in _REPLICATED or len(body) == 1:
        return mk(*(None,) * len(body))
    return mk(*(None,) * len(body))


def param_specs(mesh: Mesh, cfg: ArchConfig, params_tree) -> Any:
    """PartitionSpec pytree matching a params(-shaped) pytree."""
    def walk(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p.idx) for p in path)
        return param_spec(mesh, cfg, names, tuple(leaf.shape))
    return jax.tree_util.tree_map_with_path(walk, params_tree)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(mesh: Mesh, cfg: ArchConfig, batch_tree) -> Any:
    dp = dp_axes(mesh)

    def walk(path, leaf):
        b = shard_dim(mesh, leaf.shape[0], dp)
        return P(b, *(None,) * (len(leaf.shape) - 1))
    return jax.tree_util.tree_map_with_path(walk, batch_tree)


def cache_specs(mesh: Mesh, cfg: ArchConfig, cache_tree) -> Any:
    """KV caches: batch on data; kv-heads on model when divisible, else
    sequence-sharded (SP / flash-decoding); recurrent states: channels/heads
    on model."""
    dp = dp_axes(mesh)
    m = "model"

    def walk(path, leaf):
        names = [p.key if hasattr(p, "key") else "" for p in path]
        stacked = names and names[0] == "macro"
        shape = leaf.shape[1:] if stacked else leaf.shape
        lead = (None,) if stacked else ()

        def mk(*spec):
            return P(*(lead + spec))

        last = names[-1] if names else ""
        bspec = shard_dim(mesh, shape[0], dp)
        if last == "S":            # rwkv state (B, H, hd, hd)
            return mk(bspec, shard_dim(mesh, shape[1], m), None, None)
        if last == "h":            # rglru hidden (B, dr)
            return mk(bspec, shard_dim(mesh, shape[1], m))
        if last == "conv":         # (B, W-1, dr)
            return mk(bspec, None, shard_dim(mesh, shape[2], m))
        if last in ("last_x", "last_xc"):
            return mk(bspec, None)
        if len(shape) == 4:        # attention kv cache (B, S, KV, hd)
            kv_ax = shard_dim(mesh, shape[2], m)
            if kv_ax is not None:
                return mk(bspec, None, kv_ax, None)
            return mk(bspec, shard_dim(mesh, shape[1], m), None, None)
        return mk(bspec, *(None,) * (len(shape) - 1))
    return jax.tree_util.tree_map_with_path(walk, cache_tree)


def named(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# the middleware path: PartitionSpec propagation over a SOL IR graph
# ---------------------------------------------------------------------------

def mesh_backend(backend, mesh: Mesh):
    """The per-mesh view of a dispatch-table backend: same ``name`` (so
    tier-0 impls and capabilities match unchanged) but a ``shard_tag``
    qualifying every autotune-cache key.  Without the tag, a per-shard
    bucket could collide with a global-shape bucket — a local pow2 shape
    divided by a pow2 mesh axis IS some other global bucket — and a mesh
    election would silently serve a flat-backend timing."""
    tag = "".join(f"{a}{mesh.shape[a]}" for a in mesh.axis_names)
    return dataclasses.replace(backend, shard_tag=tag)


def _entry(spec: P, i: int, rank: int):
    """The sharding of dim ``i`` (supports negative) under ``spec``; specs
    shorter than the rank are replicated on the trailing dims."""
    if i < 0:
        i += rank
    return spec[i] if 0 <= i < len(spec) else None


def _axes_tuple(e) -> Tuple[str, ...]:
    if e is None:
        return ()
    return (e,) if isinstance(e, str) else tuple(e)


def _local_shape(mesh: Mesh, shape: Tuple[int, ...], spec: P
                 ) -> Tuple[int, ...]:
    return tuple(d // axis_size(mesh, _entry(spec, i, len(shape)))
                 for i, d in enumerate(shape))


def shard_graph(g, mesh: Mesh):
    """Partition a freshly-extracted SOL graph for ``mesh`` — the rule
    table threaded through the middleware rather than bolted on beside it.

    In one topo walk the engine (1) assigns every node a PartitionSpec of
    its GLOBAL shape — DP on the batch dim of inputs, Megatron-style TP for
    attention (wq/wk/wv column-parallel so heads stay shard-local, wo
    row-parallel) and for MLP pairs (column → elementwise → row), KV caches
    sharded on the kv-head axis to match the column-parallel projections —
    then (2) rewrites every ``node.spec`` (and shape-bearing attrs:
    RESHAPE targets, LINEAR ``out_features``) to the per-shard LOCAL shape,
    and (3) annotates row-parallel LINEAR/MATMUL nodes with
    ``attrs['psum_axes']`` — the collective the executor lowers right after
    the partial matmul, BEFORE any downstream bias add (extraction emits
    BIAS_ADD as its own node, so the ordering is structural).

    Because the rewrite happens before ``passes.run_pipeline``, elections,
    autotune lookups, Tunable pinning and ``strict_provenance`` all see
    post-partition shapes; paired with ``mesh_backend``'s cache qualifier,
    mesh timings and flat timings can never alias.

    Every sharding decision is guarded by divisibility (``shard_dim``) and
    falls back to replication; a sharded dim reaching an op that needs it
    whole raises :class:`ShardingError` naming the node.  Returns ``g``
    with ``g.mesh`` / ``g.input_specs`` / ``g.output_specs`` /
    ``g.param_specs`` attached for the shard_map compile."""
    from ..core.ir import OpKind, TensorSpec

    dp = dp_axes(mesh)
    m = "model" if "model" in mesh.axis_names else None
    mp = axis_size(mesh, m)
    spec: Dict[int, P] = {}
    cons = g.consumers()
    param_name = {id(n): name for name, n in g.params.items()}
    order = list(g.topo())

    def pspec(node) -> P:
        s = spec.get(id(node))
        if s is None:
            s = P(*([None] * len(node.spec.shape)))
            spec[id(node)] = s
        return s

    def ent(node, i):
        return _entry(pspec(node), i, len(node.spec.shape))

    # -- global feasibility: head-parallel attention needs every layer's
    #    query AND kv head counts divisible by the model axis (a partially
    #    sharded q/k/v set would make the attention node non-local)
    attn_tp = mp > 1
    for n in order:
        if n.op in (OpKind.ATTENTION, OpKind.DECODE_ATTENTION):
            heads = n.spec.shape[2]
            kv = n.inputs[1].spec.shape[2]
            if heads % mp or kv % mp:
                attn_tp = False

    _LOCAL_CHAIN = {OpKind.BIAS_ADD, OpKind.RELU, OpKind.GELU, OpKind.SILU,
                    OpKind.SIGMOID, OpKind.TANH, OpKind.EXP, OpKind.SOFTPLUS,
                    OpKind.SQRT, OpKind.SCALE, OpKind.SOFTCAP,
                    OpKind.DROPOUT, OpKind.IDENTITY}
    _ELEMENTWISE = _LOCAL_CHAIN - {OpKind.BIAS_ADD}

    def _col_ok(n) -> bool:
        """Column-sharding ``n``'s output feature dim is legal when the
        sharded activation stays shard-local (bias/unary elementwise) until
        a row-parallelizable matmul folds it back — or until the graph edge,
        where shard_map's out_specs gather it (vocab-parallel head)."""
        cur = n
        while True:
            users = cons.get(cur, [])
            if not users:
                return cur in g.outputs
            if len(users) != 1:
                return False
            u = users[0]
            if u.op in _LOCAL_CHAIN:
                cur = u
                continue
            return (u.op in (OpKind.LINEAR, OpKind.MATMUL)
                    and u.inputs[0] is cur
                    and u.inputs[1].op is OpKind.PARAM
                    and _div(u.inputs[1].spec.size
                             // max(u.spec.shape[-1], 1), mp))

    def _attn_proj(n):
        """True when ``n`` is an attention q/k/v projection: its sole
        consumer is a RESHAPE feeding ATTENTION / DECODE_ATTENTION."""
        users = cons.get(n, [])
        if len(users) == 1 and users[0].op is OpKind.RESHAPE:
            nxt = cons.get(users[0], [])
            return (len(nxt) == 1
                    and nxt[0].op in (OpKind.ATTENTION,
                                      OpKind.DECODE_ATTENTION))
        return False

    def _matmul(n):
        x, w = n.inputs[0], n.inputs[1]
        rank = len(x.spec.shape)
        sx = tuple(_entry(pspec(x), i, rank) for i in range(rank))
        xlast = sx[-1]
        out_dim = n.spec.shape[-1]
        # weight orientation: LINEAR params are stored (out, in)
        # framework-style; MATMUL weights are (in, out)
        oi = n.op is OpKind.LINEAR

        def wspec(in_ax, out_ax) -> P:
            return P(out_ax, in_ax) if oi else P(in_ax, out_ax)

        if w.op is not OpKind.PARAM:
            if xlast is not None or ent(w, 0) is not None:
                raise ShardingError(
                    f"{n.name}: contraction dim is sharded but the weight "
                    f"is not a parameter — no rule to row-parallelize it")
            spec[id(n)] = P(*(tuple(sx)[: rank - 1]
                              + (ent(w, -1),)))
            return
        have = spec.get(id(w))
        if xlast is not None:
            # row-parallel: weight sharded on its input dim, partial sums
            # psum'd over the contraction axes right after this node
            want = wspec(xlast, None)
            if have is not None and have != want:
                raise ShardingError(
                    f"{n.name}: shared param "
                    f"{param_name.get(id(w), w.name)!r} already sharded as "
                    f"{have}, row-parallel use needs {want}")
            spec[id(w)] = want
            n.attrs["psum_axes"] = _axes_tuple(xlast)
            spec[id(n)] = P(*(tuple(sx)[: rank - 1] + (None,)))
            return
        col = False
        if m is not None and have is None and _div(out_dim, mp):
            col = attn_tp if _attn_proj(n) else _col_ok(n)
        if col:
            spec[id(w)] = wspec(None, m)
            # batch dims follow the activation; features land on the model axis
            spec[id(n)] = P(*(tuple(sx)[: rank - 1] + (m,)))
        else:
            if have is None:
                spec[id(w)] = wspec(None, None)
            out_ax = _entry(spec[id(w)], 0 if oi else -1,
                            len(w.spec.shape))
            spec[id(n)] = P(*(tuple(sx)[: rank - 1] + (out_ax,)))

    def _reshape(n):
        src = n.inputs[0]
        a, b = src.spec.shape, tuple(n.attrs["shape"])
        sin = pspec(src)
        ra = len(a)
        if len(b) == ra + 1 and a[:-1] == b[:-2] and a[-1] == b[-2] * b[-1]:
            # split last dim, e.g. (B,S,H·hd) → (B,S,H,hd): a feature shard
            # holds whole heads (attn_tp guarantees H % mp == 0), so the
            # shard moves to the head axis
            spec[id(n)] = P(*(tuple(_entry(sin, i, ra) for i in range(ra))
                              + (None,)))
            return
        if len(b) == ra - 1 and a[:-2] == b[:-1] and b[-1] == a[-2] * a[-1]:
            # merge last two dims, e.g. (B,S,H,hd) → (B,S,H·hd)
            if _entry(sin, -1, ra) is not None:
                raise ShardingError(
                    f"{n.name}: cannot merge a sharded trailing dim")
            spec[id(n)] = P(*tuple(_entry(sin, i, ra)
                                   for i in range(ra - 1)))
            return
        if any(_entry(sin, i, ra) is not None for i in range(ra)
               if not (i == 0 and b and b[0] == a[0])):
            raise ShardingError(
                f"{n.name}: general reshape of a sharded tensor "
                f"({a} → {b} under {sin}) has no propagation rule")
        lead = _entry(sin, 0, ra) if b and a and b[0] == a[0] else None
        spec[id(n)] = P(*((lead,) + (None,) * (len(b) - 1)))

    def _attention(n):
        names = ("q", "k", "v", "k_new", "v_new")
        head_ents = {ent(q, 2) for q in n.inputs
                     if len(q.spec.shape) == 4}
        if len(head_ents) > 1:
            raise ShardingError(
                f"{n.name}: inconsistent head sharding across operands "
                f"({head_ents}) — the model axis must divide every "
                f"layer's n_heads and n_kv_heads, or none ({names})")
        spec[id(n)] = pspec(n.inputs[0])

    for n in order:
        op = n.op
        shape = n.spec.shape
        rank = len(shape)
        if op is OpKind.INPUT:
            bspec = shard_dim(mesh, shape[0], dp) if rank else None
            if (rank == 4 and m is not None
                    and n.name.endswith(("k_cache", "v_cache"))):
                kv = shard_dim(mesh, shape[2], m) if attn_tp else None
                spec[id(n)] = P(bspec, None, kv, None)
            else:
                spec[id(n)] = P(*((bspec,) + (None,) * (rank - 1)))
            continue
        if op in (OpKind.PARAM, OpKind.CONST):
            continue                       # params: assigned by consumers;
                                           # consts: replicated (lazily)
        if op in (OpKind.LINEAR, OpKind.MATMUL):
            _matmul(n)
        elif op is OpKind.RESHAPE:
            _reshape(n)
        elif op in (OpKind.ATTENTION, OpKind.DECODE_ATTENTION):
            _attention(n)
        elif op is OpKind.BIAS_ADD:
            x, b = n.inputs[0], n.inputs[1]
            ax = n.attrs.get("axis", -1)
            want = P(ent(x, ax))
            have = spec.get(id(b))
            if have is not None and have != want:
                raise ShardingError(
                    f"{n.name}: bias already sharded as {have}, "
                    f"needs {want}")
            spec[id(b)] = want
            spec[id(n)] = pspec(x)
        elif op in (OpKind.LAYERNORM, OpKind.RMSNORM):
            if ent(n.inputs[0], -1) is not None:
                raise ShardingError(
                    f"{n.name}: normalization over a model-sharded feature "
                    f"dim — insert the psum/row-parallel matmul before the "
                    f"norm (serving graphs normalize replicated "
                    f"activations)")
            spec[id(n)] = pspec(n.inputs[0])
        elif op is OpKind.SOFTMAX:
            if ent(n.inputs[0], n.attrs.get("axis", -1)) is not None:
                raise ShardingError(
                    f"{n.name}: softmax over a sharded axis")
            spec[id(n)] = pspec(n.inputs[0])
        elif op in _ELEMENTWISE:
            spec[id(n)] = pspec(n.inputs[0])
        elif op is OpKind.TIME_SHIFT:
            if ent(n.inputs[0], 1) is not None:
                raise ShardingError(f"{n.name}: shift along a sharded axis")
            spec[id(n)] = pspec(n.inputs[0])
        elif op in (OpKind.ADD, OpKind.SUB, OpKind.MUL, OpKind.DIV):
            out: List[Any] = []
            for i in range(rank):
                ents = []
                for inp in n.inputs:
                    off = rank - len(inp.spec.shape)
                    if i - off >= 0 and inp.spec.shape[i - off] > 1:
                        ents.append(ent(inp, i - off))
                if len(set(ents)) > 1:
                    raise ShardingError(
                        f"{n.name}: operands disagree on dim {i} sharding "
                        f"({ents})")
                out.append(ents[0] if ents else None)
            spec[id(n)] = P(*out)
        elif op is OpKind.TRANSPOSE:
            sin = pspec(n.inputs[0])
            ri = len(n.inputs[0].spec.shape)
            spec[id(n)] = P(*(_entry(sin, p, ri) for p in n.attrs["perm"]))
        elif op is OpKind.FLATTEN:
            if any(ent(n.inputs[0], i) is not None
                   for i in range(1, len(n.inputs[0].spec.shape))):
                raise ShardingError(f"{n.name}: flatten of a sharded tensor")
            spec[id(n)] = P(ent(n.inputs[0], 0), None)
        else:
            # batch-preserving default (convs, pools, norms over channels,
            # scans): model-sharded inputs have no rule here
            for inp in n.inputs:
                ri = len(inp.spec.shape)
                if any(_entry(pspec(inp), i, ri) is not None
                       for i in range(1, ri)):
                    raise ShardingError(
                        f"{n.name} ({op.value}): no sharding-propagation "
                        f"rule for a model-sharded operand")
            lead = ent(n.inputs[0], 0) if n.inputs and rank else None
            spec[id(n)] = P(*((lead,) + (None,) * max(rank - 1, 0)))

    # -- rewrite every node to its per-shard LOCAL shape -------------------
    for n in order:
        s = pspec(n)
        local = _local_shape(mesh, n.spec.shape, s)
        if local != n.spec.shape:
            n.spec = dataclasses.replace(n.spec, shape=local)
        if n.op is OpKind.RESHAPE:
            n.attrs["shape"] = local
        if n.op is OpKind.LINEAR:
            f = axis_size(mesh, _entry(s, -1, len(local)))
            if f > 1:
                n.attrs["out_features"] = n.attrs["out_features"] // f

    g.mesh = mesh
    g.input_specs = [spec[id(i)] for i in g.inputs]
    g.output_specs = [pspec(o) for o in g.outputs]
    g.param_specs = {name: pspec(node) for name, node in g.params.items()}
    g.validate()
    return g
