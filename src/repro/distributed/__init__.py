from . import sharding, steps, zero, compress

__all__ = ["sharding", "steps", "zero", "compress"]
