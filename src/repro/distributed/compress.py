"""Gradient compression for the cross-pod all-reduce.

bf16 compression halves DCN/ICI gradient traffic; error-feedback (optional)
keeps the quantization bias bounded.  Applied *inside* the jitted step:
grads are cast before the (XLA-inserted) all-reduce boundary by donating the
cast — in GSPMD terms the psum runs on the compressed dtype."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_grads(grads, method: str = "none"):
    if method == "none":
        return grads
    if method == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16) if g.dtype == jnp.float32 else g,
            grads)
    raise ValueError(f"unknown compression {method}")


def decompress_grads(grads, method: str = "none"):
    if method == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    return grads
