"""Distributed train / serve steps (pjit factories).

``make_train_step``: value_and_grad over the backbone loss, optional
microbatch grad-accumulation scan (keeps the per-layer reduce-scatter inside
the scan so XLA's latency-hiding scheduler overlaps collectives with the
next microbatch's compute), optional bf16 gradient compression across the
DP axes, AdamW with ZeRO-sharded moments, donated state.

``make_serve_steps``: prefill + single-token decode with donated KV caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import backbone as B
from ..models.config import ArchConfig
from ..optim import AdamWConfig, adamw_update, cosine_schedule, init_opt_state
from . import compress as C
from . import ctx
from . import sharding as S
from . import zero as Z


@dataclasses.dataclass(frozen=True)
class StepOptions:
    remat: bool = True
    microbatch: int = 1               # grad-accumulation factor
    grad_compression: str = "none"    # 'none' | 'bf16'
    zero: bool = True                 # ZeRO-1 moment sharding
    moment_dtype: str = "float32"
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    aux_weight: float = 0.01


def make_train_state_specs(mesh: Mesh, cfg: ArchConfig, opts: StepOptions):
    pshapes = B.param_specs(cfg)
    pspecs = S.param_specs(mesh, cfg, pshapes)
    if opts.zero:
        ospecs = Z.zero_opt_specs(mesh, pspecs, pshapes)
    else:
        from ..optim import opt_state_specs
        ospecs = opt_state_specs(pspecs)
    return {"params": pspecs, "opt": ospecs, "step": P()}


def init_train_state(cfg: ArchConfig, opts: StepOptions, key):
    params = B.init_params(cfg, key)
    ocfg = AdamWConfig(lr=opts.lr, moment_dtype=opts.moment_dtype)
    return {"params": params, "opt": init_opt_state(params, ocfg),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(cfg: ArchConfig, opts: StepOptions):
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, opts),
        jax.random.PRNGKey(0))


def make_train_step(mesh: Mesh, cfg: ArchConfig, opts: StepOptions
                    ) -> Callable:
    ocfg = AdamWConfig(lr=opts.lr, moment_dtype=opts.moment_dtype)

    def loss(params, batch):
        return B.loss_fn(cfg, params, batch, remat=opts.remat,
                         aux_weight=opts.aux_weight)

    def train_step(state, batch):
        with ctx.use_mesh(mesh):
            return _train_step(state, batch)

    def _train_step(state, batch):
        params = state["params"]
        if opts.microbatch > 1:
            # split batch leading dim into microbatches and scan
            def resh(x):
                bsz = x.shape[0]
                mb = opts.microbatch
                return x.reshape(mb, bsz // mb, *x.shape[1:])
            mbatch = jax.tree.map(resh, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(
                    params, mb)
                g = jax.tree.map(jnp.add, g_acc, g)
                return (g, l_acc + l), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), metrics = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32)), mbatch)
            grads = jax.tree.map(lambda g: g / opts.microbatch, grads)
            lval = lsum / opts.microbatch
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        else:
            (lval, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)

        grads = C.compress_grads(grads, opts.grad_compression)
        grads = C.decompress_grads(grads, opts.grad_compression)
        lr = cosine_schedule(state["step"], peak_lr=opts.lr,
                             warmup=opts.warmup, total=opts.total_steps)
        new_params, new_opt, om = adamw_update(params, grads, state["opt"],
                                               ocfg, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": lval, **metrics, **om, "lr": lr}
        return new_state, out_metrics

    state_specs = make_train_state_specs(mesh, cfg, opts)
    bshapes = None  # batch specs are computed at call sites from shapes
    return train_step, state_specs


def jit_train_step(mesh: Mesh, cfg: ArchConfig, opts: StepOptions,
                   batch_shapes) -> Tuple[Any, Any, Any]:
    """Returns (jitted step, state_specs, batch_specs)."""
    step_fn, state_specs = make_train_step(mesh, cfg, opts)
    batch_specs = S.batch_specs(mesh, cfg, batch_shapes)
    metric_specs = None
    jitted = jax.jit(
        step_fn,
        in_shardings=(S.named(mesh, state_specs),
                      S.named(mesh, batch_specs)),
        out_shardings=(S.named(mesh, state_specs), None),
        donate_argnums=(0,),
    )
    return jitted, state_specs, batch_specs


# ---------------------------------------------------------------------------
# SOL-pipeline training: fwd AND bwd ride the elected graph
# ---------------------------------------------------------------------------

def make_sol_train_step(model, opts: StepOptions,
                        loss_fn: Optional[Callable] = None
                        ) -> Tuple[Callable, Callable]:
    """Train step over a ``SolModel`` compiled with ``training=True``:
    ``jax.value_and_grad`` of the loss differentiates straight through the
    elected graph, where every grad-registered node is a ``custom_vjp``
    pairing its elected forward with its elected backward — both directions
    run tuned, provenance-audited kernels.  Mesh-compiled models work
    unchanged: the psum collectives sit outside the per-node wrappers, so
    AD transposes them into the psum-correct gradient collectives.

    Returns ``(train_step, init_state)``; ``train_step(state, batch)`` with
    ``batch = {"x": ..., "y": ...}`` reuses the same AdamW + cosine
    schedule as the backbone trainer (``optim/``)."""
    ocfg = AdamWConfig(lr=opts.lr, moment_dtype=opts.moment_dtype)

    def default_loss(out, batch):
        tgt = batch["y"].astype(jnp.float32)
        return ((out.astype(jnp.float32) - tgt) ** 2).mean()

    lf = loss_fn or default_loss

    def loss(params, batch):
        return lf(model._fn(params, batch["x"]), batch)

    def init_state(params: Optional[Dict[str, Any]] = None):
        p = dict(params) if params is not None \
            else dict(model._params_for_call())
        return {"params": p, "opt": init_opt_state(p, ocfg),
                "step": jnp.zeros((), jnp.int32)}

    def train_step(state, batch):
        lval, grads = jax.value_and_grad(loss)(state["params"], batch)
        lr = cosine_schedule(state["step"], peak_lr=opts.lr,
                             warmup=opts.warmup, total=opts.total_steps)
        new_params, new_opt, om = adamw_update(state["params"], grads,
                                               state["opt"], ocfg, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": lval, "lr": lr, **om}

    return train_step, init_state


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(mesh: Mesh, cfg: ArchConfig):
    def prefill_step(params, batch):
        with ctx.use_mesh(mesh):
            logits, _ = B.prefill(cfg, params, batch)
        return logits

    return prefill_step


def make_decode_step(mesh: Mesh, cfg: ArchConfig):
    def decode(params, cache, tokens, pos, enc_out=None):
        with ctx.use_mesh(mesh):
            if cfg.enc_dec is not None:
                return B.decode_step(cfg, params, cache, tokens, pos,
                                     enc_out=enc_out)
            return B.decode_step(cfg, params, cache, tokens, pos)

    return decode


def jit_serve_steps(mesh: Mesh, cfg: ArchConfig, batch: int, max_seq: int,
                    prefill_shapes=None):
    pshapes = B.param_specs(cfg)
    pspecs = S.param_specs(mesh, cfg, pshapes)
    cshapes = B.cache_specs(cfg, batch, max_seq)
    cspecs = S.cache_specs(mesh, cfg, cshapes)
    dp = S.dp_axes(mesh)
    tok_spec = P(S.shard_dim(mesh, batch, dp), None)

    decode = make_decode_step(mesh, cfg)
    args_shard = [S.named(mesh, pspecs), S.named(mesh, cspecs),
                  NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())]
    if cfg.enc_dec is not None:
        enc_spec = P(S.shard_dim(mesh, batch, dp), None, None)
        args_shard.append(NamedSharding(mesh, enc_spec))
    jitted_decode = jax.jit(
        decode,
        in_shardings=tuple(args_shard),
        out_shardings=(NamedSharding(mesh, P(S.shard_dim(mesh, batch, dp),
                                             None, "model")),
                       S.named(mesh, cspecs)),
        donate_argnums=(1,),
    )
    return jitted_decode, pspecs, cspecs
