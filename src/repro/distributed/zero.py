"""ZeRO-1: shard optimizer moments over the data-parallel axes on top of TP.

For each moment tensor we find the largest dim not already model-sharded
whose size divides the DP world, and add the DP axes there.  Under GSPMD
this turns the weight-update into reduce-scatter(grad) → sharded update →
all-gather(param), which XLA emits automatically from the sharding
annotations — the standard ZeRO-1 dataflow."""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from .sharding import axis_size, dp_axes


def zero_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    dp = dp_axes(mesh)
    n = axis_size(mesh, dp)
    if n <= 1:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    # choose the largest unsharded, divisible dim
    best, best_size = -1, 0
    for i, (s, dim) in enumerate(zip(parts, shape)):
        if s is None and dim % n == 0 and dim > best_size:
            best, best_size = i, dim
    if best < 0:
        return spec
    parts[best] = dp if len(dp) > 1 else dp[0]
    return P(*parts)


def zero_opt_specs(mesh: Mesh, param_spec_tree, params_shape_tree) -> Any:
    def walk(spec, shaped):
        return zero_spec(mesh, spec, tuple(shaped.shape))
    moment = jax.tree.map(walk, param_spec_tree, params_shape_tree,
                          is_leaf=lambda x: isinstance(x, P))
    return {"m": moment, "v": moment, "step": P()}
