"""Activation-sharding context: lets model code place GSPMD constraints
without threading mesh objects through every layer.

``constrain(x, ("dp", None, "model"))`` resolves logical axis names against
the active mesh ("dp" → ("pod","data") when present), checks divisibility
(falls back to None per-dim — same policy as the parameter rule engine),
and applies ``jax.lax.with_sharding_constraint``.  Outside a context it is
a no-op, so single-device tests and smoke runs never pay for it.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = _mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def _resolve(mesh: Mesh, name) -> Optional[Tuple[str, ...]]:
    if name is None:
        return None
    if name == "dp":
        axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        return axes or None
    if isinstance(name, str):
        return (name,) if name in mesh.axis_names else None
    return tuple(a for a in name if a in mesh.axis_names) or None


def constrain(x: jax.Array, spec: Sequence) -> jax.Array:
    mesh = _mesh()
    if mesh is None:
        return x
    parts = []
    for dim, name in zip(x.shape, spec):
        axes = _resolve(mesh, name)
        if axes is None:
            parts.append(None)
            continue
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        parts.append(axes if n > 0 and dim % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
