from .adamw import (AdamWConfig, global_norm, init_opt_state, adamw_update,
                    opt_state_specs)
from .schedule import cosine_schedule

__all__ = ["AdamWConfig", "global_norm", "init_opt_state", "adamw_update",
           "opt_state_specs", "cosine_schedule"]
