"""AdamW with sharded moments (ZeRO-ready) and optional bf16 moment storage
(needed to fit the 1T-param MoE on 512 × 16 GB chips)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # 'bfloat16' halves optimizer memory


def init_opt_state(params, ocfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(ocfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_spec_tree) -> Dict[str, Any]:
    from jax.sharding import PartitionSpec as P
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, state, ocfg: AdamWConfig, lr: jax.Array
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9)) \
        if ocfg.grad_clip else 1.0
    dt = jnp.dtype(ocfg.moment_dtype)
    b1, b2 = ocfg.beta1, ocfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + ocfg.eps) + \
            ocfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(dt), v32.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm}
