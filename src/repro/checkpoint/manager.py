"""Fault-tolerant, mesh-agnostic checkpointing.

Design for 1000+ nodes:
  * arrays are saved with their *logical* pytree paths, not device layouts —
    a restore onto a different mesh (elastic scaling: pod count changed)
    re-lays-out via the current sharding rules;
  * manifest-last protocol: array files are written first, the manifest
    (step, tree structure, hashes) is atomically renamed into place last, so
    a node failure mid-save never corrupts the latest checkpoint;
  * async save: the host thread serializes a device-fetched copy while
    training continues (double-buffered);
  * keep-last-k garbage collection.

On a real cluster each host writes only its data-parallel shard and the
manifest records the global shape (here single-process: full arrays).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> List:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _path_str(kp) -> str:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    base = Path(ckpt_dir)
    tmp = base / f"step_{step:08d}.tmp"
    final = base / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    manifest: Dict[str, Any] = {"step": step, "arrays": {},
                                "time": time.time()}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for kp, leaf in flat:
        name = _path_str(kp)
        arr = np.asarray(jax.device_get(leaf))
        fname = hashlib.sha1(name.encode()).hexdigest()[:16] + ".npy"
        np.save(tmp / fname, arr)
        manifest["arrays"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with (tmp / "manifest.json").open("w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    _gc(base, keep)
    return str(final)


def _gc(base: Path, keep: int) -> None:
    steps = sorted(p for p in base.glob("step_????????") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = sorted(base.glob("step_????????"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir: str, tree_like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``; if ``shardings`` is
    given, arrays are placed with those shardings (elastic re-shard)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in
                      jax.tree_util.tree_flatten_with_path(shardings)[0]]

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for i, (kp, leaf) in enumerate(flat):
        name = _path_str(kp)
        if name not in manifest["arrays"]:
            raise KeyError(f"checkpoint missing array {name}")
        info = manifest["arrays"][name]
        arr = np.load(d / info["file"])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != {leaf.shape}")
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out)


class CheckpointManager:
    """Async double-buffered checkpointing with restart/resume."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def maybe_save(self, step: int, tree: Any, block: bool = False) -> bool:
        if step % self.interval:
            return False
        self.wait()
        # device_get on the main thread (consistent snapshot), serialize off
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree, keep=self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like: Any, shardings: Any = None):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.ckpt_dir, tree_like,
                                        step=step, shardings=shardings)
