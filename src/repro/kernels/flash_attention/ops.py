"""Public wrapper: accepts model-layout (B, S, H, hd) tensors."""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core.ir import Node, OpKind
from .kernel import flash_attention_call


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, cap: float = 0.0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) → (B, S, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_call(qt, kt, vt, causal=causal, window=window,
                             cap=cap, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


# -- dispatch-table entries: OpKind.ATTENTION over (q, k, v) nodes -----------

def _attrs(n: Node) -> dict:
    return dict(causal=n.attrs.get("causal", True),
                window=n.attrs.get("window", 0),
                cap=n.attrs.get("cap", 0.0))


def _attention_pallas_impl(n: Node, vals: Sequence[jax.Array],
                           backend: "registry.Backend") -> jax.Array:
    q, k, v = vals
    return flash_attention(q, k, v, interpret=backend.interpret, **_attrs(n))


def _attention_ref_impl(n: Node, vals: Sequence[jax.Array],
                        backend: "registry.Backend") -> jax.Array:
    from .ref import flash_attention_ref
    q, k, v = vals
    o = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), **_attrs(n))
    return o.transpose(0, 2, 1, 3)


registry.register_shared_impl(
    OpKind.ATTENTION, _attention_pallas_impl, name="pallas.flash_attention",
    requires=("pallas",),
    supports=lambda n: len(n.spec.shape) == 4)
registry.register_reference_impl(
    OpKind.ATTENTION, _attention_ref_impl, name="ref.attention",
    memory="roundtrip")   # materializes the S×S score matrix
