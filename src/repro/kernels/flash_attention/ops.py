"""Public wrapper: accepts model-layout (B, S, H, hd) tensors.

The Pallas impl declares a ``Tunable`` over the (bq, bk) block sizes: the
autotune sweep measures every candidate pair and the election pass pins the
winner on the node as ``node.attrs['attn_block']``, which the impl reads
back at lowering time."""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .._util import round_up
from .kernel import DEFAULT_BK, DEFAULT_BQ, flash_attention_call


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, cap: float = 0.0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) → (B, S, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_call(qt, kt, vt, causal=causal, window=window,
                             cap=cap, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


# -- dispatch-table entries: OpKind.ATTENTION over (q, k, v) nodes -----------

def _attrs(n: Node) -> dict:
    return dict(causal=n.attrs.get("causal", True),
                window=n.attrs.get("window", 0),
                cap=n.attrs.get("cap", 0.0))


def attn_tune_space(n: Node, hw) -> List[Tuple[int, int]]:
    """Candidate (bq, bk) block pairs for one ATTENTION node: powers of two
    from one VPU row block up to the default block, clamped to the (8-sublane
    rounded) sequence length, deduplicated, and gated on the f32 logits tile
    plus the q/k/v/accumulator blocks fitting in half of VMEM."""
    b, s, h, hd = n.spec.shape
    cap = min(DEFAULT_BQ, round_up(s, 8))
    cands: List[Tuple[int, int]] = []
    seen = set()
    size = 32
    sizes = []
    while size <= max(DEFAULT_BQ, DEFAULT_BK):
        sizes.append(size)
        size *= 2
    for bq in sizes:
        for bk in sizes:
            cfg = (min(bq, cap), min(bk, cap))
            # logits/mask (bq, bk) f32 + q/acc (bq, hd) + k/v blocks (bk, hd)
            working = 4 * (2 * cfg[0] * cfg[1]
                           + 2 * cfg[0] * hd + 2 * cfg[1] * hd)
            if cfg in seen or working > hw.vmem_bytes // 2:
                continue
            seen.add(cfg)
            cands.append(cfg)
    return cands


def _attention_pallas_impl(n: Node, vals: Sequence[jax.Array],
                           backend: "registry.Backend") -> jax.Array:
    q, k, v = vals
    cfg = n.attrs.get("attn_block")
    bq, bk = (int(cfg[0]), int(cfg[1])) if cfg else (DEFAULT_BQ, DEFAULT_BK)
    return flash_attention(q, k, v, bq=bq, bk=bk,
                           interpret=backend.interpret, **_attrs(n))


def _attention_ref_impl(n: Node, vals: Sequence[jax.Array],
                        backend: "registry.Backend") -> jax.Array:
    from .ref import flash_attention_ref
    q, k, v = vals
    o = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), **_attrs(n))
    return o.transpose(0, 2, 1, 3)


registry.register_shared_impl(
    OpKind.ATTENTION, _attention_pallas_impl, name="pallas.flash_attention",
    requires=("pallas",),
    supports=lambda n: len(n.spec.shape) == 4,
    tunable=Tunable("attn_block", attn_tune_space))
registry.register_reference_impl(
    OpKind.ATTENTION, _attention_ref_impl, name="ref.attention",
    memory="roundtrip")   # materializes the S×S score matrix
