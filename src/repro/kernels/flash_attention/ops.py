"""Public wrapper: accepts model-layout (B, S, H, hd) tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_call


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, cap: float = 0.0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) → (B, S, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_call(qt, kt, vt, causal=causal, window=window,
                             cap=cap, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
