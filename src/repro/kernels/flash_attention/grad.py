"""Flash-attention backward as a first-class dispatch-table impl.

The canonical home of the chunked flash backward math: ``models/flash.py``'s
eager ``flash_mha`` VJP delegates here, and the registry-backed gradient path
(``OpKind.ATTENTION`` backward election) wraps the same scans — eager and
elected backwards cannot drift.

Memory story (why this beats AD of the forward): AD through the
online-softmax KV-chunk scan saves every per-chunk probability tensor
(B,KV,G,Sq,C f32) across the scan.  The flash backward instead keeps O(S)
residuals — here the *default* registry residuals (q, k, v, o) — recomputes
the logsumexp rows with a cheap m/l-only sweep, then per chunk:

  D = Σ do·o;  p = exp(softcap(qkᵀ) − L);
  dv = pᵀdo;  ds = p⊙(do vᵀ − D);  through-softcap chain;
  dq accumulated, dk/dv emitted per chunk.

The KV-chunk length is the backward's own ``Tunable``
(``node.attrs['attn_block_bwd']``), swept and elected independently of the
forward's (bq, bk) blocks.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core import executor
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .._util import round_up

Array = jax.Array

DEFAULT_CHUNK = 1024


def chunks(x: Array, nc: int, c: int):
    b = x.shape[0]
    return x.reshape(b, nc, c, *x.shape[2:]).transpose(
        1, 0, 2, *range(3, x.ndim + 1))


def mask_for(sq: int, c: int, j0: Array, causal: bool, window: int,
             skv: int):
    """(Sq, C) validity mask for the chunk starting at kv position j0."""
    qp = jnp.arange(sq)[:, None]
    kp = j0 + jnp.arange(c)[None, :]
    m = kp < skv
    if causal:
        m &= qp >= kp
    if window:
        m &= qp - kp < window
    return m


def _pad_kv(k: Array, v: Array, nc: int, chunk: int):
    pad = nc * chunk - k.shape[1]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, v


def fwd_scan(qg: Array, k: Array, v: Array, *, causal: bool, window: int,
             cap: float, chunk: int) -> Tuple[Array, Array]:
    """Online-softmax forward.  qg: (B,Sq,KV,G,hd); k, v: (B,Skv,KV,hd)
    → (o: (B,KV,G,Sq,hd) f32, lse: (B,KV,G,Sq) f32)."""
    b, sq, kvh, g, hd = qg.shape
    skv = k.shape[1]
    nc = (skv + chunk - 1) // chunk
    k, v = _pad_kv(k, v, nc, chunk)
    kc = chunks(k, nc, chunk)
    vc = chunks(v, nc, chunk)
    scale = 1.0 / math.sqrt(hd)

    def step(carry, xs):
        m, l, acc = carry
        j, kb, vb = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        if cap:
            logits = jnp.tanh(logits / cap) * cap
        msk = mask_for(sq, chunk, j * chunk, causal, window, skv)
        logits = jnp.where(msk[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, g, sq), jnp.float32),
            jnp.zeros((b, kvh, g, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (jnp.arange(nc), kc, vc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (B,KV,G,Sq)
    return o, lse


def lse_scan(qg: Array, k: Array, *, causal: bool, window: int,
             cap: float, chunk: int) -> Array:
    """Recompute only the logsumexp rows (no p·v accumulation) — what the
    registry backward needs when the fwd residuals are just (q, k, v, o)."""
    b, sq, kvh, g, hd = qg.shape
    skv = k.shape[1]
    nc = (skv + chunk - 1) // chunk
    pad = nc * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = chunks(k, nc, chunk)
    scale = 1.0 / math.sqrt(hd)

    def step(carry, xs):
        m, l = carry
        j, kb = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        if cap:
            logits = jnp.tanh(logits / cap) * cap
        msk = mask_for(sq, chunk, j * chunk, causal, window, skv)
        logits = jnp.where(msk[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        l_new = l * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(-1)
        return (m_new, l_new), None

    init = (jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, g, sq), jnp.float32))
    (m, l), _ = jax.lax.scan(step, init, (jnp.arange(nc), kc))
    return m + jnp.log(jnp.maximum(l, 1e-30))


def bwd_scan(q: Array, k: Array, v: Array, lse: Array, dsum: Array,
             do: Array, *, causal: bool, window: int, cap: float,
             chunk: int) -> Tuple[Array, Array, Array]:
    """Chunked flash backward.  q, do: (B,Sq,H,hd); k, v: (B,Skv,KV,hd);
    lse, dsum: (B,KV,G,Sq) f32 → (dq, dk, dv) in the primal dtypes."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    skv = k.shape[1]
    nc = (skv + chunk - 1) // chunk
    kp, vp = _pad_kv(k, v, nc, chunk)
    kc = chunks(kp, nc, chunk)
    vc = chunks(vp, nc, chunk)
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    dog = do.reshape(b, sq, kvh, g, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)           # (B,KV,G,Sq,hd)

    def step(dq_acc, xs):
        j, kb, vb = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        if cap:
            capped = jnp.tanh(logits / cap) * cap
        else:
            capped = logits
        msk = mask_for(sq, chunk, j * chunk, causal, window, skv)
        capped = jnp.where(msk[None, None, None], capped, -1e30)
        p = jnp.exp(capped - lse[..., None])            # (B,KV,G,Sq,C)
        dv = jnp.einsum("bkgqs,bkgqd->bskd", p, dog)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", dog, vb.astype(jnp.float32))
        ds = p * (dp - dsum[..., None])                 # grad wrt capped
        if cap:
            ds = ds * (1.0 - (capped / cap) ** 2)
        ds = jnp.where(msk[None, None, None], ds, 0.0)
        dq_c = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                          kb.astype(jnp.float32)) * scale
        dk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg) * scale
        return dq_acc + dq_c, (dk, dv)

    dq0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (jnp.arange(nc), kc, vc))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, kvh, hd)[:, :skv]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, kvh, hd)[:, :skv]
    return (dq.reshape(b, sq, h, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


# -- dispatch-table entry: backward of OpKind.ATTENTION over (q, k, v) -------

def attn_bwd_tune_space(n: Node, hw) -> List[Tuple[int]]:
    """Candidate KV-chunk lengths for the backward scan: powers of two
    clamped to the (lane-rounded) sequence length, deduplicated."""
    if len(n.spec.shape) != 4:
        return []
    s = n.spec.shape[1]
    cap_len = round_up(s, 128)
    return [(c,) for c in sorted({min(c, cap_len)
                                  for c in (128, 256, 512, 1024)})]


def _attention_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    (q, k, v), o = res
    cfg = n.attrs.get("attn_block_bwd")
    chunk = int(cfg[0]) if cfg else DEFAULT_CHUNK
    causal = n.attrs.get("causal", True)
    window = n.attrs.get("window", 0)
    cap = n.attrs.get("cap", 0.0)
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    lse = lse_scan(qg, k, causal=causal, window=window, cap=cap, chunk=chunk)
    og = o.reshape(b, sq, kvh, g, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)
    dog = ct.reshape(b, sq, kvh, g, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)
    dsum = (dog * og).sum(-1)                           # (B,KV,G,Sq)
    return bwd_scan(q, k, v, lse, dsum, ct, causal=causal, window=window,
                    cap=cap, chunk=chunk)


registry.register_shared_grad_impl(
    OpKind.ATTENTION, _attention_grad_impl, name="flash.attention_bwd",
    supports=lambda n: len(n.spec.shape) == 4,
    tunable=Tunable("attn_block_bwd", attn_bwd_tune_space))
registry.register_reference_grad_impl(
    OpKind.ATTENTION, executor.reference_vjp_grad,
    name="ref.attention_bwd", memory="roundtrip")
