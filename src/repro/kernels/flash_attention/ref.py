"""Pure-jnp oracle: direct masked-softmax GQA attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        cap: float = 0.0) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg,
                        k.astype(jnp.float32)) * scale
    if cap:
        logits = jnp.tanh(logits / cap) * cap
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[:, None] - pos[None, :] < window
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(b, h, s, hd).astype(q.dtype)
