"""Flash attention (TPU Pallas): causal GQA with optional local window and
logit softcap — the DNN-module flavour of the chunked online-softmax scan in
``models.layers``.

Grid: (batch, q_head, q_block).  The kv-head index is derived from the
q-head index (GQA: h // group).  K/V for one kv head live in VMEM whole
(S·hd·2 B ≤ 8 MiB at 32k×128 bf16); the kernel streams kv blocks out of
them with an online-softmax carry in VREGs.  Causality bounds the kv loop
dynamically — upper = ceil((q_hi+1)/bk) — so the wasted-block count is zero.

BlockSpecs:
  q:   (1, 1, bq, hd)   index (b, h, i) -> (b, h, i, 0)
  k/v: (1, 1, S,  hd)   index (b, h, i) -> (b, h // group, 0, 0)
  o:   (1, 1, bq, hd)
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._util import round_up as _round_up

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG = -1e30


def _kernel(bq: int, bk: int, causal: bool, window: int, cap: float,
            scale: float, s_len: int, q_ref, k_ref, v_ref, o_ref):
    i = pl.program_id(2)
    s = k_ref.shape[2]
    nk = s // bk
    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale        # (bq, hd)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        vb = v_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bq, bk)
        if cap:
            logits = jnp.tanh(logits / cap) * cap
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        if s_len != s:      # ragged tail: padded key positions contribute 0
            mask &= k_pos < s_len
        logits = jnp.where(mask, logits, NEG)
        m_new = jnp.maximum(m, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    hd = q_ref.shape[3]
    init = (jnp.full((bq,), -jnp.inf, jnp.float32),
            jnp.zeros((bq,), jnp.float32),
            jnp.zeros((bq, hd), jnp.float32))
    if causal:
        hi = jnp.minimum(nk, pl.cdiv((i + 1) * bq, bk))
        lo = jnp.maximum(0, (i * bq - window) // bk) if window else 0
    else:
        hi, lo = nk, 0
    m, l, acc = jax.lax.fori_loop(lo, hi, body, init)
    o = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0, :, :] = o.astype(o_ref.dtype)


def flash_attention_call(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         cap: float = 0.0, bq: int = DEFAULT_BQ,
                         bk: int = DEFAULT_BK,
                         interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd).  Returns (B, H, S, hd).

    Ragged sequence lengths are zero-padded up to the block grid and sliced
    back after the call (like ``kernels/matmul``): padded *key* positions
    are masked inside the kernel (a zero-padded key would score logit 0,
    not -inf), while padded *query* rows compute garbage that the final
    slice drops.
    """
    b, h, s, hd = q.shape
    kv = k.shape[1]
    group = h // kv
    bq = min(bq, _round_up(s, 8))     # keep the 8-sublane alignment for
    bk = min(bk, _round_up(s, 8))     # short sequences instead of bq = s
    if max(bq, bk) % min(bq, bk):     # incommensurate pair: collapse to the
        bq = bk = min(bq, bk)         # smaller instead of an lcm-sized pad
    step = max(bq, bk)                # padded S must divide both blocks
    sp = _round_up(s, step)
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    scale = 1.0 / math.sqrt(hd)
    grid = (b, h, sp // bq)
    kernel = functools.partial(_kernel, bq, bk, causal, window, cap, scale, s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, sp, hd),
                         lambda b_, h_, i, g=group: (b_, h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, sp, hd),
                         lambda b_, h_, i, g=group: (b_, h_ // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :] if sp != s else out
