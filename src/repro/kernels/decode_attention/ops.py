"""Public wrapper: accepts model-layout decode-attention operands.

The node carries six operands in model layout —

    q       (B, 1, H, hd)    this step's query projection
    k, v    (B, S, KV, hd)   the KV cache gathered from the SlotArena
    k_new   (B, 1, KV, hd)   the step's key projection (cache position len)
    v_new   (B, 1, KV, hd)   the step's value projection
    lens    (B,) int32       valid cache rows per sequence

— and produces (B, 1, H, hd).  The Pallas impl declares a ``Tunable`` over
the kv block length: the autotune sweep measures every candidate and the
election pass pins the winner on the node as ``node.attrs['decode_block']``,
which the impl reads back at lowering time (one pin per decode cache
bucket, since the cache keys DECODE_ATTENTION on the KV-cache shape)."""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax

from ...backends import registry
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .._util import round_up
from .kernel import DEFAULT_BK, decode_attention_call


@functools.partial(jax.jit, static_argnames=("window", "cap", "bk",
                                             "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_new: jax.Array, v_new: jax.Array, lens: jax.Array, *,
                     window: int = 0, cap: float = 0.0, bk: int = DEFAULT_BK,
                     interpret: bool = False) -> jax.Array:
    """q: (B, 1, H, hd); k, v: (B, S, KV, hd); k_new, v_new: (B, 1, KV, hd);
    lens: (B,) int32 → (B, 1, H, hd)."""
    o = decode_attention_call(
        q[:, 0], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        k_new[:, 0], v_new[:, 0], lens,
        window=window, cap=cap, bk=bk, interpret=interpret)
    return o[:, None]


# -- dispatch-table entries: OpKind.DECODE_ATTENTION --------------------------

def _attrs(n: Node) -> dict:
    return dict(window=n.attrs.get("window", 0),
                cap=n.attrs.get("cap", 0.0))


def decode_tune_space(n: Node, hw) -> List[Tuple[int]]:
    """Candidate kv block lengths for one DECODE_ATTENTION node: powers of
    two up to the default block, clamped to the (8-sublane rounded) cache
    bucket length, deduplicated, and gated on the whole per-head cache plus
    the block-sized working set fitting in half of VMEM."""
    if len(n.inputs) < 2 or len(n.inputs[1].spec.shape) != 4:
        return []
    s = n.inputs[1].spec.shape[1]              # k_cache is (B, S, KV, hd)
    hd = n.spec.shape[-1]
    cap = round_up(s, 8)
    cands: List[Tuple[int]] = []
    seen = set()
    size = 32
    while size <= DEFAULT_BK:
        bk = min(size, cap)
        # cache k+v (sp, hd) f32 per kv head + kv block + logits row
        working = 4 * (2 * round_up(s, bk) * hd + 2 * bk * hd + 2 * bk)
        if bk not in seen and working <= hw.vmem_bytes // 2:
            seen.add(bk)
            cands.append((bk,))
        size *= 2
    return cands


def _decode_attention_pallas_impl(n: Node, vals: Sequence[jax.Array],
                                  backend: "registry.Backend") -> jax.Array:
    q, k, v, k_new, v_new, lens = vals
    cfg = n.attrs.get("decode_block")
    bk = int(cfg[0]) if cfg else DEFAULT_BK
    return decode_attention(q, k, v, k_new, v_new, lens, bk=bk,
                            interpret=backend.interpret, **_attrs(n))


def _decode_attention_ref_impl(n: Node, vals: Sequence[jax.Array],
                               backend: "registry.Backend") -> jax.Array:
    from .ref import decode_attention_ref
    q, k, v, k_new, v_new, lens = vals
    o = decode_attention_ref(q[:, 0], k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), k_new[:, 0],
                             v_new[:, 0], lens, **_attrs(n))
    return o[:, None]


registry.register_shared_impl(
    OpKind.DECODE_ATTENTION, _decode_attention_pallas_impl,
    name="pallas.decode_attention", requires=("pallas",),
    supports=lambda n: len(n.spec.shape) == 4,
    tunable=Tunable("decode_block", decode_tune_space))
registry.register_reference_impl(
    OpKind.DECODE_ATTENTION, _decode_attention_ref_impl,
    name="ref.decode_attention",
    memory="roundtrip")   # materializes the (B, H, S) score rows
