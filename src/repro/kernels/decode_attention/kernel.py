"""Single-token decode attention (TPU Pallas): one query row per sequence
against that sequence's KV cache — the O(1)-per-token half of the served
prefill/decode split (``distributed/steps.py:make_serve_steps`` is the SPMD
ancestor of the same shape).

Grid: (batch, q_head).  The kv-head index is derived from the q-head index
(GQA: h // group).  The whole cache for one (batch, kv head) lives in VMEM
(S·hd·4 B — a few hundred KiB at serving cache buckets) and the kernel
streams it in ``bk``-row blocks with an online-softmax carry, exactly like
the prefill flash kernel but with a single query row.  The per-row cache
length arrives as a scalar block: the kv loop's upper bound is
``ceil(len/bk)``, so a short resident sequence reads only its own rows —
per-step work is proportional to the *actual* cache length, never to the
bucket.  The step's freshly projected (k_new, v_new) pair — position
``len``, computed in the same forward — is folded into the softmax after
the loop, resolving the same-layer chicken-and-egg without a cache write
inside the kernel.

BlockSpecs:
  lens: (1, 1)          index (b, h) -> (b, 0)
  q:    (1, 1, hd)      index (b, h) -> (b, h, 0)
  k/v:  (1, 1, S, hd)   index (b, h) -> (b, h // group, 0, 0)
  k_new/v_new: (1, 1, hd) index (b, h) -> (b, h // group, 0)
  o:    (1, 1, hd)      index (b, h) -> (b, h, 0)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._util import round_up as _round_up

DEFAULT_BK = 512
NEG = -1e30


def _kernel(bk: int, window: int, cap: float, scale: float,
            lens_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref, o_ref):
    s = k_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale                 # (1, hd)
    length = lens_ref[0, 0]                                  # valid cache rows

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        vb = v_ref[0, 0, pl.dslice(j * bk, bk), :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (1, bk)
        if cap:
            logits = jnp.tanh(logits / cap) * cap
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = k_pos < length          # ragged tail + bucket padding rows
        if window:                     # query position is `length`
            mask &= (length - k_pos) < window
        logits = jnp.where(mask, logits, NEG)
        m_new = jnp.maximum(m, logits.max(axis=1))
        p = jnp.exp(logits - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    hd = q_ref.shape[2]
    init = (jnp.full((1,), -jnp.inf, jnp.float32),
            jnp.zeros((1,), jnp.float32),
            jnp.zeros((1, hd), jnp.float32))
    hi = jnp.minimum(s // bk, pl.cdiv(length, bk))
    lo = jnp.maximum(0, length - window) // bk if window else 0
    m, l, acc = jax.lax.fori_loop(lo, hi, body, init)

    # fold in the new (k, v) pair at position `length` (distance 0: always
    # causal-visible and inside any window)
    kn = kn_ref[0].astype(jnp.float32)                       # (1, hd)
    vn = vn_ref[0].astype(jnp.float32)
    logit_n = (q * kn).sum(axis=1)                           # (1,)
    if cap:
        logit_n = jnp.tanh(logit_n / cap) * cap
    m_fin = jnp.maximum(m, logit_n)
    corr = jnp.exp(m - m_fin)
    p_n = jnp.exp(logit_n - m_fin)
    l_fin = l * corr + p_n
    acc_fin = acc * corr[:, None] + p_n[:, None] * vn
    o = acc_fin / jnp.maximum(l_fin, 1e-30)[:, None]
    o_ref[0] = o.astype(o_ref.dtype)


def decode_attention_call(q: jax.Array, k: jax.Array, v: jax.Array,
                          k_new: jax.Array, v_new: jax.Array,
                          lens: jax.Array, *, window: int = 0,
                          cap: float = 0.0, bk: int = DEFAULT_BK,
                          interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k, v: (B, KV, S, hd); k_new, v_new: (B, KV, hd);
    lens: (B,) int32.  Returns (B, H, hd).

    The cache is zero-padded along S up to the block grid; padded rows are
    masked inside the kernel (``k_pos < lens[b]``), so any garbage beyond a
    row's valid length — bucket padding included — contributes nothing.
    """
    b, h, hd = q.shape
    kv, s = k.shape[1], k.shape[2]
    group = h // kv
    bk = min(bk, _round_up(s, 8))
    sp = _round_up(s, bk)
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    lens2 = lens.astype(jnp.int32).reshape(b, 1)
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_kernel, bk, window, cap, scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_: (b_, 0)),
            pl.BlockSpec((1, 1, hd), lambda b_, h_: (b_, h_, 0)),
            pl.BlockSpec((1, 1, sp, hd),
                         lambda b_, h_, g=group: (b_, h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, sp, hd),
                         lambda b_, h_, g=group: (b_, h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b_, h_, g=group: (b_, h_ // g, 0)),
            pl.BlockSpec((1, 1, hd), lambda b_, h_, g=group: (b_, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b_, h_: (b_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(lens2, q, k, v, k_new, v_new)
