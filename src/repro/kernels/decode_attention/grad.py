"""Decode-attention backward: vjp of the reference oracle.

Decode steps are inference-only in practice, but the op still joins the
gradient dispatch table so a graph containing DECODE_ATTENTION nodes stays
differentiable end-to-end (e.g. RL-style fine-tuning over served decode
programs).  The integer ``lens`` operand naturally receives a ``float0``
cotangent through the executor's custom_vjp wrapper.
"""
from __future__ import annotations

from ...backends import registry
from ...core import executor
from ...core.ir import OpKind

registry.register_reference_grad_impl(
    OpKind.DECODE_ATTENTION, executor.reference_vjp_grad,
    name="ref.decode_attention_bwd", memory="roundtrip")
