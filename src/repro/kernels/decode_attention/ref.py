"""Pure-jnp oracle: one query token against a ragged KV cache.

Row ``b`` of the batch holds a cache of ``lens[b]`` valid rows (positions
``0 .. lens[b]-1``) plus the current step's freshly projected key/value pair
at position ``lens[b]`` — the query's own position.  The oracle attends the
single query over the valid cache rows and the new pair; padded cache rows
(``p >= lens[b]``) contribute nothing.  This is exactly one row of the
causal ``flash_attention_ref`` at position ``lens[b]``, which is what the
conformance matrix and the serving parity tests pin the kernel to.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_new: jax.Array, v_new: jax.Array,
                         lens: jax.Array, *, window: int = 0,
                         cap: float = 0.0) -> jax.Array:
    """q: (B, H, hd); k, v: (B, KV, S, hd); k_new, v_new: (B, KV, hd);
    lens: (B,) int32 → (B, H, hd)."""
    b, h, hd = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg,
                        k.astype(jnp.float32)) * scale
    logit_new = jnp.einsum("bkgd,bkd->bkg", qg,
                           k_new.astype(jnp.float32)) * scale
    if cap:
        logits = jnp.tanh(logits / cap) * cap
        logit_new = jnp.tanh(logit_new / cap) * cap
    pos = jnp.arange(s)
    mask = pos[None, :] < lens[:, None]                  # valid cache rows
    if window:  # query sits at position lens[b]; the new pair is distance 0
        mask &= (lens[:, None] - pos[None, :]) < window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    all_logits = jnp.concatenate([logits, logit_new[..., None]], axis=-1)
    w = jax.nn.softmax(all_logits, axis=-1)
    o = (jnp.einsum("bkgs,bksd->bkgd", w[..., :s], v.astype(jnp.float32))
         + jnp.einsum("bkg,bkd->bkgd", w[..., s], v_new.astype(jnp.float32)))
    return o.reshape(b, h, hd).astype(q.dtype)
