"""Public wrapper + dispatch-table entry for the Listing-3 AveragePooling.

The impl declares a ``Tunable`` over the channel-block length: a config
``(bc,)`` pinned as ``node.attrs['avgpool_block']`` makes each kernel
launch pool ``bc`` channels from one VMEM-resident block."""
from __future__ import annotations

import functools
import math
from typing import List, Sequence, Tuple

import jax

from ...backends import registry
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .kernel import avgpool_call


@functools.partial(jax.jit, static_argnames=("kh", "kw", "bc", "interpret"))
def avgpool(x: jax.Array, kh: int = 3, kw: int = 3, *, bc: int = 1,
            interpret: bool = False) -> jax.Array:
    """Paper Listing-3 AveragePooling (NCHW, stride 1, VALID)."""
    return avgpool_call(x, kh, kw, bc=bc, interpret=interpret)


def _supports(n: Node) -> bool:
    # the Pallas kernel covers rank-4 NCHW, stride 1, VALID
    k = n.attrs.get("kernel", 2)
    s = n.attrs.get("stride", k)
    return len(n.spec.shape) == 4 and s in (1, (1, 1))


def avgpool_tune_space(n: Node, hw) -> List[Tuple[int]]:
    """Candidate channel blocks: sublane-friendly sizes clamped to divisors
    of C (gcd) and deduplicated."""
    if len(n.spec.shape) != 4:
        return []
    c = n.spec.shape[1]
    cands = {math.gcd(v, c) for v in (1, hw.sublanes, 4 * hw.sublanes,
                                      16 * hw.sublanes, c)}
    return [(bc,) for bc in sorted(cands)]


def avgpool_refine_space(n: Node, hw, cfg) -> List[Tuple[int]]:
    """SOL-gap planner neighborhood: the channel block must divide C, so
    probe divisor-clamped half/double steps around the winner."""
    c = n.spec.shape[1]
    bc = int(cfg[0])
    return [(math.gcd(max(1, v), c),) for v in (bc // 2, bc * 2, bc * 4)]


def _avgpool_impl(n: Node, vals: Sequence[jax.Array],
                  backend: "registry.Backend") -> jax.Array:
    k = n.attrs.get("kernel", 2)
    kh, kw = (k, k) if isinstance(k, int) else k
    cfg = n.attrs.get("avgpool_block")
    bc = int(cfg[0]) if cfg else 1
    return avgpool(vals[0], kh, kw, bc=bc, interpret=backend.interpret)


registry.register_shared_impl(
    OpKind.AVGPOOL, _avgpool_impl, name="pallas.avgpool",
    requires=("pallas",), supports=_supports,
    tunable=Tunable("avgpool_block", avgpool_tune_space,
                    refine=avgpool_refine_space))
