from __future__ import annotations

import functools

import jax

from .kernel import avgpool_call


@functools.partial(jax.jit, static_argnames=("kh", "kw", "interpret"))
def avgpool(x: jax.Array, kh: int = 3, kw: int = 3, *,
            interpret: bool = False) -> jax.Array:
    """Paper Listing-3 AveragePooling (NCHW, stride 1, VALID)."""
    return avgpool_call(x, kh, kw, interpret=interpret)
