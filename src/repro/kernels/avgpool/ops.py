from __future__ import annotations

import functools
from typing import Sequence

import jax

from ...backends import registry
from ...core.ir import Node, OpKind
from .kernel import avgpool_call


@functools.partial(jax.jit, static_argnames=("kh", "kw", "interpret"))
def avgpool(x: jax.Array, kh: int = 3, kw: int = 3, *,
            interpret: bool = False) -> jax.Array:
    """Paper Listing-3 AveragePooling (NCHW, stride 1, VALID)."""
    return avgpool_call(x, kh, kw, interpret=interpret)


def _supports(n: Node) -> bool:
    # the Pallas kernel covers rank-4 NCHW, stride 1, VALID
    k = n.attrs.get("kernel", 2)
    s = n.attrs.get("stride", k)
    return len(n.spec.shape) == 4 and s in (1, (1, 1))


def _avgpool_impl(n: Node, vals: Sequence[jax.Array],
                  backend: "registry.Backend") -> jax.Array:
    k = n.attrs.get("kernel", 2)
    kh, kw = (k, k) if isinstance(k, int) else k
    return avgpool(vals[0], kh, kw, interpret=backend.interpret)


registry.register_shared_impl(
    OpKind.AVGPOOL, _avgpool_impl, name="pallas.avgpool",
    requires=("pallas",), supports=_supports)
