"""AveragePooling backward: uniform spreading as a grouped convolution.

For stride-1 VALID average pooling every input pixel receives ct/area from
each window that covers it — a full-padding correlation of the cotangent
with a ones/area kernel, expressed as one grouped ``conv_general_dilated``
(feature_group_count=C) so XLA lowers it as a single fused op instead of
the scatter loop AD of ``reduce_window`` produces.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core.ir import Node, OpKind
from .ops import _supports

Array = jax.Array


def _avgpool_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    (x,), _out = res
    k = n.attrs.get("kernel", 2)
    kh, kw = (k, k) if isinstance(k, int) else k
    c = x.shape[1]
    kern = jnp.full((c, 1, kh, kw), 1.0 / (kh * kw), dtype=jnp.float32)
    dx = jax.lax.conv_general_dilated(
        ct.astype(jnp.float32), kern, window_strides=(1, 1),
        padding=((kh - 1, kh - 1), (kw - 1, kw - 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c)
    return (dx.astype(x.dtype),)


registry.register_shared_grad_impl(
    OpKind.AVGPOOL, _avgpool_grad_impl, name="conv.avgpool_bwd",
    supports=_supports)
