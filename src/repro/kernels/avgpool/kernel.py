"""AveragePooling — the paper's own Listing 3, as a TPU Pallas kernel.

The paper shows the same DFP loop nest emitted for ISPC (CPU), CUDA and
NCC (SX-Aurora); this is the fourth flavour.  The (OP1, OP0) spatial loops
of the listing become the VPU lane grid; the channel loop (OC0x, the
paper's ``taskIndex``) becomes the Pallas grid dimension; the K1/K2 kernel
loops unroll in VREGs — one HBM read per input tile, depth-first.

Layout NCHW, stride 1, VALID padding (matching the listing's 3×3/9 form).
``bc`` blocks the channel grid: each program holds (bc, H, W) in VMEM and
pools bc channels per launch — the tunable knob the autotune sweep
measures (``bc`` is clamped to a divisor of C via gcd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(kh: int, kw: int, out_h: int, out_w: int, x_ref, o_ref):
    bc = x_ref.shape[1]
    acc = jnp.zeros((bc, out_h, out_w), jnp.float32)
    for k1 in range(kh):                 # the listing's K1/K2 unrolled
        for k2 in range(kw):
            acc = acc + x_ref[0, :, k1:k1 + out_h, k2:k2 + out_w].astype(
                jnp.float32)
    o_ref[0, :, :, :] = (acc / float(kh * kw)).astype(o_ref.dtype)


def avgpool_call(x: jax.Array, kh: int = 3, kw: int = 3, *,
                 bc: int = 1, interpret: bool = False) -> jax.Array:
    """x: (N, C, H, W) → (N, C, H-kh+1, W-kw+1); stride 1, VALID."""
    n, c, h, w = x.shape
    bc = math.gcd(max(1, bc), c)
    out_h, out_w = h - kh + 1, w - kw + 1
    kernel = functools.partial(_kernel, kh, kw, out_h, out_w)
    return pl.pallas_call(
        kernel,
        grid=(n, c // bc),               # OC0x of the listing, bc-blocked
        in_specs=[pl.BlockSpec((1, bc, h, w), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, bc, out_h, out_w),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, out_h, out_w), x.dtype),
        interpret=interpret,
    )(x)
