from __future__ import annotations

import jax
import jax.numpy as jnp


def avgpool_ref(x: jax.Array, kh: int = 3, kw: int = 3) -> jax.Array:
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add,
        window_dimensions=(1, 1, kh, kw), window_strides=(1, 1, 1, 1),
        padding="VALID")
    return (s / (kh * kw)).astype(x.dtype)
