from .ops import avgpool

__all__ = ["avgpool"]
