"""RWKV6 WKV Pallas kernel (TPU).

Per (batch, head): walks T steps with the (hd_k × hd_v) state matrix
resident in VMEM (64×64 f32 = 16 KiB), computing

  o_t = r_t · (S_{t-1} + (u ⊙ k_t) vᵀ_t)
  S_t = diag(w_t) S_{t-1} + k_t vᵀ_t

The matrix state never round-trips to HBM during the scan — the DFP
insight applied to linear attention.  Grid: (B, H); blocks hold the whole
(T, hd) head slice in VMEM (4096×64×4 B ≈ 1 MiB per operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_total: int, r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            o_ref, sl_ref):
    u = u_ref[0, :].astype(jnp.float32)                 # (hd,)

    def body(t, s):
        r = r_ref[0, t, 0, :].astype(jnp.float32)       # (hd,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)       # log decay ≤ 0
        kv = k[:, None] * v[None, :]                    # (hd_k, hd_v)
        o = ((s + (u * k)[:, None] * v[None, :]) * r[:, None]).sum(axis=0)
        o_ref[0, t, 0, :] = o.astype(o_ref.dtype)
        return jnp.exp(w)[:, None] * s + kv

    s0 = s0_ref[0, 0].astype(jnp.float32)
    s = jax.lax.fori_loop(0, t_total, body, s0)
    sl_ref[0, 0] = s.astype(sl_ref.dtype)


def rwkv6_scan_call(r, k, v, logw, u, s0, *, interpret: bool = False):
    """r,k,v,logw: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).
    Returns (o: (B,T,H,hd), s_last: (B,H,hd,hd))."""
    b, t, h, hd = r.shape
    grid = (b, h)
    kernel = functools.partial(_kernel, t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, hd), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
