"""RWKV6 WKV Pallas kernel (TPU).

Per (batch, head): walks T steps with the (hd_k × hd_v) state matrix
resident in VMEM (64×64 f32 = 16 KiB), computing

  o_t = r_t · (S_{t-1} + (u ⊙ k_t) vᵀ_t)
  S_t = diag(w_t) S_{t-1} + k_t vᵀ_t

The matrix state never round-trips to HBM during the scan — the DFP
insight applied to linear attention.  Grid: (B, H, T/bt) with the time
dimension innermost: TPU grids iterate the last dimension sequentially, so
the state carries across time blocks in a VMEM scratch (the same pattern
as the matmul kernel's K-loop accumulator).  ``bt`` bounds how much of the
(T, hd) head slice one launch holds in VMEM — the tunable knob the
autotune sweep measures (clamped to a divisor of T via gcd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bt: int, nt: int, r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            o_ref, sl_ref, s_ref):
    tq = pl.program_id(2)

    @pl.when(tq == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0, :].astype(jnp.float32)                 # (hd,)

    def body(t, s):
        r = r_ref[0, t, 0, :].astype(jnp.float32)       # (hd,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)       # log decay ≤ 0
        kv = k[:, None] * v[None, :]                    # (hd_k, hd_v)
        o = ((s + (u * k)[:, None] * v[None, :]) * r[:, None]).sum(axis=0)
        o_ref[0, t, 0, :] = o.astype(o_ref.dtype)
        return jnp.exp(w)[:, None] * s + kv

    s_ref[...] = jax.lax.fori_loop(0, bt, body, s_ref[...])

    @pl.when(tq == nt - 1)
    def _store():
        sl_ref[0, 0] = s_ref[...].astype(sl_ref.dtype)


def rwkv6_scan_call(r, k, v, logw, u, s0, *, bt: int = 0,
                    interpret: bool = False):
    """r,k,v,logw: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).
    Returns (o: (B,T,H,hd), s_last: (B,H,hd,hd))."""
    b, t, h, hd = r.shape
    bt = math.gcd(max(1, bt), t) if bt else t
    nt = t // bt
    grid = (b, h, nt)
    kernel = functools.partial(_kernel, bt, nt)
    seq = pl.BlockSpec((1, bt, 1, hd), lambda i, j, tq: (i, tq, j, 0))
    state = pl.BlockSpec((1, 1, hd, hd), lambda i, j, tq: (i, j, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq, seq, seq, seq,
                  pl.BlockSpec((1, hd), lambda i, j, tq: (j, 0)),
                  state],
        out_specs=[seq, state],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
