from __future__ import annotations

import functools
from typing import Sequence

import jax

from ...backends import registry
from ...core.ir import Node, OpKind
from .kernel import rwkv6_scan_call


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, logw, u, s0, *, interpret: bool = False):
    """RWKV6 WKV recurrence.  r,k,v,logw: (B,T,H,hd); u: (H,hd);
    s0: (B,H,hd,hd) → (o: (B,T,H,hd), s_last)."""
    return rwkv6_scan_call(r, k, v, logw, u, s0, interpret=interpret)


# -- dispatch-table entries: OpKind.RWKV6_SCAN over (r, k, v, logw, u, s0);
#    the graph-level op yields the per-token output o.

def _rwkv6_pallas_impl(n: Node, vals: Sequence[jax.Array],
                       backend: "registry.Backend") -> jax.Array:
    return rwkv6_scan(*vals, interpret=backend.interpret)[0]


def _rwkv6_ref_impl(n: Node, vals: Sequence[jax.Array],
                    backend: "registry.Backend") -> jax.Array:
    from .ref import rwkv6_scan_ref
    return rwkv6_scan_ref(*vals)[0]


registry.register_shared_impl(
    OpKind.RWKV6_SCAN, _rwkv6_pallas_impl, name="pallas.rwkv6_scan",
    requires=("pallas",), supports=lambda n: len(n.spec.shape) == 4)
registry.register_reference_impl(
    OpKind.RWKV6_SCAN, _rwkv6_ref_impl, name="ref.rwkv6_scan")
