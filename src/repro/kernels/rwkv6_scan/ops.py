from __future__ import annotations

import functools

import jax

from .kernel import rwkv6_scan_call


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, logw, u, s0, *, interpret: bool = False):
    """RWKV6 WKV recurrence.  r,k,v,logw: (B,T,H,hd); u: (H,hd);
    s0: (B,H,hd,hd) → (o: (B,T,H,hd), s_last)."""
    return rwkv6_scan_call(r, k, v, logw, u, s0, interpret=interpret)
