"""Public wrapper + dispatch-table entries for the RWKV6 WKV recurrence.

The Pallas impl declares a ``Tunable`` over the time-block length: a config
``(bt,)`` pinned as ``node.attrs['rwkv6_block']`` bounds how many timesteps
one kernel launch holds in VMEM, the state matrix carrying across blocks in
scratch."""
from __future__ import annotations

import functools
import math
from typing import List, Sequence, Tuple

import jax

from ...backends import registry
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .kernel import rwkv6_scan_call


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rwkv6_scan(r, k, v, logw, u, s0, *, bt: int = 0,
               interpret: bool = False):
    """RWKV6 WKV recurrence.  r,k,v,logw: (B,T,H,hd); u: (H,hd);
    s0: (B,H,hd,hd) → (o: (B,T,H,hd), s_last)."""
    return rwkv6_scan_call(r, k, v, logw, u, s0, bt=bt, interpret=interpret)


# -- dispatch-table entries: OpKind.RWKV6_SCAN over (r, k, v, logw, u, s0);
#    the graph-level op yields the per-token output o.

def rwkv6_tune_space(n: Node, hw) -> List[Tuple[int]]:
    """Candidate time-block lengths: sublane multiples up to the whole
    sequence, clamped to divisors of T (gcd) and deduplicated."""
    if len(n.spec.shape) != 4:
        return []
    t = n.spec.shape[1]
    cands = {math.gcd(v, t) for v in (hw.sublanes, 4 * hw.sublanes,
                                      16 * hw.sublanes, t, max(1, t // 2))}
    return [(bt,) for bt in sorted(cands)]


def rwkv6_refine_space(n: Node, hw, cfg) -> List[Tuple[int]]:
    """SOL-gap planner neighborhood: the time block must divide T, so probe
    divisor-clamped half/double steps around the winner."""
    t = n.spec.shape[1]
    bt = int(cfg[0])
    return [(math.gcd(max(1, c), t),) for c in (bt // 2, bt * 2, bt * 4)]


def _rwkv6_pallas_impl(n: Node, vals: Sequence[jax.Array],
                       backend: "registry.Backend") -> jax.Array:
    cfg = n.attrs.get("rwkv6_block")
    bt = int(cfg[0]) if cfg else 0
    return rwkv6_scan(*vals, bt=bt, interpret=backend.interpret)[0]


def _rwkv6_ref_impl(n: Node, vals: Sequence[jax.Array],
                    backend: "registry.Backend") -> jax.Array:
    from .ref import rwkv6_scan_ref
    return rwkv6_scan_ref(*vals)[0]


registry.register_shared_impl(
    OpKind.RWKV6_SCAN, _rwkv6_pallas_impl, name="pallas.rwkv6_scan",
    requires=("pallas",), supports=lambda n: len(n.spec.shape) == 4,
    tunable=Tunable("rwkv6_block", rwkv6_tune_space,
                    refine=rwkv6_refine_space))
registry.register_reference_impl(
    OpKind.RWKV6_SCAN, _rwkv6_ref_impl, name="ref.rwkv6_scan")
