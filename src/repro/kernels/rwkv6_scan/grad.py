"""RWKV6 backward via chunk-level gradient checkpointing.

The WKV state is an hd×hd matrix per head — saving it for every timestep
(what plain AD of the per-step scan does) costs O(T·hd²) HBM.  Instead the
backward re-runs the forward recurrence once storing only the state at each
chunk boundary, then sweeps the chunks in reverse, ``jax.vjp``-ing the
per-chunk reference math with the carried state cotangent.  Peak residency
is O(T/bt·hd² + bt·hd) — the time-block length ``bt`` is the backward's own
``Tunable`` (``node.attrs['rwkv6_block_bwd']``), a genuine memory/recompute
knob elected independently of the forward's block.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core import executor
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .ops import rwkv6_refine_space, rwkv6_tune_space

Array = jax.Array


def _chunk_fwd(rc, kc, vc, wc, u, s):
    """One chunk of the WKV recurrence.  rc..wc: (bt,B,H,hd) f32;
    u: (H,hd); s: (B,H,hd,hd) → (o: (bt,B,H,hd), s_out)."""

    def step(s_, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        o = ((s_ + u[None, :, :, None] * kv) * rt[..., :, None]).sum(axis=-2)
        s_ = jnp.exp(wt)[..., :, None] * s_ + kv
        return s_, o

    s_out, o = jax.lax.scan(step, s, (rc, kc, vc, wc))
    return o, s_out


def _rwkv6_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    (r, k, v, logw, u, s0), _o = res
    b, t, h, hd = r.shape
    cfg = n.attrs.get("rwkv6_block_bwd")
    bt = math.gcd(int(cfg[0]), t) if cfg else math.gcd(16, t)
    nc = t // bt
    rf, kf, vf, wf = (x.astype(jnp.float32).transpose(1, 0, 2, 3)
                      .reshape(nc, bt, b, h, hd)
                      for x in (r, k, v, logw))       # (NC,bt,B,H,hd)
    ctf = ct.astype(jnp.float32).transpose(1, 0, 2, 3) \
        .reshape(nc, bt, b, h, hd)
    uf = u.astype(jnp.float32)
    s0f = s0.astype(jnp.float32)

    # pass 1: chunk-boundary states only (the checkpoints)
    def boundary(s, xs):
        rc, kc, vc, wc = xs
        _o, s_out = _chunk_fwd(rc, kc, vc, wc, uf, s)
        return s_out, s                                # emit the chunk's s_in
    _s_last, s_ins = jax.lax.scan(boundary, s0f, (rf, kf, vf, wf))

    # pass 2: reverse sweep, vjp of each chunk from its checkpoint
    def bwd_step(carry, xs):
        ds, du = carry                                 # ds: (B,H,hd,hd)
        s_in, rc, kc, vc, wc, ctc = xs
        _out, pull = jax.vjp(_chunk_fwd, rc, kc, vc, wc, uf, s_in)
        dr, dk, dv, dw, du_c, ds_in = pull((ctc, ds))
        return (ds_in, du + du_c), (dr, dk, dv, dw)

    init = (jnp.zeros_like(s0f), jnp.zeros_like(uf))
    (ds0, du), (drs, dks, dvs, dws) = jax.lax.scan(
        bwd_step, init, (s_ins, rf, kf, vf, wf, ctf), reverse=True)

    def unchunk(x):
        return x.reshape(nc * bt, b, h, hd).transpose(1, 0, 2, 3)
    return (unchunk(drs), unchunk(dks), unchunk(dvs), unchunk(dws),
            du, ds0)


registry.register_shared_grad_impl(
    OpKind.RWKV6_SCAN, _rwkv6_grad_impl, name="ckpt.rwkv6_scan_bwd",
    supports=lambda n: len(n.spec.shape) == 4,
    tunable=Tunable("rwkv6_block_bwd", rwkv6_tune_space,
                    refine=rwkv6_refine_space))
registry.register_reference_grad_impl(
    OpKind.RWKV6_SCAN, executor.reference_vjp_grad,
    name="ref.rwkv6_scan_bwd")
