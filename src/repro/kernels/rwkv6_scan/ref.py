"""Oracle: per-step jnp recurrence (the O(1)-state decode form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, logw, u, s0):
    """Same contract as rwkv6_scan: r,k,v,logw (B,T,H,hd); u (H,hd);
    s0 (B,H,hd,hd) → (o, s_last)."""
    rf, kf, vf, wf = (x.astype(jnp.float32).transpose(1, 0, 2, 3)
                      for x in (r, k, v, logw))     # (T,B,H,hd)
    uf = u.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        o = ((s + uf[None, :, :, None] * kv) * rt[..., :, None]).sum(axis=-2)
        s = jnp.exp(wt)[..., :, None] * s + kv
        return s, o

    s_last, o = jax.lax.scan(step, s0.astype(jnp.float32), (rf, kf, vf, wf))
    return o.transpose(1, 0, 2, 3).astype(r.dtype), s_last
