"""Shared kernel helpers: the padding/alignment convention lives here once."""
from __future__ import annotations


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return -(-x // m) * m
