"""Tiled MXU matmul (TPU Pallas) — the DNN-module flavour the ROADMAP named:
``pallas_tpu`` advertises the 'mxu' capability, this is the kernel that uses
it for LINEAR/MATMUL instead of lowering through the reference einsum.

Grid: (M/bm, N/bn, K/bk) with the K dimension innermost.  Each (i, j) output
tile owns an f32 VMEM scratch accumulator that carries across the K steps:
zeroed at k == 0, one ``jnp.dot``-into-MXU per step
(``preferred_element_type=f32`` keeps the accumulation in f32 even for bf16
operands), and cast + stored to the output block at the last step.  Ragged
shapes are zero-padded up to the block grid before the call and sliced after
— zeros in K contribute nothing to the dot product.

Block sizes are keyed off ``HardwareSpec.mxu_dim`` (the systolic-array tile):
``default_block`` starts at one MXU tile per dimension and ``tile_space``
spans the small search space the autotune driver measures (multiples of
``mxu_dim``, VMEM-footprint-gated).  Blocks are clamped to the rounded-up
problem size so tiny shapes do not pay for full 128-wide tiles, keeping the
TPU tiling alignments (8 sublanes × 128 lanes).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._util import round_up as _round_up

Block = Tuple[int, int, int]          # (bm, bk, bn)


def _clamp(block: Block, m: int, k: int, n: int) -> Block:
    """Shrink a block to the rounded-up problem size, preserving the TPU
    tiling alignments: 8 on the sublane dims (bm), 128 on the lane dims
    (bk is x's minor dim, bn is w's and the output's)."""
    bm, bk, bn = block
    return (max(8, min(bm, _round_up(m, 8))),
            max(128, min(bk, _round_up(k, 128))),
            max(128, min(bn, _round_up(n, 128))))


def default_block(m: int, k: int, n: int, mxu_dim: int = 128) -> Block:
    """One MXU tile per grid dimension, clamped to the problem."""
    return _clamp((mxu_dim, mxu_dim, mxu_dim), m, k, n)


def tile_space(m: int, k: int, n: int, hw) -> List[Block]:
    """The autotune search space: {1,2,4}·mxu_dim output tiles × {1,2}·mxu_dim
    K depth, deduplicated after clamping and gated on the working set
    (x tile + w tile + f32 accumulator) fitting in half of VMEM."""
    d = hw.mxu_dim
    out: List[Block] = []
    seen = set()
    for mm in (1, 2, 4):
        for nn in (1, 2, 4):
            for kk in (1, 2):
                blk = _clamp((mm * d, kk * d, nn * d), m, k, n)
                bm, bk, bn = blk
                working_set = 4 * (bm * bk + bk * bn) + 4 * 2 * bm * bn
                if working_set > hw.vmem_bytes // 2 or blk in seen:
                    continue
                seen.add(blk)
                out.append(blk)
    return out or [default_block(m, k, n, d)]


def _kernel(nk: int, x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_call(x: jax.Array, w: jax.Array, *,
                block: Optional[Block] = None,
                interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) → (M, N), f32 accumulation on the MXU."""
    m, kd = x.shape
    kd2, n = w.shape
    if kd != kd2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    bm, bk, bn = _clamp(block or default_block(m, kd, n), m, kd, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(kd, bk), _round_up(n, bn)
    if (mp, kp) != (m, kd):
        x = jnp.pad(x, ((0, mp - m), (0, kp - kd)))
    if (kp, np_) != (kd, n):
        w = jnp.pad(w, ((0, kp - kd), (0, np_ - n)))
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_kernel, nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kq: (i, kq)),
            pl.BlockSpec((bk, bn), lambda i, j, kq: (kq, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:m, :n] if (mp, np_) != (m, n) else out
