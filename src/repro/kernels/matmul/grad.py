"""Matmul / Linear backward on the tiled MXU kernel.

dA and dB of ``y = x @ w`` are themselves matmuls — ``dx = ct @ wᵀ`` and
``dw = xᵀ @ ct`` — so the backward rides the same Pallas MXU kernel as the
forward, with its *own* tile ``Tunable`` (``node.attrs['mxu_block_bwd']``):
the dx matmul's (M, N, K) problem shape differs from the forward's
(M, K, N), so the forward's elected tile is not assumed optimal and the
backward is swept/elected independently.  The Linear flavour adds the bias
reduction and maps dw back to the stored weight orientation through the
same ``linear_weight_kn`` heuristic the forward uses.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core.autotune import Tunable, node_shape
from ...core.ir import Node, OpKind
from .kernel import Block, tile_space
from .ops import _supports_linear, _supports_matmul, matmul

Array = jax.Array


def _bwd_block(n: Node) -> Block | None:
    cfg = n.attrs.get("mxu_block_bwd")
    return tuple(cfg) if cfg else None


def _dx_dw(x: Array, w_kn: Array, ct: Array, block: Block | None,
           interpret: bool):
    """x: (..., K); w_kn: (K, N); ct: (..., N) → (dx, dw_kn)."""
    dx = matmul(ct, w_kn.T, block=block, interpret=interpret)
    x2d = x.reshape(-1, x.shape[-1])
    ct2d = ct.reshape(-1, ct.shape[-1])
    # the dw problem (K, M, N) has different dims — let the kernel pick its
    # default tile rather than force the dx matmul's tuned block on it
    dw_kn = matmul(x2d.T, ct2d, interpret=interpret)
    return dx, dw_kn


def _matmul_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    (x, w), _out = res
    dx, dw = _dx_dw(x, w, ct, _bwd_block(n), backend.interpret)
    return dx, dw


def _linear_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    from ...core.executor import linear_weight_kn
    vals, _out = res
    x, w = vals[0], vals[1]
    w_kn = linear_weight_kn(n, w)
    dx, dw_kn = _dx_dw(x, w_kn, ct, _bwd_block(n), backend.interpret)
    dw = dw_kn.T if w.shape[0] == n.attrs["out_features"] else dw_kn
    outs = [dx, dw]
    if len(vals) > 2 and vals[2] is not None:
        axes = tuple(range(ct.ndim - 1))
        outs.append(ct.sum(axes))
    return tuple(outs)


def _mxu_bwd_tune_space(n: Node, hw) -> List[Block]:
    shp = node_shape(n)                   # (M, K, N), batch folded into M
    if not shp or len(shp) != 3:
        return []
    m, k, nn = shp
    return tile_space(m, nn, k, hw)       # the dx matmul: (M, N) · (N, K)


_MXU_BWD_TUNABLE = Tunable("mxu_block_bwd", _mxu_bwd_tune_space)

registry.register_shared_grad_impl(
    OpKind.MATMUL, _matmul_grad_impl, name="pallas.matmul_mxu_bwd",
    requires=("mxu",), supports=_supports_matmul, tunable=_MXU_BWD_TUNABLE)
registry.register_shared_grad_impl(
    OpKind.LINEAR, _linear_grad_impl, name="pallas.linear_mxu_bwd",
    requires=("mxu",), supports=_supports_linear, tunable=_MXU_BWD_TUNABLE)
