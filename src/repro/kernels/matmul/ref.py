"""Pure-jnp oracle for the tiled MXU matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., K) @ w: (K, N) — einsum with f32 accumulation, matching the
    kernel's preferred_element_type."""
    return jnp.einsum("...k,kn->...n", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
