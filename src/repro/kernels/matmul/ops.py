"""Public wrapper + dispatch-table entries for the tiled MXU matmul.

Registered for the 'mxu' capability on LINEAR and MATMUL — the first kernel
that actually uses the capability ``pallas_tpu`` has always advertised.
Both impls declare a ``Tunable`` over the ``tile_space`` search space: the
election pass may pin a measured tile config on the node
(``node.attrs['mxu_block']``, written from the autotune cache); absent that,
``default_block`` keys the tile off the backend's ``HardwareSpec.mxu_dim``.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax

from ...backends import registry
from ...core.autotune import Tunable, node_shape
from ...core.ir import Node, OpKind
from .kernel import Block, default_block, matmul_call, tile_space

_FLOAT_DTYPES = ("float32", "bfloat16", "float16")


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def matmul(x: jax.Array, w: jax.Array, *,
           block: Optional[Block] = None,
           interpret: bool = False) -> jax.Array:
    """x: (..., K) @ w: (K, N) → (..., N); leading dims collapse into M."""
    lead = x.shape[:-1]
    y = matmul_call(x.reshape((-1, x.shape[-1])), w,
                    block=block, interpret=interpret)
    return y.reshape(lead + (w.shape[-1],))


def _node_block(n: Node, backend: "registry.Backend",
                m: int, k: int, nn: int) -> Block:
    cfg = n.attrs.get("mxu_block")
    if cfg:
        return tuple(cfg)
    return default_block(m, k, nn, backend.hw.mxu_dim)


def _matmul_impl(n: Node, vals: Sequence[jax.Array],
                 backend: "registry.Backend") -> jax.Array:
    x, w = vals[0], vals[1]
    blk = _node_block(n, backend, x.size // x.shape[-1], w.shape[0],
                      w.shape[1])
    return matmul(x, w, block=blk, interpret=backend.interpret)


def _linear_impl(n: Node, vals: Sequence[jax.Array],
                 backend: "registry.Backend") -> jax.Array:
    from ...core.executor import linear_weight_kn
    x, w = vals[0], linear_weight_kn(n, vals[1])  # kernel wants (K, N)
    blk = _node_block(n, backend, x.size // x.shape[-1], w.shape[0],
                      w.shape[1])
    y = matmul(x, w, block=blk, interpret=backend.interpret)
    if len(vals) > 2 and vals[2] is not None:
        y = y + vals[2]
    return y


def _floats(n: Node) -> bool:
    return (n.spec.dtype in _FLOAT_DTYPES
            and all(i.spec.dtype == n.spec.dtype for i in n.inputs[:2]))


def _supports_matmul(n: Node) -> bool:
    return (len(n.inputs) >= 2 and len(n.inputs[1].spec.shape) == 2
            and len(n.inputs[0].spec.shape) >= 2 and _floats(n))


def _supports_linear(n: Node) -> bool:
    return (len(n.inputs) >= 2 and len(n.inputs[1].spec.shape) == 2
            and len(n.inputs[0].spec.shape) >= 2 and _floats(n)
            and "out_features" in n.attrs)


def _mxu_tune_space(n: Node, hw) -> List[Block]:
    shp = node_shape(n)                   # (M, K, N), batch folded into M
    if not shp or len(shp) != 3:
        return []
    m, k, nn = shp
    return tile_space(m, k, nn, hw)


_MXU_TUNABLE = Tunable("mxu_block", _mxu_tune_space)

registry.register_shared_impl(
    OpKind.MATMUL, _matmul_impl, name="pallas.matmul_mxu",
    requires=("mxu",), supports=_supports_matmul, tunable=_MXU_TUNABLE)
registry.register_shared_impl(
    OpKind.LINEAR, _linear_impl, name="pallas.linear_mxu",
    requires=("mxu",), supports=_supports_linear, tunable=_MXU_TUNABLE)
