from .kernel import default_block, tile_space
from .ops import matmul

__all__ = ["matmul", "default_block", "tile_space"]
