"""Pallas TPU kernels for SOL's perf-critical compute layers.

The paper's DFP module generates fused depth-first kernels — these are the
TPU-native equivalents.  Each kernel is a subpackage:

  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True validates on CPU)
  ref.py    — pure-jnp oracle used by the allclose tests
"""
