"""RGLRU backward: the reverse recurrence IS an rglru scan.

For h_t = a_t·h_{t-1} + b_t the cotangent recurrence is

  g_t = ḣ_t + a_{t+1}·g_{t+1}        (g_{T-1} = ḣ_{T-1})

which, read in reversed time, is exactly another gated linear recurrence —
coefficients rev(a) shifted right one step, additions rev(ḣ), zero initial
state.  The backward therefore reuses the *same Pallas scan kernel* as the
forward, with its own channel-block ``Tunable``
(``node.attrs['rglru_block_bwd']``).  The remaining grads are elementwise:

  db_t = g_t;   da_t = g_t·h_{t-1}  (h_{-1} = h0);   dh0 = a_0·g_0
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ...backends import registry
from ...core import executor
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .kernel import DEFAULT_BD
from .ops import _clamp_bd, rglru_scan, rglru_refine_space, rglru_tune_space

Array = jax.Array


def _rglru_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    (a, b, h0), h = res
    cfg = n.attrs.get("rglru_block_bwd")
    bd = _clamp_bd(cfg[0], a.shape[-1]) if cfg else DEFAULT_BD
    af = a.astype(jnp.float32)
    ctf = ct.astype(jnp.float32)
    # reversed-time scan coefficients: coeff_i = a_{T-i} (first step unused —
    # the zero initial state absorbs it)
    a_rev = jnp.flip(af, axis=1)
    coeff = jnp.concatenate([jnp.ones_like(a_rev[:, :1]), a_rev[:, :-1]],
                            axis=1)
    zeros0 = jnp.zeros_like(h0, dtype=jnp.float32)
    g_rev = rglru_scan(coeff, jnp.flip(ctf, axis=1), zeros0, bd=bd,
                       interpret=backend.interpret)[0]
    g = jnp.flip(g_rev, axis=1)
    h_prev = jnp.concatenate(
        [h0.astype(jnp.float32)[:, None], h.astype(jnp.float32)[:, :-1]],
        axis=1)
    da = g * h_prev
    db = g
    dh0 = af[:, 0] * g[:, 0]
    return da, db, dh0


registry.register_shared_grad_impl(
    OpKind.RGLRU_SCAN, _rglru_grad_impl, name="pallas.rglru_scan_bwd",
    requires=("pallas",), supports=lambda n: len(n.spec.shape) == 3,
    tunable=Tunable("rglru_block_bwd", rglru_tune_space,
                    refine=rglru_refine_space))
registry.register_reference_grad_impl(
    OpKind.RGLRU_SCAN, executor.reference_vjp_grad,
    name="ref.rglru_scan_bwd")
