"""RG-LRU linear-recurrence Pallas kernel (TPU).

h_t = a_t ⊙ h_{t-1} + b_t over time, per channel — the gated linear
recurrence at the heart of RecurrentGemma/Griffin.  The recurrence is
elementwise over channels, so the grid tiles (batch × channel-blocks) and
each program walks T sequentially with the state vector resident in VREGs —
the DFP principle (state never leaves the core) applied to an RNN.

BlockSpecs: a, b: (1, T, bd); h0: (1, bd); outputs likewise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BD = 512


def _kernel(t_total: int, a_ref, b_ref, h0_ref, o_ref, hl_ref):
    h0 = h0_ref[0, :].astype(jnp.float32)

    def body(t, h):
        a = a_ref[0, t, :].astype(jnp.float32)
        b = b_ref[0, t, :].astype(jnp.float32)
        h = a * h + b
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, t_total, body, h0)
    hl_ref[0, :] = h.astype(hl_ref.dtype)


def rglru_scan_call(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                    bd: int = DEFAULT_BD, interpret: bool = False):
    """a, b: (B, T, D) decay/input; h0: (B, D).  Returns (h, h_last)."""
    bsz, t, d = a.shape
    bd = min(bd, d)
    if d % bd:
        raise ValueError(f"d={d} must divide bd={bd}")
    grid = (bsz, d // bd)
    kernel = functools.partial(_kernel, t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, t, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, d), a.dtype),
            jax.ShapeDtypeStruct((bsz, d), a.dtype),
        ],
        interpret=interpret,
    )(a, b, h0)
