from __future__ import annotations

import functools
import math
from typing import List, Sequence, Tuple

import jax

from ...backends import registry
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .kernel import DEFAULT_BD, rglru_scan_call


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
               bd: int = 512, interpret: bool = False):
    """Gated linear recurrence h_t = a_t·h_{t-1} + b_t.
    a, b: (B, T, D); h0: (B, D) → (h: (B,T,D), h_last: (B,D))."""
    return rglru_scan_call(a, b, h0, bd=bd, interpret=interpret)


# -- dispatch-table entries: OpKind.RGLRU_SCAN over (a, b, h0) nodes;
#    the graph-level op yields the full hidden sequence h.

def _clamp_bd(bd: int, d: int) -> int:
    """The kernel's channel block must divide D: gcd is the largest value
    that both divides D and never exceeds the request."""
    return math.gcd(max(1, int(bd)), d)


def rglru_tune_space(n: Node, hw) -> List[Tuple[int]]:
    """Candidate channel-block lengths for one RGLRU_SCAN node: VPU-lane
    multiples up to the default block plus the whole/half channel dim, each
    clamped to a divisor of D and deduplicated."""
    if len(n.spec.shape) != 3:
        return []
    d = n.spec.shape[-1]
    cands = {_clamp_bd(c, d)
             for c in (hw.lanes, 2 * hw.lanes, 4 * hw.lanes, DEFAULT_BD,
                       d, max(1, d // 2))}
    return [(bd,) for bd in sorted(cands)]


def rglru_refine_space(n: Node, hw, cfg) -> List[Tuple[int]]:
    """SOL-gap planner neighborhood: the channel block must divide D, so
    probe the divisor-clamped half/double of the winning block instead of
    the default raw power-of-two neighbors (which gcd would collapse back
    onto the winner)."""
    d = n.spec.shape[-1]
    bd = int(cfg[0])
    return [(_clamp_bd(c, d),) for c in (bd // 2, bd * 2, bd * 4)]


def _rglru_pallas_impl(n: Node, vals: Sequence[jax.Array],
                       backend: "registry.Backend") -> jax.Array:
    a, b, h0 = vals
    cfg = n.attrs.get("rglru_block")
    bd = _clamp_bd(cfg[0], a.shape[-1]) if cfg else DEFAULT_BD
    return rglru_scan(a, b, h0, bd=bd, interpret=backend.interpret)[0]


def _rglru_ref_impl(n: Node, vals: Sequence[jax.Array],
                    backend: "registry.Backend") -> jax.Array:
    from .ref import rglru_scan_ref
    a, b, h0 = vals
    return rglru_scan_ref(a, b, h0)[0]


registry.register_shared_impl(
    OpKind.RGLRU_SCAN, _rglru_pallas_impl, name="pallas.rglru_scan",
    requires=("pallas",), supports=lambda n: len(n.spec.shape) == 3,
    tunable=Tunable("rglru_block", rglru_tune_space,
                    refine=rglru_refine_space))
registry.register_reference_impl(
    OpKind.RGLRU_SCAN, _rglru_ref_impl, name="ref.rglru_scan")
