from __future__ import annotations

import functools
from typing import Sequence

import jax

from ...backends import registry
from ...core.ir import Node, OpKind
from .kernel import rglru_scan_call


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
               bd: int = 512, interpret: bool = False):
    """Gated linear recurrence h_t = a_t·h_{t-1} + b_t.
    a, b: (B, T, D); h0: (B, D) → (h: (B,T,D), h_last: (B,D))."""
    return rglru_scan_call(a, b, h0, bd=bd, interpret=interpret)


# -- dispatch-table entries: OpKind.RGLRU_SCAN over (a, b, h0) nodes;
#    the graph-level op yields the full hidden sequence h.

def _rglru_pallas_impl(n: Node, vals: Sequence[jax.Array],
                       backend: "registry.Backend") -> jax.Array:
    a, b, h0 = vals
    return rglru_scan(a, b, h0, interpret=backend.interpret)[0]


def _rglru_ref_impl(n: Node, vals: Sequence[jax.Array],
                    backend: "registry.Backend") -> jax.Array:
    from .ref import rglru_scan_ref
    a, b, h0 = vals
    return rglru_scan_ref(a, b, h0)[0]


registry.register_shared_impl(
    OpKind.RGLRU_SCAN, _rglru_pallas_impl, name="pallas.rglru_scan",
    requires=("pallas",), supports=lambda n: len(n.spec.shape) == 3)
registry.register_reference_impl(
    OpKind.RGLRU_SCAN, _rglru_ref_impl, name="ref.rglru_scan")
