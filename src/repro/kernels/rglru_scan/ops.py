from __future__ import annotations

import functools

import jax

from .kernel import rglru_scan_call


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
               bd: int = 512, interpret: bool = False):
    """Gated linear recurrence h_t = a_t·h_{t-1} + b_t.
    a, b: (B, T, D); h0: (B, D) → (h: (B,T,D), h_last: (B,D))."""
    return rglru_scan_call(a, b, h0, bd=bd, interpret=interpret)
