"""Oracle: the same recurrence via associative scan (as the model uses)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array):
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype), h[:, -1].astype(a.dtype)
