"""DFP fusion-group backward: recompute-and-vjp of the composed chain.

A FUSED node's forward may be the single-launch Pallas DFP kernel (which has
no AD rule); its backward recomputes the group op-at-a-time through
``compose_fused`` — body ops still resolve through the dispatch table — and
``jax.vjp``s that chain, remat-style: no per-op intermediate survives the
forward pass, and the backward's recompute stays VMEM-friendly under jit.
Registered at the shared tier (streamed memory) so FUSED nodes elect a
non-reference backward; the reference tier (``ref.fused_bwd``) is the same
math charged with roundtrip memory.
"""
from __future__ import annotations

from typing import Sequence

import jax

from ...backends import registry
from ...core import executor
from ...core.ir import Node, OpKind


def _fused_grad_impl(n: Node, res, ct, backend: "registry.Backend"):
    vals, _out = res
    _, pull = jax.vjp(
        lambda *xs: executor.compose_fused(n, list(xs), backend), *vals)
    return pull(ct)


registry.register_shared_grad_impl(
    OpKind.FUSED, _fused_grad_impl, name="recompute.fused_bwd")
