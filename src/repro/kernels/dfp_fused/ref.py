"""Pure-jnp oracle for the DFP fused kernel: interprets the same static
program on whole arrays (no tiling), used by the allclose tests."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .program import Program


def dfp_fused_ref(prog: Program, operands: Sequence[jax.Array],
                  out_shape, out_dtype) -> jax.Array:
    d = out_shape[-1]
    rows = 1
    for s in out_shape[:-1]:
        rows *= s
    vals = {}
    for i, (op, kind) in enumerate(zip(operands, prog.operand_kinds)):
        vals[i] = op.reshape(rows, d) if kind == "full" else op.reshape(1, d)

    regs = {}

    def val(src):
        tag, i = src
        return regs[i] if tag == "reg" else vals[i]

    for ins in prog.instrs:
        op, dst = ins[0], ins[1]
        if op == "relu":
            r = jnp.maximum(val(ins[2]), 0.0)
        elif op == "gelu":
            r = jax.nn.gelu(val(ins[2]))
        elif op == "silu":
            r = jax.nn.silu(val(ins[2]))
        elif op == "sigmoid":
            r = jax.nn.sigmoid(val(ins[2]))
        elif op == "tanh":
            r = jnp.tanh(val(ins[2]))
        elif op == "exp":
            r = jnp.exp(val(ins[2]))
        elif op == "copy":
            r = val(ins[2])
        elif op == "add":
            r = val(ins[2]) + val(ins[3])
        elif op == "sub":
            r = val(ins[2]) - val(ins[3])
        elif op == "mul":
            r = val(ins[2]) * val(ins[3])
        elif op == "div":
            r = val(ins[2]) / val(ins[3])
        elif op == "scale":
            r = val(ins[2]) * ins[3]
        elif op == "softcap":
            r = jnp.tanh(val(ins[2]) / ins[3]) * ins[3]
        elif op == "bias":
            r = val(ins[2]) + vals[ins[3]]
        elif op == "rmsnorm":
            x = val(ins[2]).astype(jnp.float32)
            ms = jnp.mean(x * x, axis=-1, keepdims=True)
            r = (x * jax.lax.rsqrt(ms + ins[4])).astype(val(ins[2]).dtype) \
                * vals[ins[3]]
        elif op == "layernorm":
            x = val(ins[2]).astype(jnp.float32)
            mu = jnp.mean(x, axis=-1, keepdims=True)
            var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
            xn = (x - mu) * jax.lax.rsqrt(var + ins[5])
            r = xn.astype(val(ins[2]).dtype) * vals[ins[3]] + vals[ins[4]]
        else:  # pragma: no cover
            raise NotImplementedError(op)
        regs[dst] = r
    return regs[prog.out_reg].reshape(out_shape).astype(out_dtype)
