from .ops import dfp_fused
from .program import encode_program, Instr

__all__ = ["dfp_fused", "encode_program", "Instr"]
