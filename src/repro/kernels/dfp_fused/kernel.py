"""DFP fused-chain Pallas kernel (TPU).

One HBM→VMEM round-trip for an entire memory-bound op chain — the TPU-native
version of the paper's depth-first parallelism.  Input is viewed as
(rows, d); the grid tiles rows; each block holds (block_rows, d) in VMEM and
the whole instruction program executes on the resident block.  Norm ops
reduce over d, so d is kept un-tiled inside the block (and block_rows is
shrunk to respect the VMEM budget instead).

BlockSpecs:
  main input / 'full' operands / output: (block_rows, d) tiles over the grid
  'vec' operands:                        (1, d), same block for every step
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .program import Program

# VMEM working-set budget per block (bytes); conservative vs 128 MiB/core so
# several live registers + double buffering fit.
_VMEM_BUDGET = 8 * 1024 * 1024
_SUBLANE = 8
_LANE = 128


def clamp_block_rows(br: int, rows: int) -> int:
    """Snap a row-block request to the 8-sublane tile and the (rounded-up)
    problem size."""
    br = max(_SUBLANE, (br // _SUBLANE) * _SUBLANE)
    return min(br, max(_SUBLANE,
                       ((rows + _SUBLANE - 1) // _SUBLANE) * _SUBLANE))


def choose_block_rows(rows: int, d: int, n_regs: int, itemsize: int) -> int:
    """Pick block_rows: multiple of the 8-sublane tile, working set under
    budget.  n_regs live registers of (block_rows, d) each."""
    denom = max(1, n_regs) * max(d, _LANE) * itemsize
    return clamp_block_rows(max(1, _VMEM_BUDGET // denom), rows)


def _apply_program(prog: Program, blocks, vecs):
    """Unroll the instruction program on VMEM-resident values.

    blocks: dict operand_idx -> (block_rows, d) array for 'full' operands,
            with -1 = main chain... (not used; chain srcs are ('op', i))
    vecs:   dict operand_idx -> (1, d) array
    """
    regs = {}

    def val(src):
        tag, i = src
        return regs[i] if tag == "reg" else blocks[i]

    for ins in prog.instrs:
        op, dst = ins[0], ins[1]
        if op in ("relu", "gelu", "silu", "sigmoid", "tanh", "exp", "copy"):
            x = val(ins[2])
            if op == "relu":
                r = jnp.maximum(x, 0.0)
            elif op == "gelu":
                r = jax.nn.gelu(x)
            elif op == "silu":
                r = x * jax.nn.sigmoid(x)
            elif op == "sigmoid":
                r = jax.nn.sigmoid(x)
            elif op == "tanh":
                r = jnp.tanh(x)
            elif op == "exp":
                r = jnp.exp(x)
            else:
                r = x
        elif op in ("add", "sub", "mul", "div"):
            a, b = val(ins[2]), val(ins[3])
            r = {"add": a + b, "sub": a - b, "mul": a * b,
                 "div": a / b}[op]
        elif op == "scale":
            r = val(ins[2]) * ins[3]
        elif op == "softcap":
            c = ins[3]
            r = jnp.tanh(val(ins[2]) / c) * c
        elif op == "bias":
            r = val(ins[2]) + vecs[ins[3]]
        elif op == "rmsnorm":
            x = val(ins[2]).astype(jnp.float32)
            ms = jnp.mean(x * x, axis=-1, keepdims=True)
            r = (x * jax.lax.rsqrt(ms + ins[4])).astype(val(ins[2]).dtype) \
                * vecs[ins[3]]
        elif op == "layernorm":
            x = val(ins[2]).astype(jnp.float32)
            mu = jnp.mean(x, axis=-1, keepdims=True)
            var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
            xn = (x - mu) * jax.lax.rsqrt(var + ins[5])
            r = xn.astype(val(ins[2]).dtype) * vecs[ins[3]] + vecs[ins[4]]
        else:  # pragma: no cover
            raise NotImplementedError(op)
        regs[dst] = r
    return regs[prog.out_reg]


def _kernel(prog: Program, full_idx: Tuple[int, ...], vec_idx: Tuple[int, ...],
            *refs):
    n_full, n_vec = len(full_idx), len(vec_idx)
    full_refs = refs[:n_full]
    vec_refs = refs[n_full:n_full + n_vec]
    out_ref = refs[-1]
    blocks = {i: r[...] for i, r in zip(full_idx, full_refs)}
    vecs = {i: r[...] for i, r in zip(vec_idx, vec_refs)}
    out_ref[...] = _apply_program(prog, blocks, vecs).astype(out_ref.dtype)


def dfp_fused_call(prog: Program, operands: Sequence[jax.Array],
                   out_shape: Tuple[int, ...], out_dtype,
                   block_rows: int = 0,
                   interpret: bool = False) -> jax.Array:
    d = out_shape[-1]
    rows = 1
    for s in out_shape[:-1]:
        rows *= s

    full_idx = tuple(i for i, k in enumerate(prog.operand_kinds)
                     if k == "full")
    vec_idx = tuple(i for i, k in enumerate(prog.operand_kinds) if k == "vec")

    n_regs = len(prog.instrs) + len(full_idx) + 2
    itemsize = jnp.dtype(out_dtype).itemsize
    br = (clamp_block_rows(block_rows, rows) if block_rows
          else choose_block_rows(rows, d, n_regs, itemsize))
    grid = (pl.cdiv(rows, br),)

    full_ops = [operands[i].reshape(rows, d) for i in full_idx]
    vec_ops = [operands[i].reshape(1, d) for i in vec_idx]

    in_specs = (
        [pl.BlockSpec((br, d), lambda r: (r, 0)) for _ in full_ops] +
        [pl.BlockSpec((1, d), lambda r: (0, 0)) for _ in vec_ops])
    out_spec = pl.BlockSpec((br, d), lambda r: (r, 0))

    out = pl.pallas_call(
        functools.partial(_kernel, prog, full_idx, vec_idx),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, d), out_dtype),
        interpret=interpret,
    )(*full_ops, *vec_ops)
    return out.reshape(out_shape)
