"""Static program encoding for the DFP fused kernel.

The paper's DFP module turns a chain of memory-bound layers into one
depth-first loop nest.  On TPU the analogue is a single Pallas kernel that
streams (rows × features) blocks HBM→VMEM once, applies the whole chain on
the VMEM-resident block, and writes the result back once.

A fusion group is encoded as a tuple of ``Instr`` over a small virtual
register file — the kernel unrolls it at trace time, so the encoding is
static and jit-cacheable.

Register model:
  r0..rk — VMEM block values (full block shape)
Operands:
  kind 'full' — tensor shaped like the chain output (residual inputs)
  kind 'vec'  — last-dim vector broadcast over rows (bias / norm gains)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax

from ...core.ir import Node, OpKind

# (opname, dst, srcs..., imm)
Instr = Tuple[Any, ...]

UNARY = {OpKind.RELU: "relu", OpKind.GELU: "gelu", OpKind.SILU: "silu",
         OpKind.SIGMOID: "sigmoid", OpKind.TANH: "tanh", OpKind.EXP: "exp",
         OpKind.IDENTITY: "copy", OpKind.DROPOUT: "copy"}
BINARY = {OpKind.ADD: "add", OpKind.SUB: "sub", OpKind.MUL: "mul",
          OpKind.DIV: "div"}


@dataclasses.dataclass
class Program:
    instrs: Tuple[Instr, ...]
    operand_kinds: Tuple[str, ...]   # per operand: 'full' | 'vec'
    out_reg: int

    def key(self):
        return (self.instrs, self.operand_kinds, self.out_reg)


def encode_program(fused: Node, env: Dict[int, "jax.Array"]):
    """IR fusion group → (Program, operand list).  Raises NotImplementedError
    for chains the kernel doesn't cover (caller composes instead)."""
    body = fused.body
    out_shape = body[-1].spec.shape
    if len(out_shape) < 2:
        raise NotImplementedError("dfp_fused wants rank>=2")
    d = out_shape[-1]

    operands: List[Any] = []
    operand_kinds: List[str] = []
    op_index: Dict[int, int] = {}     # id(node) -> operand idx
    regs: Dict[int, int] = {}         # id(node) -> register
    next_reg = 0
    instrs: List[Instr] = []
    in_chain = {id(b) for b in body}

    def operand_for(node: Node) -> Tuple[str, int]:
        nonlocal operands
        if id(node) in op_index:
            i = op_index[id(node)]
            return operand_kinds[i], i
        val = env[id(node)]
        if tuple(val.shape) == tuple(out_shape):
            kind = "full"
        elif val.shape == (d,):
            kind = "vec"
        else:
            raise NotImplementedError(f"operand shape {val.shape}")
        op_index[id(node)] = len(operands)
        operands.append(val)
        operand_kinds.append(kind)
        return kind, op_index[id(node)]

    def src_of(node: Node) -> Tuple[str, int]:
        """('reg', r) if produced in-chain else ('op', operand_idx)."""
        if id(node) in in_chain:
            return ("reg", regs[id(node)])
        kind, i = operand_for(node)
        if kind != "full":
            raise NotImplementedError("non-full operand as value source")
        return ("op", i)

    for b in body:
        dst = next_reg
        next_reg += 1
        if b.op in UNARY:
            instrs.append((UNARY[b.op], dst, src_of(b.inputs[0]), None))
        elif b.op in BINARY:
            instrs.append((BINARY[b.op], dst, src_of(b.inputs[0]),
                           src_of(b.inputs[1]), None))
        elif b.op is OpKind.SCALE:
            instrs.append(("scale", dst, src_of(b.inputs[0]),
                           float(b.attrs["value"])))
        elif b.op is OpKind.SOFTCAP:
            instrs.append(("softcap", dst, src_of(b.inputs[0]),
                           float(b.attrs["cap"])))
        elif b.op is OpKind.BIAS_ADD:
            kind, i = operand_for(b.inputs[1])
            if kind != "vec":
                raise NotImplementedError("bias must be a vector")
            instrs.append(("bias", dst, src_of(b.inputs[0]), i, None))
        elif b.op is OpKind.RMSNORM:
            kind, i = operand_for(b.inputs[1])
            if kind != "vec":
                raise NotImplementedError
            instrs.append(("rmsnorm", dst, src_of(b.inputs[0]), i,
                           float(b.attrs.get("eps", 1e-6))))
        elif b.op is OpKind.LAYERNORM:
            kg, gi = operand_for(b.inputs[1])
            kb, bi = operand_for(b.inputs[2])
            if kg != "vec" or kb != "vec":
                raise NotImplementedError
            instrs.append(("layernorm", dst, src_of(b.inputs[0]), gi, bi,
                           float(b.attrs.get("eps", 1e-5))))
        else:
            raise NotImplementedError(f"dfp op {b.op}")
        regs[id(b)] = dst

    prog = Program(tuple(instrs), tuple(operand_kinds),
                   out_reg=regs[id(body[-1])])
    return prog, operands


# ---------------------------------------------------------------------------
# program splitting — the tunable half of DFP fusion-group sizing: a config
# may cap how many instructions execute as one kernel launch, trading one
# extra HBM round-trip per cut against VMEM pressure inside the launch.
# ---------------------------------------------------------------------------

# which Instr slots hold value sources ('reg'/'op' pairs) vs raw operand
# indices of broadcast vectors, per opcode — the knowledge split_program
# needs to renumber a segment's registers and operands
_SRC_SLOTS = {**{op: (2,) for op in
                 ("relu", "gelu", "silu", "sigmoid", "tanh", "exp", "copy",
                  "scale", "softcap", "bias", "rmsnorm", "layernorm")},
              **{op: (2, 3) for op in ("add", "sub", "mul", "div")}}
_VEC_SLOTS = {"bias": (3,), "rmsnorm": (3,), "layernorm": (3, 4)}


def split_points(prog: Program) -> List[int]:
    """Instruction indices ``i`` where the only value live after instruction
    ``i`` is its own destination — the legal places to cut the program,
    because exactly one tensor then crosses the cut."""
    n = len(prog.instrs)
    dst_pos = {ins[1]: j for j, ins in enumerate(prog.instrs)}
    pts: List[int] = []
    for i in range(n - 1):
        live = set()
        for j in range(i + 1, n):
            ins = prog.instrs[j]
            for slot in _SRC_SLOTS[ins[0]]:
                tag, r = ins[slot]
                if tag == "reg" and dst_pos[r] <= i:
                    live.add(r)
        if dst_pos.get(prog.out_reg, n) <= i:
            live.add(prog.out_reg)
        if live == {prog.instrs[i][1]}:
            pts.append(i)
    return pts


def split_program(prog: Program, max_len: int):
    """Split ``prog`` at legal split points into segments of at most
    ``max_len`` instructions (stretching a segment to the next legal point
    when none falls inside the budget).  The value crossing each cut becomes
    a ``'full'`` operand of the following segment.

    Returns ``[(segment, selection), ...]`` where ``selection`` maps each
    segment operand slot to an original operand index, or the string
    ``'carry'`` for the previous segment's output."""
    n = len(prog.instrs)
    if max_len >= n or max_len < 1:
        return [(prog, list(range(len(prog.operand_kinds))))]
    pts = set(split_points(prog))
    cuts: List[int] = []
    start = 0
    while n - start > max_len:
        cut = None
        for i in range(min(start + max_len, n - 1) - 1, start - 1, -1):
            if i in pts:
                cut = i
                break
        if cut is None:
            for i in range(start + max_len, n - 1):
                if i in pts:
                    cut = i
                    break
        if cut is None:
            break
        cuts.append(cut)
        start = cut + 1
    if not cuts:
        return [(prog, list(range(len(prog.operand_kinds))))]

    segments = []
    carry_reg: Optional[int] = None
    lo = 0
    for hi in cuts + [n - 1]:
        sel: List[Any] = []
        kinds: List[str] = []
        op_map: Dict[int, int] = {}
        carry_local: Optional[int] = None
        local_reg: Dict[int, int] = {}
        instrs: List[Instr] = []

        def op_local(orig: int) -> int:
            if orig not in op_map:
                op_map[orig] = len(sel)
                sel.append(orig)
                kinds.append(prog.operand_kinds[orig])
            return op_map[orig]

        for j in range(lo, hi + 1):
            ins = list(prog.instrs[j])
            for slot in _SRC_SLOTS[ins[0]]:
                tag, r = ins[slot]
                if tag == "op":
                    ins[slot] = ("op", op_local(r))
                elif r in local_reg:
                    ins[slot] = ("reg", local_reg[r])
                else:       # produced before this segment: must be the carry
                    assert r == carry_reg, f"non-carry reg {r} crosses a cut"
                    if carry_local is None:
                        carry_local = len(sel)
                        sel.append("carry")
                        kinds.append("full")
                    ins[slot] = ("op", carry_local)
            for slot in _VEC_SLOTS.get(ins[0], ()):
                ins[slot] = op_local(ins[slot])
            local_reg[ins[1]] = j - lo
            ins[1] = j - lo
            instrs.append(tuple(ins))
        segments.append((Program(tuple(instrs), tuple(kinds),
                                 out_reg=hi - lo), sel))
        carry_reg = prog.instrs[hi][1]
        lo = hi + 1
    return segments
