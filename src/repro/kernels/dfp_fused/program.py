"""Static program encoding for the DFP fused kernel.

The paper's DFP module turns a chain of memory-bound layers into one
depth-first loop nest.  On TPU the analogue is a single Pallas kernel that
streams (rows × features) blocks HBM→VMEM once, applies the whole chain on
the VMEM-resident block, and writes the result back once.

A fusion group is encoded as a tuple of ``Instr`` over a small virtual
register file — the kernel unrolls it at trace time, so the encoding is
static and jit-cacheable.

Register model:
  r0..rk — VMEM block values (full block shape)
Operands:
  kind 'full' — tensor shaped like the chain output (residual inputs)
  kind 'vec'  — last-dim vector broadcast over rows (bias / norm gains)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax

from ...core.ir import Node, OpKind

# (opname, dst, srcs..., imm)
Instr = Tuple[Any, ...]

UNARY = {OpKind.RELU: "relu", OpKind.GELU: "gelu", OpKind.SILU: "silu",
         OpKind.SIGMOID: "sigmoid", OpKind.TANH: "tanh", OpKind.EXP: "exp",
         OpKind.IDENTITY: "copy", OpKind.DROPOUT: "copy"}
BINARY = {OpKind.ADD: "add", OpKind.SUB: "sub", OpKind.MUL: "mul",
          OpKind.DIV: "div"}


@dataclasses.dataclass
class Program:
    instrs: Tuple[Instr, ...]
    operand_kinds: Tuple[str, ...]   # per operand: 'full' | 'vec'
    out_reg: int

    def key(self):
        return (self.instrs, self.operand_kinds, self.out_reg)


def encode_program(fused: Node, env: Dict[int, "jax.Array"]):
    """IR fusion group → (Program, operand list).  Raises NotImplementedError
    for chains the kernel doesn't cover (caller composes instead)."""
    body = fused.body
    out_shape = body[-1].spec.shape
    if len(out_shape) < 2:
        raise NotImplementedError("dfp_fused wants rank>=2")
    d = out_shape[-1]

    operands: List[Any] = []
    operand_kinds: List[str] = []
    op_index: Dict[int, int] = {}     # id(node) -> operand idx
    regs: Dict[int, int] = {}         # id(node) -> register
    next_reg = 0
    instrs: List[Instr] = []
    in_chain = {id(b) for b in body}

    def operand_for(node: Node) -> Tuple[str, int]:
        nonlocal operands
        if id(node) in op_index:
            i = op_index[id(node)]
            return operand_kinds[i], i
        val = env[id(node)]
        if tuple(val.shape) == tuple(out_shape):
            kind = "full"
        elif val.shape == (d,):
            kind = "vec"
        else:
            raise NotImplementedError(f"operand shape {val.shape}")
        op_index[id(node)] = len(operands)
        operands.append(val)
        operand_kinds.append(kind)
        return kind, op_index[id(node)]

    def src_of(node: Node) -> Tuple[str, int]:
        """('reg', r) if produced in-chain else ('op', operand_idx)."""
        if id(node) in in_chain:
            return ("reg", regs[id(node)])
        kind, i = operand_for(node)
        if kind != "full":
            raise NotImplementedError("non-full operand as value source")
        return ("op", i)

    for b in body:
        dst = next_reg
        next_reg += 1
        if b.op in UNARY:
            instrs.append((UNARY[b.op], dst, src_of(b.inputs[0]), None))
        elif b.op in BINARY:
            instrs.append((BINARY[b.op], dst, src_of(b.inputs[0]),
                           src_of(b.inputs[1]), None))
        elif b.op is OpKind.SCALE:
            instrs.append(("scale", dst, src_of(b.inputs[0]),
                           float(b.attrs["value"])))
        elif b.op is OpKind.SOFTCAP:
            instrs.append(("softcap", dst, src_of(b.inputs[0]),
                           float(b.attrs["cap"])))
        elif b.op is OpKind.BIAS_ADD:
            kind, i = operand_for(b.inputs[1])
            if kind != "vec":
                raise NotImplementedError("bias must be a vector")
            instrs.append(("bias", dst, src_of(b.inputs[0]), i, None))
        elif b.op is OpKind.RMSNORM:
            kind, i = operand_for(b.inputs[1])
            if kind != "vec":
                raise NotImplementedError
            instrs.append(("rmsnorm", dst, src_of(b.inputs[0]), i,
                           float(b.attrs.get("eps", 1e-6))))
        elif b.op is OpKind.LAYERNORM:
            kg, gi = operand_for(b.inputs[1])
            kb, bi = operand_for(b.inputs[2])
            if kg != "vec" or kb != "vec":
                raise NotImplementedError
            instrs.append(("layernorm", dst, src_of(b.inputs[0]), gi, bi,
                           float(b.attrs.get("eps", 1e-5))))
        else:
            raise NotImplementedError(f"dfp op {b.op}")
        regs[id(b)] = dst

    prog = Program(tuple(instrs), tuple(operand_kinds),
                   out_reg=regs[id(body[-1])])
    return prog, operands
