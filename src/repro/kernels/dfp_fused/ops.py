"""Public wrapper for the DFP fused kernel + its dispatch-table entry.

Registered as the shared-tier impl of ``OpKind.FUSED``: any backend with the
'pallas' capability lowers DFP fusion groups to one VMEM-resident Pallas
program; everyone else falls back to the reference tier, which composes
op-at-a-time (XLA then fuses the chain — the 'vendor stack' flavour).

The impl declares a ``Tunable`` over fusion-group sizing: a config is
``(block_rows, max_group)`` pinned as ``node.attrs['dfp_block']`` —
``block_rows`` overrides the VMEM-budget row-block heuristic, and
``max_group`` caps how many instructions run as one kernel launch
(``program.split_program`` cuts the chain at its legal split points, the
carried value paying one HBM round-trip per cut)."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax

from ...backends import registry
from ...core.autotune import Tunable
from ...core.ir import Node, OpKind
from .kernel import choose_block_rows, clamp_block_rows, dfp_fused_call
from .program import Program, split_program

# ops the Pallas dfp_fused kernel supports as a single VMEM-resident program
DFP_KERNEL_OPS = {
    OpKind.RELU, OpKind.GELU, OpKind.SILU, OpKind.SIGMOID, OpKind.TANH,
    OpKind.EXP, OpKind.ADD, OpKind.SUB, OpKind.MUL, OpKind.DIV,
    OpKind.BIAS_ADD, OpKind.SCALE, OpKind.SOFTCAP, OpKind.RMSNORM,
    OpKind.LAYERNORM, OpKind.IDENTITY, OpKind.DROPOUT,
}


def dfp_fused(prog: Program, operands: Sequence[jax.Array],
              interpret: bool = False, block_rows: int = 0) -> jax.Array:
    # chain output shape == shape of the first 'full' operand
    full = [o for o, k in zip(operands, prog.operand_kinds) if k == "full"]
    if not full:
        raise ValueError("dfp_fused needs at least one full-shape operand")
    out_shape = tuple(full[0].shape)
    out_dtype = full[0].dtype
    return dfp_fused_call(prog, list(operands), out_shape, out_dtype,
                          block_rows=block_rows, interpret=interpret)


def dfp_fused_segmented(prog: Program, operands: Sequence[jax.Array],
                        max_group: int, *, block_rows: int = 0,
                        interpret: bool = False) -> jax.Array:
    """Run a program as ≤``max_group``-instruction kernel launches, the cut
    values round-tripping through HBM between launches."""
    out = None
    for seg, sel in split_program(prog, max_group):
        vals = [out if s == "carry" else operands[s] for s in sel]
        out = dfp_fused(seg, vals, interpret=interpret,
                        block_rows=block_rows)
    return out


def _supports_chain(n: Node) -> bool:
    body = n.body
    return (bool(body)
            and all(b.op in DFP_KERNEL_OPS for b in body)
            and all(b.spec.shape == body[-1].spec.shape
                    or b.op is OpKind.BIAS_ADD for b in body))


def dfp_tune_space(n: Node, hw) -> List[Tuple[int, int]]:
    """Candidate (block_rows, max_group) configs for one FUSED node: the
    VMEM-budget heuristic row block plus coarser/finer power-of-two blocks
    (clamped and VMEM-gated for the body's register count), crossed with the
    whole chain vs a half-length fusion split when the body is long enough
    to have split points worth measuring."""
    shape = n.spec.shape
    body = n.body
    if len(shape) < 2 or not body:
        return []
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    n_regs = len(body) + 3
    auto = choose_block_rows(rows, d, n_regs, 4)
    brs = sorted({clamp_block_rows(c, rows)
                  for c in (auto, 128, 512, 2048)
                  if n_regs * clamp_block_rows(c, rows) * max(d, 128) * 4
                  <= hw.vmem_bytes // 2})
    groups = [len(body)]
    if len(body) >= 4:
        groups.append((len(body) + 1) // 2)
    return [(br, grp) for br in brs for grp in groups]


def _node_config(n: Node) -> Tuple[int, int]:
    cfg = n.attrs.get("dfp_block")
    if not cfg:
        return 0, 0
    return int(cfg[0]), int(cfg[1]) if len(cfg) > 1 else 0


def _dfp_fused_impl(n: Node, vals: Sequence[jax.Array],
                    backend: "registry.Backend") -> jax.Array:
    from ...core.executor import compose_fused
    from .program import encode_program
    env = {id(i): v for i, v in zip(n.inputs, vals)}
    try:
        program, operands = encode_program(n, env)
    except NotImplementedError:
        program = None
    if program is None:   # shapes the kernel doesn't cover — compose instead
        return compose_fused(n, vals, backend)
    block_rows, max_group = _node_config(n)
    if max_group and max_group < len(program.instrs):
        return dfp_fused_segmented(program, operands, max_group,
                                   block_rows=block_rows,
                                   interpret=backend.interpret)
    return dfp_fused(program, operands, interpret=backend.interpret,
                     block_rows=block_rows)


registry.register_shared_impl(
    OpKind.FUSED, _dfp_fused_impl, name="pallas.dfp_fused",
    requires=("pallas",), supports=_supports_chain, memory="streamed",
    tunable=Tunable("dfp_block", dfp_tune_space))
