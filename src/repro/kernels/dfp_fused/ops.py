"""Public wrapper for the DFP fused kernel + its dispatch-table entry.

Registered as the shared-tier impl of ``OpKind.FUSED``: any backend with the
'pallas' capability lowers DFP fusion groups to one VMEM-resident Pallas
program; everyone else falls back to the reference tier, which composes
op-at-a-time (XLA then fuses the chain — the 'vendor stack' flavour)."""
from __future__ import annotations

from typing import Sequence

import jax

from ...backends import registry
from ...core.ir import Node, OpKind
from .kernel import dfp_fused_call
from .program import Program

# ops the Pallas dfp_fused kernel supports as a single VMEM-resident program
DFP_KERNEL_OPS = {
    OpKind.RELU, OpKind.GELU, OpKind.SILU, OpKind.SIGMOID, OpKind.TANH,
    OpKind.EXP, OpKind.ADD, OpKind.SUB, OpKind.MUL, OpKind.DIV,
    OpKind.BIAS_ADD, OpKind.SCALE, OpKind.SOFTCAP, OpKind.RMSNORM,
    OpKind.LAYERNORM, OpKind.IDENTITY, OpKind.DROPOUT,
}


def dfp_fused(prog: Program, operands: Sequence[jax.Array],
              interpret: bool = False) -> jax.Array:
    # chain output shape == shape of the first 'full' operand
    full = [o for o, k in zip(operands, prog.operand_kinds) if k == "full"]
    if not full:
        raise ValueError("dfp_fused needs at least one full-shape operand")
    out_shape = tuple(full[0].shape)
    out_dtype = full[0].dtype
    return dfp_fused_call(prog, list(operands), out_shape, out_dtype,
                          interpret=interpret)


def _supports_chain(n: Node) -> bool:
    body = n.body
    return (bool(body)
            and all(b.op in DFP_KERNEL_OPS for b in body)
            and all(b.spec.shape == body[-1].spec.shape
                    or b.op is OpKind.BIAS_ADD for b in body))


def _dfp_fused_impl(n: Node, vals: Sequence[jax.Array],
                    backend: "registry.Backend") -> jax.Array:
    from ...core.executor import compose_fused
    from .program import encode_program
    env = {id(i): v for i, v in zip(n.inputs, vals)}
    try:
        program, operands = encode_program(n, env)
    except NotImplementedError:
        program = None
    if program is None:   # shapes the kernel doesn't cover — compose instead
        return compose_fused(n, vals, backend)
    return dfp_fused(program, operands, interpret=backend.interpret)


registry.register_shared_impl(
    OpKind.FUSED, _dfp_fused_impl, name="pallas.dfp_fused",
    requires=("pallas",), supports=_supports_chain, memory="streamed")
