"""Public wrapper for the DFP fused kernel."""
from __future__ import annotations

from typing import Sequence

import jax

from .kernel import dfp_fused_call
from .program import Program


def dfp_fused(prog: Program, operands: Sequence[jax.Array],
              interpret: bool = False) -> jax.Array:
    # chain output shape == shape of the first 'full' operand
    full = [o for o, k in zip(operands, prog.operand_kinds) if k == "full"]
    if not full:
        raise ValueError("dfp_fused needs at least one full-shape operand")
    out_shape = tuple(full[0].shape)
    out_dtype = full[0].dtype
    return dfp_fused_call(prog, list(operands), out_shape, out_dtype,
                          interpret=interpret)
