"""Architecture configuration.

One ``ArchConfig`` instance per assigned architecture lives in
``repro.configs.<id>``; ``reduced()`` derives the CPU smoke-test version.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_dense_layers: int = 0       # leading dense layers (Kimi K2 style)
    d_ff_dense: int = 0           # FFN dim of those dense layers
    capacity_factor: float = 1.25
    group_size: int = 1024        # token group for dispatch


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    enc_seq: int                  # encoder sequence length (frames/patches)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    # repeating block pattern; kinds: attn | local | rglru | rwkv
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0               # local-attention window
    softcap_attn: float = 0.0     # gemma2 attn logit softcap
    softcap_final: float = 0.0    # gemma2 final logit softcap
    qkv_bias: bool = False
    attn_out_bias: bool = False
    moe: Optional[MoEConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    frontend: str = ""            # '' | 'audio' | 'vision'  (stub embeddings)
    n_patches: int = 0            # vision stub patch count
    ffn: str = "swiglu"           # swiglu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    parallel_block: bool = False  # command-r: attn & FFN in parallel
    post_norms: bool = False      # gemma2: norm after attn/ffn too
    tie_embeddings: bool = False
    d_rnn: int = 0                # RG-LRU recurrence width (0 → d_model)
    rwkv_head_dim: int = 64
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    # long-context capability: True only for sub-quadratic (SSM/hybrid/linear)
    subquadratic: bool = False
    vocab_pad_multiple: int = 128
    source: str = ""              # provenance tag from the assignment table

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return pad_to_multiple(self.vocab, self.vocab_pad_multiple)

    @property
    def drnn(self) -> int:
        return self.d_rnn or self.d_model

    def pattern_for(self, n_layers: int) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
        """(macro pattern repeated n_macro times, tail kinds)."""
        p = len(self.layer_pattern)
        n_macro = n_layers // p
        tail = n_layers - n_macro * p
        return self.layer_pattern, tuple(self.layer_pattern[:tail])

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        D, V = self.d_model, self.vocab_padded
        total = V * D                       # embed
        if not self.tie_embeddings:
            total += V * D                  # lm head
        kinds = [self.layer_pattern[i % len(self.layer_pattern)]
                 for i in range(self.n_layers)]
        for li, kind in enumerate(kinds):
            total += self._block_params(kind, li)
        if self.enc_dec is not None:
            for _ in range(self.enc_dec.n_enc_layers):
                total += self._block_params("attn", -1, enc=True)
        total += D                          # final norm
        return total

    def _block_params(self, kind: str, li: int, enc: bool = False) -> int:
        D, H, KV, hd = self.d_model, self.n_heads, self.n_kv, self.hd
        n = 2 * D if self.norm == "layernorm" else D   # pre-norm
        if self.post_norms:
            n *= 2
        n *= 2 if not self.parallel_block else 1        # attn norm + ffn norm
        p = n
        if kind in ("attn", "local"):
            p += D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
            if self.qkv_bias:
                p += H * hd + 2 * KV * hd
            if enc or (self.enc_dec is not None and not enc):
                pass
        elif kind == "rglru":
            dr = self.drnn
            p += 2 * D * dr + dr * D + 4 * dr + 3 * dr  # in/gate/out + conv4 + lru
        elif kind == "rwkv":
            p += 4 * D * D + D * D          # r,k,v,g,out
            p += 6 * (D * 64 + 64 * D)      # data-dependent lerp LoRAs (approx)
        if self.enc_dec is not None and not enc and kind in ("attn", "local"):
            # cross-attention in decoder blocks
            p += D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D + n // 2
        # FFN
        if self.moe is not None and li >= self.moe.n_dense_layers and \
                kind not in ("rglru", "rwkv"):
            p += self.moe.n_experts * 3 * D * self.moe.d_expert + \
                D * self.moe.n_experts
        elif kind == "rwkv":
            p += 2 * D * self.d_ff          # rwkv channel-mix (k, v)
        else:
            dff = self.d_ff if not (self.moe and li < self.moe.n_dense_layers) \
                else (self.moe.d_ff_dense or self.d_ff)
            mult = 3 if self.ffn == "swiglu" else 2
            p += mult * D * dff
        return p

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.n_params()
        total = self.n_params()
        dead = (self.moe.n_experts - self.moe.top_k) * 3 * self.d_model * \
            self.moe.d_expert
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if i >= self.moe.n_dense_layers and
            self.layer_pattern[i % len(self.layer_pattern)] not in
            ("rglru", "rwkv"))
        return total - dead * n_moe_layers


def reduced(cfg: ArchConfig, *, n_layers: int = 0, d_model: int = 128,
            vocab: int = 512) -> ArchConfig:
    """Smoke-test shrink of the same family: tiny widths, few experts,
    tiny vocab, same block pattern (one full period + tail coverage)."""
    p = len(cfg.layer_pattern)
    nl = n_layers or (p + min(2, p))      # ≥ one full period + partial tail
    h = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv, h))
    hd = max(8, d_model // h)
    moe = None
    if cfg.moe is not None:
        # capacity_factor = n_experts/top_k → capacity == group size: no
        # token is ever dropped, so decode ≡ forward exactly (capacity
        # drops depend on group partitioning and would make the smoke
        # decode-consistency check routing-luck-dependent)
        moe = MoEConfig(n_experts=8, top_k=2, d_expert=64,
                        n_dense_layers=min(cfg.moe.n_dense_layers, 1),
                        d_ff_dense=128 if cfg.moe.d_ff_dense else 0,
                        capacity_factor=4.0, group_size=64)
    enc_dec = None
    if cfg.enc_dec is not None:
        enc_dec = EncDecConfig(n_enc_layers=2, enc_seq=16)
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=nl, d_model=d_model,
        n_heads=h, n_kv=kv, head_dim=hd, d_ff=4 * d_model, vocab=vocab,
        window=min(cfg.window, 32) if cfg.window else 0,
        moe=moe, enc_dec=enc_dec, d_rnn=d_model if cfg.d_rnn else 0,
        n_patches=8 if cfg.n_patches else 0,
        dtype="float32", vocab_pad_multiple=16)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
