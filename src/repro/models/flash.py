"""Flash attention with a hand-written VJP (jnp, GQA, window, softcap).

Why this exists: AD through the online-softmax KV-chunk scan saves every
per-chunk probability tensor (B,KV,G,Sq,C f32) across the layer scan — on
qwen2-1.5b × train_4k that is the dominant HBM-traffic term (memory term
7.3 s at baseline).  The flash backward recomputes chunk logits from
(q, k, v, L) instead:

  fwd residuals: q, k, v, o (bf16) + L = logsumexp rows (f32)   — O(S)
  bwd: D = Σ do·o; per chunk p = exp(softcap(qkᵀ) − L);
       dv = pᵀdo; ds = p⊙(do vᵀ − D); through-softcap chain;
       dq accumulated, dk/dv emitted per chunk.

This is the jnp mirror of kernels/flash_attention (the Pallas TPU kernel);
both validate against the same oracle in tests.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _chunks(x: Array, nc: int, c: int):
    b, s = x.shape[0], x.shape[1]
    return x.reshape(b, nc, c, *x.shape[2:]).transpose(
        1, 0, 2, *range(3, x.ndim + 1))


def _mask_for(sq: int, c: int, j0: Array, causal: bool, window: int,
              skv: int):
    """(Sq, C) validity mask for the chunk starting at kv position j0."""
    qp = jnp.arange(sq)[:, None]
    kp = j0 + jnp.arange(c)[None, :]
    m = kp < skv
    if causal:
        m &= qp >= kp
    if window:
        m &= qp - kp < window
    return m


def _fwd_scan(qg, k, v, *, causal, window, cap, chunk):
    b, sq, kvh, g, hd = qg.shape
    skv = k.shape[1]
    nc = (skv + chunk - 1) // chunk
    pad = nc * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = _chunks(k, nc, chunk)
    vc = _chunks(v, nc, chunk)
    scale = 1.0 / math.sqrt(hd)

    def step(carry, xs):
        m, l, acc = carry
        j, kb, vb = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        if cap:
            logits = jnp.tanh(logits / cap) * cap
        msk = _mask_for(sq, chunk, j * chunk, causal, window, skv)
        logits = jnp.where(msk[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, g, sq), jnp.float32),
            jnp.zeros((b, kvh, g, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(nc), kc, vc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))              # (B,KV,G,Sq)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_mha(q: Array, k: Array, v: Array, causal: bool = True,
              window: int = 0, cap: float = 0.0,
              chunk: int = 1024) -> Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) → (B,Sq,H,hd).  Causal positions
    are the natural aranges (training/prefill layout)."""
    o, _ = _flash_fwd(q, k, v, causal, window, cap, chunk)
    return o


def _flash_fwd(q, k, v, causal, window, cap, chunk):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, hd)
    o, lse = _fwd_scan(qg, k, v, causal=causal, window=window, cap=cap,
                       chunk=chunk)
    o_out = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
    return o_out, (q, k, v, o, lse)


def _flash_fwd_rule(q, k, v, causal, window, cap, chunk):
    o_out, res = _flash_fwd(q, k, v, causal, window, cap, chunk)
    return o_out, res


def _flash_bwd_rule(causal, window, cap, chunk, res, do):
    q, k, v, o, lse = res                   # o: (B,KV,G,Sq,hd) f32
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    skv = k.shape[1]
    nc = (skv + chunk - 1) // chunk
    pad = nc * chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    kc = _chunks(kp, nc, chunk)
    vc = _chunks(vp, nc, chunk)
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    dog = do.reshape(b, sq, kvh, g, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)           # (B,KV,G,Sq,hd)
    dsum = (dog * o).sum(-1)                # (B,KV,G,Sq)

    def step(dq_acc, xs):
        j, kb, vb = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        if cap:
            capped = jnp.tanh(logits / cap) * cap
        else:
            capped = logits
        msk = _mask_for(sq, chunk, j * chunk, causal, window, skv)
        capped = jnp.where(msk[None, None, None], capped, -1e30)
        p = jnp.exp(capped - lse[..., None])            # (B,KV,G,Sq,C)
        dv = jnp.einsum("bkgqs,bkgqd->bskd", p, dog)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", dog, vb.astype(jnp.float32))
        ds = p * (dp - dsum[..., None])                 # grad wrt capped
        if cap:
            ds = ds * (1.0 - (capped / cap) ** 2)
        ds = jnp.where(msk[None, None, None], ds, 0.0)
        dq_c = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                          kb.astype(jnp.float32)) * scale
        dk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg) * scale
        return dq_acc + dq_c, (dk, dv)

    dq0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (jnp.arange(nc), kc, vc))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, kvh, hd)[:, :skv]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, kvh, hd)[:, :skv]
    return (dq.reshape(b, sq, h, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


flash_mha.defvjp(_flash_fwd_rule, _flash_bwd_rule)
