"""Eager flash attention: a thin ``custom_vjp`` over the registry-backed
backward math.

The chunked forward/backward scans live ONCE, in
``kernels/flash_attention/grad.py`` — the same functions the dispatch
table's ``flash.attention_bwd`` impl runs when an elected graph is
differentiated — so the eager path (this wrapper, used by ``models/layers``)
and the elected path cannot drift numerically.  The only difference is the
residual policy: eager saves the f32 grouped output and the logsumexp rows
from its forward (no recompute); the registry path keeps the default
(q, k, v, o) residuals and recomputes lse with an m/l-only sweep.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.grad import bwd_scan, fwd_scan

Array = jax.Array


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_mha(q: Array, k: Array, v: Array, causal: bool = True,
              window: int = 0, cap: float = 0.0,
              chunk: int = 1024) -> Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) → (B,Sq,H,hd).  Causal positions
    are the natural aranges (training/prefill layout)."""
    o, _ = _flash_fwd(q, k, v, causal, window, cap, chunk)
    return o


def _flash_fwd(q, k, v, causal, window, cap, chunk):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, h // kvh, hd)
    o, lse = fwd_scan(qg, k, v, causal=causal, window=window, cap=cap,
                      chunk=chunk)
    o_out = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)
    return o_out, (q, k, v, o, lse)


def _flash_fwd_rule(q, k, v, causal, window, cap, chunk):
    return _flash_fwd(q, k, v, causal, window, cap, chunk)


def _flash_bwd_rule(causal, window, cap, chunk, res, do):
    q, k, v, o, lse = res                   # o: (B,KV,G,Sq,hd) f32
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    dog = do.reshape(b, sq, kvh, h // kvh, hd).astype(jnp.float32) \
        .transpose(0, 2, 3, 1, 4)           # (B,KV,G,Sq,hd)
    dsum = (dog * o).sum(-1)                # (B,KV,G,Sq)
    return bwd_scan(q, k, v, lse, dsum, do, causal=causal, window=window,
                    cap=cap, chunk=chunk)


flash_mha.defvjp(_flash_fwd_rule, _flash_bwd_rule)
