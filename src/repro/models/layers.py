"""Model layers, written against the SOL backend registry.

The elementwise/norm chains route through the DFP path (fused Pallas kernel
on the pallas backends, XLA fusion on the xla backend); matmuls are the DNN
path (dot_general → MXU).  All functions are pure; params are dict pytrees.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# jax moved shard_map out of experimental (>=0.6) and renamed check_rep →
# check_vma, on independent schedules — detect the kwarg from the signature
# rather than inferring it from where shard_map lives
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                    # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map
try:
    import inspect as _inspect
    _sm_params = _inspect.signature(_shard_map).parameters
    _SHARD_MAP_NOCHECK = ({"check_vma": False} if "check_vma" in _sm_params
                          else {"check_rep": False} if "check_rep" in _sm_params
                          else {})
except (TypeError, ValueError):          # unintrospectable wrapper
    _SHARD_MAP_NOCHECK = {}

# attention chunk size for the flash-style scan (queries keep full length,
# keys/values stream in chunks; online softmax carries m/l/acc)
ATTN_CHUNK = 2048
# use the chunked path when kv length exceeds this
ATTN_CHUNK_THRESHOLD = 2048


# ---------------------------------------------------------------------------
# norms / elementwise
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, gain: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype) * gain


def layernorm(x: Array, gain: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gain + bias


def apply_norm(kind: str, x: Array, p: Dict[str, Array]) -> Array:
    if kind == "layernorm":
        return layernorm(x, p["gain"], p["bias"])
    return rmsnorm(x, p["gain"])


def softcap(x: Array, cap: float) -> Array:
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: (S,) or broadcastable (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., :, None] * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _grouped(q: Array, kv: int) -> Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd): GQA without materializing the
    KV broadcast (the einsums below carry the group dim instead — avoids
    the repeat copy that defeats kv/SP sharding under GSPMD)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv, h // kv, hd)


def _direct_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int, cap: float, q_pos: Array,
                      kv_pos: Array) -> Array:
    """Materialized-logits attention; fine for short sequences.
    q: (B,Sq,H,hd)  k,v: (B,Skv,KV,hd)."""
    kvh = k.shape[2]
    qg = _grouped(q, kvh)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cap:
        logits = softcap(logits, cap)
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    b, sq = q.shape[0], q.shape[1]
    return o.reshape(b, sq, -1, q.shape[-1])


def _chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                       window: int, cap: float, q_pos: Array,
                       kv_pos: Array, chunk: int = ATTN_CHUNK) -> Array:
    """Flash-style online-softmax scan over KV chunks (pure jnp — memory
    O(Sq·chunk) instead of O(Sq·Skv); the Pallas flash kernel is the TPU
    flavour of this same algorithm)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    skv = k.shape[1]
    nc = (skv + chunk - 1) // chunk
    pad = nc * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=2 ** 30)
    kc = k.reshape(b, nc, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nc, chunk)
    scale = 1.0 / math.sqrt(hd)
    qg = _grouped(q, kvh)                       # (B,Sq,KV,G,hd)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                            preferred_element_type=jnp.float32) * scale
        if cap:
            logits = softcap(logits, cap)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= pb[None, :]
        if window:
            mask &= q_pos[:, None] - pb[None, :] < window
        mask &= pb[None, :] < 2 ** 30
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, g, sq), jnp.float32),
            jnp.zeros((b, kvh, g, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,G,Sq,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd).astype(q.dtype)


def multihead_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0, cap: float = 0.0,
                        q_pos: Optional[Array] = None,
                        kv_pos: Optional[Array] = None) -> Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) with KV | H (GQA)."""
    sq, skv = q.shape[1], k.shape[1]
    natural = q_pos is None and kv_pos is None and sq == skv
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if kv_pos is None:
        kv_pos = jnp.arange(skv)
    if skv > ATTN_CHUNK_THRESHOLD and sq > 1:
        if natural:
            # flash path with hand-written VJP: recomputes chunk logits in
            # bwd instead of saving per-chunk probabilities (§Perf attn-1)
            from .flash import flash_mha
            return flash_mha(q, k, v, causal, window, cap, ATTN_CHUNK)
        return _chunked_attention(q, k, v, causal=causal, window=window,
                                  cap=cap, q_pos=q_pos, kv_pos=kv_pos)
    return _direct_attention(q, k, v, causal=causal, window=window, cap=cap,
                             q_pos=q_pos, kv_pos=kv_pos)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                     *, window: int = 0, cap: float = 0.0) -> Array:
    """Single-token decode. q: (B,1,H,hd); caches: (B,S,KV,hd); pos: scalar
    current position (index of the token just written).  Works with the cache
    sequence dim sharded (SP): the masked softmax reductions become
    all-reduces under GSPMD (flash-decoding style)."""
    kvh = k_cache.shape[2]
    qg = _grouped(q, kvh)                                  # (B,1,KV,G,hd)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if cap:
        logits = softcap(logits, cap)
    kv_pos = jnp.arange(k_cache.shape[1])
    valid = kv_pos <= pos                                  # (S,)
    if window:
        valid &= (pos - kv_pos) < window
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v_cache)
    b = q.shape[0]
    return o.reshape(b, 1, -1, q.shape[-1])


# ---------------------------------------------------------------------------
# attention block (projections + rope + residual), parameterized
# ---------------------------------------------------------------------------

def attn_proj_qkv(p: Dict[str, Array], x: Array, cfg) -> Tuple[Array, Array, Array]:
    b, s, d = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv, cfg.hd)
    return q, k, v


def attn_out(p: Dict[str, Array], o: Array) -> Array:
    b, s = o.shape[:2]
    y = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------

def ffn_apply(p: Dict[str, Array], x: Array, kind: str) -> Array:
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        u = jnp.einsum("bsd,df->bsf", x, p["wu"])
        h = jax.nn.silu(g) * u
        return jnp.einsum("bsf,fd->bsd", h, p["wd"])
    # gelu MLP
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]


def moe_apply(p: Dict[str, Array], x: Array, moe_cfg) -> Tuple[Array, Array]:
    """Entry point: manual-SPMD (shard_map) version under a mesh context,
    dense single-device version otherwise."""
    from ..distributed import ctx as dctx
    mesh = dctx._mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and mesh.shape["model"] > 1 \
            and moe_cfg.n_experts % mesh.shape["model"] == 0:
        return _moe_apply_shard_map(p, x, moe_cfg, mesh)
    return _moe_apply_dense(p, x, moe_cfg)


def _moe_apply_shard_map(p, x, moe_cfg, mesh) -> Tuple[Array, Array]:
    """2D-blocked expert parallelism, written as the explicit per-device
    program (shard_map) instead of GSPMD annotations:

      tokens: dp-sharded, model-replicated  (the residual stream already is)
      slot tables: computed locally per dp shard, sliced per model rank
      dispatch gather: LOCAL (zero communication)
      expert FFN: local (E_loc experts per model rank)
      combine: local partial scatter + ONE psum over 'model'
      aux loss: psum-mean over dp

    GSPMD lowers the same math to full-tensor all-reduces around the
    gather/scatter (its scatter partitioner replicates); manual SPMD removes
    every collective except the combine reduction, which is information-
    theoretically required.  See EXPERIMENTS.md §Perf moe-5.
    """
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    b, s, d = x.shape
    e = moe_cfg.n_experts
    e_loc = e // mesh.shape["model"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    x_spec = P(dp if b % dp_size == 0 else None, None, None)
    w_spec = {"router": P(None, None), "wg": P("model", None, None),
              "wu": P("model", None, None), "wd": P("model", None, None)}

    def local_fn(p_loc, x_loc):
        bl, sl, dl = x_loc.shape
        t = bl * sl
        gs = min(moe_cfg.group_size, t)
        ng = t // gs
        xg = x_loc.reshape(ng, gs, dl)
        gates = jax.nn.softmax(
            jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                       p_loc["router"].astype(jnp.float32)), axis=-1)
        topw, topi = jax.lax.top_k(gates, moe_cfg.top_k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

        me = gates.mean(axis=(0, 1))
        ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
            1.0 / (ng * gs * moe_cfg.top_k))
        aux = e * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)

        cap = int(math.ceil(gs * moe_cfg.top_k / e *
                            moe_cfg.capacity_factor))
        cap = max(8, ((cap + 7) // 8) * 8)
        slot_tok, slot_w = _slot_tables(topi, topw, ng, gs,
                                        moe_cfg.top_k, e, cap)
        # each model rank handles its own expert block
        e0 = jax.lax.axis_index("model") * e_loc
        st = jax.lax.dynamic_slice(slot_tok, (0, e0, 0), (ng, e_loc, cap))
        sw = jax.lax.dynamic_slice(slot_w, (0, e0, 0), (ng, e_loc, cap))

        xg_pad = jnp.concatenate([xg, jnp.zeros((ng, 1, dl), xg.dtype)],
                                 axis=1)
        xin = xg_pad[jnp.arange(ng)[:, None, None], st]   # local gather
        g = jnp.einsum("gecd,edf->gecf", xin, p_loc["wg"])
        u = jnp.einsum("gecd,edf->gecf", xin, p_loc["wu"])
        h = jax.nn.silu(g) * u
        y = jnp.einsum("gecf,efd->gecd", h, p_loc["wd"])
        # combine in the residual dtype (bf16): halves the psum payload
        yw = y.astype(x_loc.dtype) * sw[..., None].astype(x_loc.dtype)
        out = jnp.zeros((ng, gs + 1, dl), yw.dtype)
        out = out.at[jnp.arange(ng)[:, None, None], st].add(yw, mode="drop")
        out = jax.lax.psum(out, "model")          # the combine reduction
        return out[:, :gs].reshape(bl, sl, dl), aux

    out, aux = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()),
        **_SHARD_MAP_NOCHECK,
    )({k: p[k] for k in ("router", "wg", "wu", "wd")}, x)
    return out, aux


def _slot_tables(topi, topw, ng, gs, k, e, cap):
    """(G, E, cap) token-id and weight tables from top-k routing (shared by
    the dense and shard_map paths)."""
    flat_e = topi.reshape(ng, gs * k)
    flat_w = topw.reshape(ng, gs * k)
    flat_t = jnp.broadcast_to(jnp.arange(gs)[:, None],
                              (gs, k)).reshape(gs * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_t = flat_t[order]
    sorted_w = jnp.take_along_axis(flat_w, order, axis=-1)
    seg_start = jnp.concatenate([
        jnp.zeros((ng, 1), dtype=bool),
        sorted_e[:, 1:] != sorted_e[:, :-1]], axis=-1)
    pos_all = jnp.broadcast_to(jnp.arange(gs * k)[None, :], sorted_e.shape)
    run_first = jnp.where(seg_start, pos_all, 0)
    run_first = jax.lax.associative_scan(jnp.maximum, run_first, axis=-1)
    slot = pos_all - run_first
    slot_oob = jnp.where(slot < cap, slot, cap)
    slot_tok = jnp.full((ng, e, cap), gs, jnp.int32)
    slot_w = jnp.zeros((ng, e, cap), jnp.float32)
    gidx = jnp.broadcast_to(jnp.arange(ng)[:, None], sorted_e.shape)
    slot_tok = slot_tok.at[gidx, sorted_e, slot_oob].set(
        sorted_t, mode="drop")
    slot_w = slot_w.at[gidx, sorted_e, slot_oob].set(
        sorted_w, mode="drop")
    return slot_tok, slot_w


def _moe_apply_dense(p: Dict[str, Array], x: Array, moe_cfg) -> Tuple[Array, Array]:
    """Gather-based top-k MoE with per-group capacity (no one-hot dispatch
    einsum — keeps HLO FLOPs ~= useful expert FLOPs).  Single-device path;
    the distributed path is _moe_apply_shard_map."""
    b, s, d = x.shape
    e, k = moe_cfg.n_experts, moe_cfg.top_k
    gs = min(moe_cfg.group_size, b * s)
    t = b * s
    ng = t // gs
    xg = x.reshape(ng, gs, d)

    gates = jax.nn.softmax(
        jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                   p["router"].astype(jnp.float32)), axis=-1)   # (G,S,E)
    topw, topi = jax.lax.top_k(gates, k)                        # (G,S,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = gates.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (ng * gs * k))
    aux = e * jnp.sum(me * ce)

    cap = int(math.ceil(gs * k / e * moe_cfg.capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)
    slot_tok, slot_w = _slot_tables(topi, topw, ng, gs, k, e, cap)

    xg_pad = jnp.concatenate([xg, jnp.zeros((ng, 1, d), xg.dtype)], axis=1)
    xin = xg_pad[jnp.arange(ng)[:, None, None], slot_tok]        # (G,E,cap,D)

    # expert FFN (SwiGLU), experts stacked on leading dim
    g = jnp.einsum("gecd,edf->gecf", xin, p["wg"])
    u = jnp.einsum("gecd,edf->gecf", xin, p["wu"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("gecf,efd->gecd", h, p["wd"])                 # (G,E,cap,D)

    # combine: scatter-add back to token positions, weighted
    yw = y * slot_w[..., None].astype(y.dtype)
    out = jnp.zeros((ng, gs + 1, d), y.dtype)
    out = out.at[jnp.arange(ng)[:, None, None],
                 slot_tok].add(yw, mode="drop")
    out = out[:, :gs].reshape(b, s, d)
    return out, aux


# dispatch/combine with sharding-aware custom VJPs: the backward of the
# dispatch gather is the combine scatter and vice versa — writing them
# explicitly lets both directions carry the token-local (dp) constraints,
# which GSPMD's autodiff'd gather/scatter otherwise turns into full-tensor
# all-reduces (measured on olmoe train_4k; EXPERIMENTS.md §Perf moe-4).

@jax.custom_vjp
def _moe_gather(xg_pad: Array, slot_tok: Array) -> Array:
    return _moe_gather_impl(xg_pad, slot_tok)


def _moe_gather_impl(xg_pad, slot_tok):
    from ..distributed.ctx import constrain
    ng = xg_pad.shape[0]
    out = xg_pad[jnp.arange(ng)[:, None, None], slot_tok]
    return constrain(out, ("dp", "model", None, None))


def _moe_gather_fwd(xg_pad, slot_tok):
    return _moe_gather_impl(xg_pad, slot_tok), (slot_tok, xg_pad.shape)


def _moe_gather_bwd(res, ct):
    from ..distributed.ctx import constrain
    slot_tok, shape = res
    ng, gs1, d = shape
    ct = constrain(ct, ("dp", "model", None, None))
    dx = constrain(jnp.zeros(shape, ct.dtype), ("dp", None, None))
    dx = dx.at[jnp.arange(ng)[:, None, None], slot_tok].add(ct, mode="drop")
    return constrain(dx, ("dp", None, None)), None


_moe_gather.defvjp(_moe_gather_fwd, _moe_gather_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _moe_scatter(yw: Array, slot_tok: Array, gs: int) -> Array:
    return _moe_scatter_impl(yw, slot_tok, gs)


def _moe_scatter_impl(yw, slot_tok, gs):
    from ..distributed.ctx import constrain
    ng, e, cap, d = yw.shape
    out = constrain(jnp.zeros((ng, gs + 1, d), yw.dtype),
                    ("dp", None, None))
    out = out.at[jnp.arange(ng)[:, None, None], slot_tok].add(
        yw, mode="drop")
    return constrain(out, ("dp", None, None))


def _moe_scatter_fwd(yw, slot_tok, gs):
    return _moe_scatter_impl(yw, slot_tok, gs), (slot_tok,)


def _moe_scatter_bwd(gs, res, ct):
    from ..distributed.ctx import constrain
    (slot_tok,) = res
    ng = slot_tok.shape[0]
    ct = constrain(ct, ("dp", None, None))
    dyw = ct[jnp.arange(ng)[:, None, None], slot_tok]
    return constrain(dyw, ("dp", "model", None, None)), None


_moe_scatter.defvjp(_moe_scatter_fwd, _moe_scatter_bwd)
