"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and RWKV6 (Finch).

Both are first-order linear recurrences — the TPU-friendly forms are:
  * RG-LRU: log-depth ``jax.lax.associative_scan`` over time (train/prefill)
    and an O(1)-state step (decode).
  * RWKV6: chunked parallel form (FLA-style) — inter-chunk state scan +
    intra-chunk (C×C) parallel attention-like computation, all decays in
    log-space for stability.  Decode is the O(1) per-step recurrence.

These are the paper's "RNN future work" delivered; the elementwise gate
chains are DFP territory (see kernels/rglru_scan, kernels/rwkv6_scan for the
Pallas flavours validated in interpret mode).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

RGLRU_C = 8.0          # Griffin's fixed recurrence sharpness constant
RWKV_CHUNK = 32


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _causal_conv1d(x: Array, w: Array, b: Array,
                   state: Array | None = None) -> Tuple[Array, Array]:
    """Depthwise causal conv, width W.  x: (B,S,D); w: (W,D); b: (D,).
    state: (B, W-1, D) trailing inputs from the previous segment.
    Returns (y, new_state)."""
    bsz, s, d = x.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, width - 1, d), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + xp[:, i:i + s] * w[i]
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return y + b, new_state


def rglru_gates(p: Dict[str, Array], u: Array) -> Tuple[Array, Array]:
    """(log a_t, b_t) from the post-conv branch u: (B,S,dr)."""
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", u, p["wx"]).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    return log_a, b


def rglru_seq(p: Dict[str, Array], u: Array,
              h0: Array | None = None) -> Tuple[Array, Array]:
    """Sequence RG-LRU via associative scan.  u: (B,S,dr).
    Returns (h: (B,S,dr), h_last: (B,dr))."""
    log_a, b = rglru_gates(p, u)
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1].astype(u.dtype)


def rglru_step(p: Dict[str, Array], u: Array, h: Array) -> Tuple[Array, Array]:
    """One decode step.  u: (B,1,dr); h: (B,dr)."""
    log_a, b = rglru_gates(p, u)
    a = jnp.exp(log_a[:, 0])
    h_new = a * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(u.dtype)[:, None], h_new.astype(u.dtype)


def rglru_block_seq(p: Dict[str, Array], x: Array,
                    state: Dict[str, Array] | None = None
                    ) -> Tuple[Array, Dict[str, Array]]:
    """Full Griffin recurrent block (sequence form).
    x: (B,S,D) → (B,S,D), plus carry state for segment continuation."""
    u = jnp.einsum("bsd,de->bse", x, p["w_in"])
    g = jnp.einsum("bsd,de->bse", x, p["w_gate"])
    conv_state = None if state is None else state["conv"]
    h0 = None if state is None else state["h"]
    u, conv_state = _causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)
    h, h_last = rglru_seq(p, u, h0)
    y = h * jax.nn.gelu(g)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"h": h_last, "conv": conv_state}


def rglru_block_step(p: Dict[str, Array], x: Array,
                     state: Dict[str, Array]
                     ) -> Tuple[Array, Dict[str, Array]]:
    u = jnp.einsum("bsd,de->bse", x, p["w_in"])
    g = jnp.einsum("bsd,de->bse", x, p["w_gate"])
    u, conv_state = _causal_conv1d(u, p["conv_w"], p["conv_b"], state["conv"])
    h, h_last = rglru_step(p, u, state["h"])
    y = h * jax.nn.gelu(g)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"h": h_last, "conv": conv_state}


def rglru_init_state(bsz: int, dr: int, conv_width: int, dtype) -> Dict[str, Array]:
    return {"h": jnp.zeros((bsz, dr), dtype),
            "conv": jnp.zeros((bsz, conv_width - 1, dr), dtype)}


# ---------------------------------------------------------------------------
# RWKV6 time mix
# ---------------------------------------------------------------------------

def _lora(x: Array, a: Array, b: Array) -> Array:
    return jnp.einsum("bsr,rd->bsd", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", x, a)), b)


def rwkv_shift(x: Array, last: Array | None) -> Array:
    """Token shift: previous token's features (zeros / carried at start)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None, :]
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def rwkv_mix_inputs(p: Dict[str, Array], x: Array, xs: Array):
    """Data-dependent lerp (RWKV6): per-target mixes for r,k,v,w,g."""
    dx = xs - x
    xm = x + dx * p["mu_x"]
    outs = {}
    for t in ("r", "k", "v", "w", "g"):
        mix = p[f"mu_{t}"] + _lora(xm, p[f"lora_a_{t}"], p[f"lora_b_{t}"])
        outs[t] = x + dx * mix
    return outs


def rwkv_time_mix_seq(p: Dict[str, Array], x: Array, n_heads: int,
                      state: Dict[str, Array] | None = None
                      ) -> Tuple[Array, Dict[str, Array]]:
    """RWKV6 time mix, chunked parallel form.  x: (B,S,D)."""
    bsz, s, d = x.shape
    hd = d // n_heads
    last_x = None if state is None else state["last_x"]
    s0 = None if state is None else state["S"]
    xs = rwkv_shift(x, last_x)
    m = rwkv_mix_inputs(p, x, xs)
    r = jnp.einsum("bsd,de->bse", m["r"], p["wr"]).reshape(bsz, s, n_heads, hd)
    k = jnp.einsum("bsd,de->bse", m["k"], p["wk"]).reshape(bsz, s, n_heads, hd)
    v = jnp.einsum("bsd,de->bse", m["v"], p["wv"]).reshape(bsz, s, n_heads, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", m["g"], p["wg"]))
    logw = -jnp.exp((p["w0"] + _lora(m["w"], p["lora_a_w"], p["lora_b_w"])
                     ).astype(jnp.float32))          # (B,S,D), ≤ 0
    logw = logw.reshape(bsz, s, n_heads, hd)
    u = p["u"].reshape(n_heads, hd)

    o, s_last = _wkv_chunked(r, k, v, logw, u, s0)
    o = o.reshape(bsz, s, d)
    # per-head groupnorm then gate
    og = o.reshape(bsz, s, n_heads, hd).astype(jnp.float32)
    mu = og.mean(-1, keepdims=True)
    var = ((og - mu) ** 2).mean(-1, keepdims=True)
    og = ((og - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(bsz, s, d)
    og = og.astype(x.dtype) * p["gn_gain"] + p["gn_bias"]
    out = jnp.einsum("bsd,de->bse", og * g, p["wo"])
    return out, {"last_x": x[:, -1], "S": s_last}


def _wkv_chunked(r, k, v, logw, u, s0):
    """Chunked WKV.  r,k,v,logw: (B,S,H,hd) with logw ≤ 0; u: (H,hd).
    State S: (B,H,hd_k,hd_v).  Returns (o: (B,S,H,hd), S_last)."""
    bsz, s, h, hd = r.shape
    c = min(RWKV_CHUNK, s)
    while s % c:          # largest divisor ≤ RWKV_CHUNK; exact at any chunk
        c -= 1
    nc = s // c
    rc = r.reshape(bsz, nc, c, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kc = k.reshape(bsz, nc, c, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(bsz, nc, c, h, hd).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    wc = logw.reshape(bsz, nc, c, h, hd).transpose(1, 0, 3, 2, 4)
    if s0 is None:
        s0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)

    idx = jnp.arange(c)
    strict = idx[:, None] > idx[None, :]       # j < i

    def step(S, xs):
        rb, kb, vb, wb = xs                    # (B,H,C,hd)
        cum = jnp.cumsum(wb, axis=2)           # inclusive Σ logw
        p_i = cum - wb                         # exclusive (through i-1)
        # contribution of carried state: (r_i ⊙ e^{p_i}) · S
        rs = rb * jnp.exp(p_i)
        o_state = jnp.einsum("bhck,bhkv->bhcv", rs, S)
        # intra-chunk: s_ij = Σ_d r_i k_j e^{p_i - cum_j}   (j < i)
        # exponent Σ_{l∈(j,i-1]} logw ≤ 0 on the valid triangle, so the exp
        # is computed only there (masked to -inf elsewhere → exact 0).
        dd = p_i[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,H,C,C,hd)
        dd = jnp.where(strict[None, None, :, :, None], dd, -jnp.inf)
        att = jnp.einsum("bhck,bhcjk->bhcj", rb,
                         kb[:, :, None, :, :] * jnp.exp(dd))
        # diagonal bonus term
        diag = jnp.einsum("bhck,bhck->bhc", rb * u[None, :, None, :], kb)
        o = o_state + jnp.einsum("bhcj,bhjv->bhcv", att, vb) \
            + diag[..., None] * vb
        # state update: S' = e^{cum_C} ⊙_k S + Σ_j (k_j e^{cum_C - cum_j})⊗v_j
        tot = cum[:, :, -1:, :]                # (B,H,1,hd)
        kd = kb * jnp.exp(tot - cum)
        S_new = jnp.exp(tot[:, :, 0, :])[..., None] * S + \
            jnp.einsum("bhjk,bhjv->bhkv", kd, vb)
        return S_new, o

    s_last, oc = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(bsz, s, h, hd)
    return o, s_last


def rwkv_time_mix_step(p: Dict[str, Array], x: Array, n_heads: int,
                       state: Dict[str, Array]
                       ) -> Tuple[Array, Dict[str, Array]]:
    """One decode step.  x: (B,1,D)."""
    bsz, _, d = x.shape
    hd = d // n_heads
    xs = rwkv_shift(x, state["last_x"])
    m = rwkv_mix_inputs(p, x, xs)
    r = jnp.einsum("bsd,de->bse", m["r"], p["wr"]).reshape(bsz, n_heads, hd)
    k = jnp.einsum("bsd,de->bse", m["k"], p["wk"]).reshape(bsz, n_heads, hd)
    v = jnp.einsum("bsd,de->bse", m["v"], p["wv"]).reshape(bsz, n_heads, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", m["g"], p["wg"]))[:, 0]
    logw = -jnp.exp((p["w0"] + _lora(m["w"], p["lora_a_w"], p["lora_b_w"])
                     ).astype(jnp.float32))[:, 0].reshape(bsz, n_heads, hd)
    u = p["u"].reshape(n_heads, hd)
    S = state["S"]
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    o = jnp.einsum("bhk,bhkv->bhv", rf, S) + \
        jnp.einsum("bhk,bhk,bhv->bhv", rf, u[None] * kf, vf)
    S_new = jnp.exp(logw)[..., None] * S + \
        jnp.einsum("bhk,bhv->bhkv", kf, vf)
    o = o.reshape(bsz, d)
    of = o.reshape(bsz, n_heads, hd)
    mu = of.mean(-1, keepdims=True)
    var = ((of - mu) ** 2).mean(-1, keepdims=True)
    of = ((of - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(bsz, d)
    og = of.astype(x.dtype) * p["gn_gain"] + p["gn_bias"]
    out = jnp.einsum("bd,de->be", og * g, p["wo"])[:, None]
    return out, {"last_x": x[:, -1], "S": S_new}


def rwkv_channel_mix_seq(p: Dict[str, Array], x: Array,
                         last_x: Array | None = None
                         ) -> Tuple[Array, Array]:
    xs = rwkv_shift(x, last_x)
    dx = xs - x
    xk = x + dx * p["mu_ck"]
    xr = x + dx * p["mu_cr"]
    kk = jnp.einsum("bsd,df->bsf", xk, p["ck"])
    kk = jnp.square(jnp.maximum(kk, 0.0))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cr"]))
    out = rr * jnp.einsum("bsf,fd->bsd", kk, p["cv"])
    return out, x[:, -1]


def rwkv_init_state(bsz: int, d: int, n_heads: int, dtype) -> Dict[str, Array]:
    hd = d // n_heads
    return {"last_x": jnp.zeros((bsz, d), dtype),
            "S": jnp.zeros((bsz, n_heads, hd, hd), jnp.float32),
            "last_xc": jnp.zeros((bsz, d), dtype)}
