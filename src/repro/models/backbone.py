"""The unified LM backbone covering all 10 assigned architectures.

Layers repeat in ``cfg.layer_pattern`` (period p); parameters for each
pattern position are stacked over macro-blocks so the layer loop is a single
``jax.lax.scan`` per position-tuple (constant-size HLO regardless of depth —
essential for 100-layer configs on the 512-device dry-run).  The remainder
layers (n_layers % p) run unscanned.

Pure functions throughout; params/caches are dict pytrees.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import recurrent as R
from .config import ArchConfig

Array = jax.Array
CONV_WIDTH = 4     # RG-LRU depthwise conv width
LORA_R = 32        # RWKV6 data-dependent-lerp LoRA rank


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_params(cfg: ArchConfig, d: int, key) -> Dict[str, Array]:
    dt = jnp.dtype(cfg.dtype)
    if cfg.norm == "layernorm":
        return {"gain": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
    return {"gain": jnp.ones((d,), dt)}


def _dense(key, shape, dtype, scale=None) -> Array:
    scale = scale or 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(cfg: ArchConfig, key, cross: bool = False) -> Dict[str, Array]:
    # NOTE: fused-QKV (one column-parallel einsum) was tried and REFUTED on
    # the lowered IR: the post-einsum splits materialize q/k/v copies that
    # cost more HBM traffic than the saved bwd all-reduces (§Perf fuse-1).
    # On real TPUs the same all-reduce merge comes from XLA's collective
    # combiner without the copies.
    dt = jnp.dtype(cfg.dtype)
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 8)
    pre = "x" if cross else ""
    p = {
        pre + "wq": _dense(ks[0], (D, H * hd), dt),
        pre + "wk": _dense(ks[1], (D, KV * hd), dt),
        pre + "wv": _dense(ks[2], (D, KV * hd), dt),
        pre + "wo": _dense(ks[3], (H * hd, D), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    if cfg.attn_out_bias and not cross:
        p["bo"] = jnp.zeros((D,), dt)
    return p


def _ffn_params(cfg: ArchConfig, key, d_ff: int) -> Dict[str, Array]:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.ffn == "swiglu":
        return {"wg": _dense(ks[0], (D, d_ff), dt),
                "wu": _dense(ks[1], (D, d_ff), dt),
                "wd": _dense(ks[2], (d_ff, D), dt)}
    return {"w1": _dense(ks[0], (D, d_ff), dt),
            "b1": jnp.zeros((d_ff,), dt),
            "w2": _dense(ks[1], (d_ff, D), dt),
            "b2": jnp.zeros((D,), dt)}


def _moe_params(cfg: ArchConfig, key) -> Dict[str, Array]:
    dt = jnp.dtype(cfg.dtype)
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_expert
    ks = jax.random.split(key, 4)
    return {"router": _dense(ks[0], (D, E), jnp.float32),
            "wg": _dense(ks[1], (E, D, F), dt),
            "wu": _dense(ks[2], (E, D, F), dt),
            "wd": _dense(ks[3], (E, F, D), dt)}


def _rglru_params(cfg: ArchConfig, key) -> Dict[str, Array]:
    dt = jnp.dtype(cfg.dtype)
    D, dr = cfg.d_model, cfg.drnn
    ks = jax.random.split(key, 7)
    lam = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.5, 4.0)
    return {"w_in": _dense(ks[0], (D, dr), dt),
            "w_gate": _dense(ks[1], (D, dr), dt),
            "w_out": _dense(ks[2], (dr, D), dt),
            "conv_w": _dense(ks[3], (CONV_WIDTH, dr), dt, scale=0.3),
            "conv_b": jnp.zeros((dr,), dt),
            "wa": _dense(ks[4], (dr, dr), dt),
            "wx": _dense(ks[6], (dr, dr), dt),
            "lam": lam}


def _rwkv_params(cfg: ArchConfig, key) -> Dict[str, Array]:
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    ks = iter(jax.random.split(key, 24))
    p: Dict[str, Array] = {"mu_x": jnp.full((D,), 0.5, dt)}
    for t in ("r", "k", "v", "w", "g"):
        p[f"mu_{t}"] = jnp.full((D,), 0.5, dt)
        p[f"lora_a_{t}"] = _dense(next(ks), (D, LORA_R), dt)
        p[f"lora_b_{t}"] = _dense(next(ks), (LORA_R, D), dt, scale=0.01)
    for t in ("r", "k", "v", "g", "o"):
        p[f"w{t}"] = _dense(next(ks), (D, D), dt)
    p["w0"] = jnp.full((D,), -1.0, dt)       # resting decay ≈ exp(-e^{-1})
    p["u"] = _dense(next(ks), (D,), jnp.float32, scale=0.3)
    p["gn_gain"] = jnp.ones((D,), dt)
    p["gn_bias"] = jnp.zeros((D,), dt)
    # channel mix
    p["mu_ck"] = jnp.full((D,), 0.5, dt)
    p["mu_cr"] = jnp.full((D,), 0.5, dt)
    p["ck"] = _dense(next(ks), (D, cfg.d_ff), dt)
    p["cv"] = _dense(next(ks), (cfg.d_ff, D), dt)
    p["cr"] = _dense(next(ks), (D, D), dt)
    return p


def _block_params(cfg: ArchConfig, kind: str, layer_idx: int, key,
                  decoder: bool = True) -> Dict[str, Array]:
    ks = jax.random.split(key, 6)
    p: Dict[str, Array] = {"ln1": _norm_params(cfg, cfg.d_model, ks[0])}
    if kind == "rwkv":
        p.update(_rwkv_params(cfg, ks[1]))
        p["ln2"] = _norm_params(cfg, cfg.d_model, ks[2])
        return p
    if kind == "rglru":
        p.update(_rglru_params(cfg, ks[1]))
    else:
        p.update(_attn_params(cfg, ks[1]))
    if cfg.enc_dec is not None and decoder and kind in ("attn", "local"):
        p["lnx"] = _norm_params(cfg, cfg.d_model, ks[5])
        p.update(_attn_params(cfg, ks[4], cross=True))
    if not cfg.parallel_block:
        p["ln2"] = _norm_params(cfg, cfg.d_model, ks[2])
    if cfg.post_norms:
        p["ln1p"] = _norm_params(cfg, cfg.d_model, ks[3])
        p["ln2p"] = _norm_params(cfg, cfg.d_model, ks[3])
    if cfg.moe is not None and layer_idx >= cfg.moe.n_dense_layers:
        p["moe"] = _moe_params(cfg, ks[3])
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and layer_idx < cfg.moe.n_dense_layers:
            d_ff = cfg.moe.d_ff_dense or cfg.d_ff
        p["ffn"] = _ffn_params(cfg, ks[3], d_ff)
    return p


def layer_kinds(cfg: ArchConfig) -> List[str]:
    p = len(cfg.layer_pattern)
    return [cfg.layer_pattern[i % p] for i in range(cfg.n_layers)]


def macro_split(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(n_head, n_macro, n_tail).

    ``head`` = leading unscanned layers whose params differ from the scanned
    body (MoE models with leading dense layers — Kimi K2's layer 0);
    ``macro`` = scanned repetitions of the full pattern; ``tail`` = trailing
    partial period, unscanned."""
    n_head = cfg.moe.n_dense_layers if cfg.moe is not None else 0
    p = len(cfg.layer_pattern)
    rem = cfg.n_layers - n_head
    return n_head, rem // p, rem % p


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    ks = iter(jax.random.split(key, 64))
    V, D = cfg.vocab_padded, cfg.d_model
    params: Dict[str, Any] = {
        "embed": _dense(next(ks), (V, D), dt, scale=0.02),
        "ln_f": _norm_params(cfg, D, next(ks)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(next(ks), (D, V), dt)
    n_head, n_macro, n_tail = macro_split(cfg)
    period = cfg.layer_pattern
    kinds = layer_kinds(cfg)

    params["head"] = {
        f"layer{i}": _block_params(cfg, kinds[i], i, next(ks))
        for i in range(n_head)}

    def stacked(kind: str, pos: int) -> Dict[str, Array]:
        subkeys = jax.random.split(next(ks), n_macro)
        ps = [_block_params(cfg, kind, n_head + m * len(period) + pos,
                            subkeys[m])
              for m in range(n_macro)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)

    if n_macro:
        params["macro"] = {f"pos{i}": stacked(kind, i)
                           for i, kind in enumerate(period)}
    params["tail"] = {
        f"layer{i}": _block_params(
            cfg, period[i], n_head + n_macro * len(period) + i, next(ks))
        for i in range(n_tail)}
    if cfg.enc_dec is not None:
        enc_cfg = dataclasses.replace(cfg, moe=None, parallel_block=False)
        params["encoder"] = {
            f"layer{i}": _block_params(enc_cfg, "attn", i, next(ks),
                                       decoder=False)
            for i in range(cfg.enc_dec.n_enc_layers)}
        params["enc_ln_f"] = _norm_params(cfg, D, next(ks))
        params["enc_pos"] = _dense(next(ks), (cfg.enc_dec.enc_seq, D), dt,
                                   scale=0.02)
    return params


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree (no allocation) for the dry-run."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def count_params(cfg: ArchConfig) -> int:
    specs = param_specs(cfg)
    return sum(int(np_prod(x.shape)) for x in jax.tree.leaves(specs))


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def count_active_params(cfg: ArchConfig) -> int:
    """Per-token active params: MoE counts top_k of n_experts expert params."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    specs = param_specs(cfg)
    expert = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(specs):
        if any(getattr(k, "key", None) == "moe" for k in path):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name != "router":
                expert += np_prod(leaf.shape)
    return total - expert + int(expert * cfg.moe.top_k / cfg.moe.n_experts)


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _maybe_post(cfg, p, key, y):
    return L.apply_norm(cfg.norm, y, p[key]) if cfg.post_norms else y


def _attn_sublayer(cfg: ArchConfig, p, h, kind: str, positions,
                   kv_cache=None, decode_pos=None):
    """Returns (out, new_kv) — new_kv is None outside decode/prefill-cache."""
    window = cfg.window if kind == "local" else 0
    q, k, v = L.attn_proj_qkv(p, h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    new_kv = None
    if kv_cache is not None and decode_pos is not None \
            and not isinstance(kv_cache, str):
        kc, vc = kv_cache
        cache_len = kc.shape[1]
        ring = bool(window) and cache_len == window
        write_pos = decode_pos % window if ring else decode_pos
        kc = jax.lax.dynamic_update_slice(kc, k, (0, write_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, write_pos, 0, 0))
        # ring caches hold exactly the last `window` tokens → no distance
        # mask needed; slot-written masking via `decode_pos` still applies
        # while the ring is filling (decode_pos < window).
        o = L.decode_attention(q, kc, vc, decode_pos,
                               window=0 if ring else window,
                               cap=cfg.softcap_attn)
        new_kv = (kc, vc)
    else:
        # positions here are always the natural arange (train/prefill), so
        # q_pos/kv_pos stay None → the flash custom-VJP path applies
        o = L.multihead_attention(q, k, v, causal=True, window=window,
                                  cap=cfg.softcap_attn)
        if kv_cache == "collect":
            new_kv = (k, v)
    return L.attn_out(p, o), new_kv


def _cross_sublayer(cfg: ArchConfig, p, h, enc_out):
    b, s, _ = h.shape
    q = jnp.einsum("bsd,dh->bsh", h, p["xwq"]).reshape(
        b, s, cfg.n_heads, cfg.hd)
    es = enc_out.shape[1]
    ek = jnp.einsum("bsd,dh->bsh", enc_out, p["xwk"]).reshape(
        b, es, cfg.n_kv, cfg.hd)
    ev = jnp.einsum("bsd,dh->bsh", enc_out, p["xwv"]).reshape(
        b, es, cfg.n_kv, cfg.hd)
    o = L.multihead_attention(q, ek, ev, causal=False)
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), p["xwo"])


def _ffn_sublayer(cfg: ArchConfig, p, h, layer_is_moe: bool):
    if layer_is_moe:
        return L.moe_apply(p["moe"], h, cfg.moe)
    return L.ffn_apply(p["ffn"], h, cfg.ffn), 0.0


def apply_block(cfg: ArchConfig, kind: str, p, h, positions, *,
                is_moe: bool, state=None, decode_pos=None, enc_kv=None,
                mode: str = "train"):
    """One full block.  Returns (h, aux_loss, new_state)."""
    new_state: Any = None
    if kind == "rwkv":
        hn = L.apply_norm(cfg.norm, h, p["ln1"])
        if mode == "decode":
            o, st = R.rwkv_time_mix_step(
                p, hn, cfg.d_model // cfg.rwkv_head_dim, state)
        else:
            o, st = R.rwkv_time_mix_seq(
                p, hn, cfg.d_model // cfg.rwkv_head_dim,
                state if mode == "prefill_cached" else None)
        h = h + o
        hn = L.apply_norm(cfg.norm, h, p["ln2"])
        lastc = state["last_xc"] if (state is not None and mode == "decode") \
            else None
        o, last_xc = R.rwkv_channel_mix_seq(p, hn, lastc)
        h = h + o
        if mode in ("decode", "prefill_cached"):
            new_state = {**st, "last_xc": last_xc}
        return h, 0.0, new_state

    hn = L.apply_norm(cfg.norm, h, p["ln1"])
    if kind == "rglru":
        if mode == "decode":
            o, new_state = R.rglru_block_step(p, hn, state)
        else:
            o, new_state = R.rglru_block_seq(
                p, hn, state if mode == "prefill_cached" else None)
            if mode not in ("decode", "prefill_cached"):
                new_state = None
        attn_out = _maybe_post(cfg, p, "ln1p", o)
    else:
        kv_cache = None
        if mode == "decode":
            kv_cache = state
        elif mode == "prefill_cached":
            kv_cache = "collect"
        o, new_kv = _attn_sublayer(cfg, p, hn, kind, positions,
                                   kv_cache=kv_cache, decode_pos=decode_pos)
        attn_out = _maybe_post(cfg, p, "ln1p", o)
        new_state = new_kv

    if cfg.parallel_block:
        f, aux = _ffn_sublayer(cfg, p, hn, is_moe)
        h = h + attn_out + f
        return h, aux, new_state

    h = h + attn_out
    if enc_kv is not None and "xwq" in p:
        hx = L.apply_norm(cfg.norm, h, p["lnx"])
        h = h + _cross_sublayer(cfg, p, hx, enc_kv)
    hn2 = L.apply_norm(cfg.norm, h, p["ln2"])
    f, aux = _ffn_sublayer(cfg, p, hn2, is_moe)
    h = h + _maybe_post(cfg, p, "ln2p", f)
    return h, aux, new_state


# ---------------------------------------------------------------------------
# embedding / head / encoder / frontends
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ArchConfig, params, tokens: Array) -> Array:
    h = params["embed"][tokens] * math.sqrt(cfg.d_model)
    return h.astype(jnp.dtype(cfg.dtype))


def lm_logits(cfg: ArchConfig, params, h: Array) -> Array:
    h = L.apply_norm(cfg.norm, h, params["ln_f"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    logits = logits.astype(jnp.float32)
    if cfg.softcap_final:
        logits = L.softcap(logits, cfg.softcap_final)
    return logits


def _is_moe_layer(cfg: ArchConfig, layer_idx: int, kind: str) -> bool:
    return (cfg.moe is not None and layer_idx >= cfg.moe.n_dense_layers
            and kind not in ("rglru", "rwkv"))


def run_encoder(cfg: ArchConfig, params, frames: Array) -> Array:
    """Audio/encoder stack over precomputed frame embeddings (conv frontend
    is a stub per the assignment: input_specs supplies the embeddings)."""
    h = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"]
    positions = jnp.arange(h.shape[1])
    for i in range(cfg.enc_dec.n_enc_layers):
        p = params["encoder"][f"layer{i}"]
        hn = L.apply_norm(cfg.norm, h, p["ln1"])
        q, k, v = L.attn_proj_qkv(p, hn, cfg)
        o = L.multihead_attention(q, k, v, causal=False, q_pos=positions,
                                  kv_pos=positions)
        h = h + L.attn_out(p, o)
        hn = L.apply_norm(cfg.norm, h, p["ln2"])
        f, _ = _ffn_sublayer(cfg, p, hn, False)
        h = h + f
    return L.apply_norm(cfg.norm, h, params["enc_ln_f"])


# ---------------------------------------------------------------------------
# full-model paths: train forward / prefill / decode
# ---------------------------------------------------------------------------

def _stack_inputs(cfg: ArchConfig, params, batch: Dict[str, Array]) -> Tuple[Array, Optional[Array]]:
    """Token embedding + modality stubs.  Returns (h, enc_out)."""
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    enc_out = None
    if cfg.frontend == "vision" and "patches" in batch:
        patches = batch["patches"].astype(h.dtype)     # (B, n_patches, D)
        h = jnp.concatenate([patches, h], axis=1)
    if cfg.frontend == "audio" and "frames" in batch:
        enc_out = run_encoder(cfg, params, batch["frames"])
    return h, enc_out


def _run_layers(cfg: ArchConfig, params, h: Array, positions, enc_out,
                remat: bool = False) -> Tuple[Array, Array]:
    """Train/eval forward through all layers.  Returns (h, aux_loss)."""
    n_head, n_macro, n_tail = macro_split(cfg)
    period = cfg.layer_pattern
    kinds = layer_kinds(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    for i in range(n_head):
        h, aux, _ = apply_block(cfg, kinds[i], params["head"][f"layer{i}"],
                                h, positions, is_moe=False, enc_kv=enc_out)
        aux_total += aux

    if n_macro:
        def body(carry, xs):
            h, aux_total = carry
            for i, kind in enumerate(period):
                h, aux, _ = apply_block(
                    cfg, kind, xs[f"pos{i}"], h, positions,
                    is_moe=_is_moe_layer(cfg, n_head, kind),
                    enc_kv=enc_out)
                aux_total += aux
            return (h, aux_total), None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total),
                                         params["macro"])

    base = n_head + n_macro * len(period)
    for i in range(n_tail):
        h, aux, _ = apply_block(
            cfg, period[i], params["tail"][f"layer{i}"], h, positions,
            is_moe=_is_moe_layer(cfg, base + i, period[i]),
            enc_kv=enc_out)
        aux_total += aux
    return h, aux_total


def forward(cfg: ArchConfig, params, batch: Dict[str, Array], *,
            remat: bool = False) -> Tuple[Array, Array]:
    """Training/eval forward.  Returns (logits, aux_loss)."""
    h, enc_out = _stack_inputs(cfg, params, batch)
    positions = jnp.arange(h.shape[1])
    h, aux = _run_layers(cfg, params, h, positions, enc_out, remat=remat)
    return lm_logits(cfg, params, h), aux


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, Array], *,
            remat: bool = False, aux_weight: float = 0.01) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patches" in batch:
        # patch positions carry no next-token loss
        logits = logits[:, batch["patches"].shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = ce + aux_weight * aux
    return total, {"ce": ce, "aux": aux}


# -- caches -------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """Decode cache pytree mirroring the head/macro/tail param structure."""
    dt = jnp.dtype(cfg.dtype)
    n_head, n_macro, n_tail = macro_split(cfg)
    period = cfg.layer_pattern

    def one(kind: str):
        if kind == "rglru":
            return R.rglru_init_state(batch, cfg.drnn, CONV_WIDTH, dt)
        if kind == "rwkv":
            return R.rwkv_init_state(batch, cfg.d_model,
                                     cfg.d_model // cfg.rwkv_head_dim, dt)
        s = min(max_seq, cfg.window) if kind == "local" and cfg.window \
            else max_seq
        # local layers still get a full-length cache when window >= max_seq
        return (jnp.zeros((batch, s, cfg.n_kv, cfg.hd), dt),
                jnp.zeros((batch, s, cfg.n_kv, cfg.hd), dt))

    def stack(kind: str):
        x = one(kind)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_macro,) + a.shape), x)

    kinds = layer_kinds(cfg)
    cache: Dict[str, Any] = {
        "head": {f"layer{i}": one(kinds[i]) for i in range(n_head)},
        "tail": {f"layer{i}": one(period[i]) for i in range(n_tail)},
    }
    if n_macro:
        cache["macro"] = {f"pos{i}": stack(k) for i, k in enumerate(period)}
    return cache


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


def decode_step(cfg: ArchConfig, params, cache, tokens: Array, pos: Array,
                enc_out: Optional[Array] = None
                ) -> Tuple[Array, Dict[str, Any]]:
    """One token for the whole batch.  tokens: (B,1); pos: scalar int32.
    Returns (logits (B,1,V), new cache)."""
    h = embed_tokens(cfg, params, tokens)
    positions = pos[None] if pos.ndim == 0 else pos
    n_head, n_macro, n_tail = macro_split(cfg)
    period = cfg.layer_pattern
    kinds = layer_kinds(cfg)
    new_cache: Dict[str, Any] = {"head": {}, "tail": {}}

    for i in range(n_head):
        h, _, st = apply_block(cfg, kinds[i], params["head"][f"layer{i}"],
                               h, positions, is_moe=False,
                               state=cache["head"][f"layer{i}"],
                               decode_pos=pos, enc_kv=enc_out, mode="decode")
        new_cache["head"][f"layer{i}"] = st

    if n_macro:
        def body(h, xs):
            p_slice, c_slice = xs
            sts = {}
            for i, kind in enumerate(period):
                h, _, st = apply_block(
                    cfg, kind, p_slice[f"pos{i}"], h, positions,
                    is_moe=_is_moe_layer(cfg, n_head, kind),
                    state=c_slice[f"pos{i}"], decode_pos=pos,
                    enc_kv=enc_out, mode="decode")
                sts[f"pos{i}"] = st
            return h, sts

        h, macro_cache = jax.lax.scan(
            body, h, (params["macro"], cache["macro"]))
        new_cache["macro"] = macro_cache

    base = n_head + n_macro * len(period)
    for i in range(n_tail):
        h, _, st = apply_block(
            cfg, period[i], params["tail"][f"layer{i}"], h, positions,
            is_moe=_is_moe_layer(cfg, base + i, period[i]),
            state=cache["tail"][f"layer{i}"], decode_pos=pos,
            enc_kv=enc_out, mode="decode")
        new_cache["tail"][f"layer{i}"] = st

    return lm_logits(cfg, params, h), new_cache


def prefill(cfg: ArchConfig, params, batch: Dict[str, Array]
            ) -> Tuple[Array, Array]:
    """Prefill forward: full-sequence logits (serving fills the KV cache from
    the same activations; the dry-run lowers this path for prefill shapes)."""
    return forward(cfg, params, batch)

