from .config import (ArchConfig, MoEConfig, EncDecConfig, ShapeConfig,
                     SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
                     reduced)
from . import backbone, layers, recurrent

__all__ = ["ArchConfig", "MoEConfig", "EncDecConfig", "ShapeConfig",
           "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "reduced", "backbone", "layers", "recurrent"]
