"""The SOL runtime's asynchronous execution queue (paper Sec. IV-C).

The paper's design, reproduced:

  * a device-side execution queue mimicking CUDA streams, operated by a
    host thread so that enqueue never blocks;
  * **asynchronous malloc/free via 64-bit virtual pointers**: allocation
    returns immediately with a token whose first 32 bits are a unique
    reference number and second 32 bits an offset, so virtual pointers
    support ordinary pointer arithmetic while the real allocation happens
    later, in queue order — removing the malloc/free synchronization points;
  * adjacent small memcopies are gathered and grouped (see ``packed.py``).

On JAX the analogous machinery already exists inside the runtime (async
dispatch, buffer donation), so this module serves two roles: (1) a faithful,
unit-tested model of the paper's mechanism, used by the transparent-offload
frontend for host↔device staging; (2) the instrumentation point where
straggler/queue-depth statistics are collected.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

_REF_BITS = 32
_OFF_MASK = (1 << _REF_BITS) - 1


class UseAfterFreeError(RuntimeError):
    """A virtual pointer was dereferenced (or double-freed) after its
    allocation was released — the async-malloc analogue of a dangling CUDA
    pointer.  Carries the offending ref number so the failing allocation is
    identifiable from the message alone."""

    def __init__(self, ref: int, action: str):
        self.ref = ref
        super().__init__(
            f"virtual ref {ref} used after free (or never allocated) "
            f"during {action}")


@dataclasses.dataclass(frozen=True)
class VirtualPtr:
    """64-bit virtual pointer: (ref << 32) | offset."""

    raw: int

    @property
    def ref(self) -> int:
        return self.raw >> _REF_BITS

    @property
    def offset(self) -> int:
        return self.raw & _OFF_MASK

    def __add__(self, delta: int) -> "VirtualPtr":
        off = self.offset + delta
        if off < 0 or off > _OFF_MASK:
            raise ValueError("virtual pointer offset out of 32-bit range")
        return VirtualPtr((self.ref << _REF_BITS) | off)

    def __sub__(self, delta: int) -> "VirtualPtr":
        return self.__add__(-delta)


class VirtualAllocator:
    """Async malloc/free: returns virtual pointers immediately; the backing
    buffers materialize when the queue executes the allocation."""

    def __init__(self):
        self._next_ref = 1
        self._buffers: Dict[int, Optional[np.ndarray]] = {}
        self._sizes: Dict[int, int] = {}
        self._lock = threading.Lock()

    def malloc(self, nbytes: int) -> VirtualPtr:
        with self._lock:
            ref = self._next_ref
            self._next_ref += 1
            self._buffers[ref] = None      # not yet materialized
            self._sizes[ref] = nbytes
        return VirtualPtr(ref << _REF_BITS)

    def materialize(self, ptr: VirtualPtr) -> None:
        with self._lock:
            if ptr.ref not in self._sizes:
                raise UseAfterFreeError(ptr.ref, "materialize")
            if self._buffers.get(ptr.ref) is None:
                self._buffers[ptr.ref] = np.zeros(self._sizes[ptr.ref],
                                                  np.uint8)

    def resolve(self, ptr: VirtualPtr) -> np.ndarray:
        self.materialize(ptr)
        with self._lock:
            buf = self._buffers.get(ptr.ref)
        if buf is None:
            raise UseAfterFreeError(ptr.ref, "resolve")
        return buf[ptr.offset:]

    def free(self, ptr: VirtualPtr) -> None:
        # async free: dropped when the queue drains past this point; freeing
        # a ref that was never allocated (or already freed) is a bug in the
        # caller's pointer bookkeeping and must not pass silently
        with self._lock:
            if ptr.ref not in self._sizes:
                raise UseAfterFreeError(ptr.ref, "free")
            self._buffers.pop(ptr.ref, None)
            self._sizes.pop(ptr.ref, None)

    @property
    def live_refs(self) -> int:
        with self._lock:
            return len(self._buffers)


@dataclasses.dataclass
class _QueueItem:
    kind: str                  # 'malloc' | 'free' | 'memcpy' | 'kernel' | 'sync'
    fn: Optional[Callable[[], Any]]
    event: Optional[threading.Event]


class AsyncQueue:
    """Ordered async execution queue (CUDA-stream-like)."""

    def __init__(self, allocator: Optional[VirtualAllocator] = None):
        self.allocator = allocator or VirtualAllocator()
        self._q: "queue.Queue[_QueueItem]" = queue.Queue()
        self._stats = {"enqueued": 0, "executed": 0, "max_depth": 0,
                       "errors": 0}
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._error_lock = threading.Lock()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self) -> None:
        # A failing kernel/memcpy must not kill the worker: the queue keeps
        # draining (so later synchronize()/close() never deadlock on an event
        # nobody will set) and the first error is parked for the next
        # synchronize() to re-raise on the calling thread.
        while not self._stop.is_set():
            try:
                item = self._q.get(timeout=0.2)
            except queue.Empty:
                continue
            try:
                if item.fn is not None:
                    item.fn()
            except BaseException as e:           # noqa: BLE001 — parked
                self._stats["errors"] += 1
                with self._error_lock:
                    if self._error is None:      # first error wins
                        self._error = e
            finally:
                self._stats["executed"] += 1
                if item.event is not None:
                    item.event.set()

    def _enqueue(self, kind: str, fn: Optional[Callable[[], Any]] = None,
                 event: Optional[threading.Event] = None) -> None:
        self._stats["enqueued"] += 1
        self._stats["max_depth"] = max(self._stats["max_depth"],
                                       self._q.qsize() + 1)
        self._q.put(_QueueItem(kind, fn, event))

    # -- paper API ----------------------------------------------------------
    def malloc_async(self, nbytes: int) -> VirtualPtr:
        ptr = self.allocator.malloc(nbytes)
        self._enqueue("malloc", lambda: self.allocator.materialize(ptr))
        return ptr

    def free_async(self, ptr: VirtualPtr) -> None:
        self._enqueue("free", lambda: self.allocator.free(ptr))

    def memcpy_async(self, dst: VirtualPtr, src: np.ndarray) -> None:
        # Snapshot the source bytes AT ENQUEUE TIME.  ``ascontiguousarray``
        # is a no-op for contiguous inputs, returning the caller's own array
        # — copying it later on the worker thread would let a caller that
        # mutates ``src`` after enqueue corrupt the transfer in flight.
        snap = np.ascontiguousarray(src)
        if snap.base is not None or snap is src:
            snap = snap.copy()
        flat = snap.view(np.uint8).reshape(-1)

        def copy():
            self.allocator.resolve(dst)[:flat.size] = flat
        self._enqueue("memcpy", copy)

    def launch(self, fn: Callable[[], Any]) -> None:
        self._enqueue("kernel", fn)

    def synchronize(self) -> None:
        """Barrier.  If any queued operation failed since the last barrier,
        the first stored error is re-raised here, on the caller's thread —
        the CUDA-style deferred error report."""
        ev = threading.Event()
        self._enqueue("sync", None, ev)
        ev.wait()
        with self._error_lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def pending_error(self) -> Optional[BaseException]:
        """The parked error the next synchronize() would raise (or None)."""
        with self._error_lock:
            return self._error

    def stats(self) -> Dict[str, int]:
        return dict(self._stats)

    def close(self) -> None:
        """Drain and stop the worker.  Never hangs and never raises: a
        parked error stays retrievable via ``pending_error()`` but must not
        turn shutdown into a deadlock or a throw."""
        ev = threading.Event()
        self._enqueue("sync", None, ev)
        ev.wait(timeout=5.0)
        self._stop.set()
        self._worker.join(timeout=5.0)
