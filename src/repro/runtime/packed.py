"""Packed memcopies (paper Sec. IV-C, the VEO-udma mechanism).

"We gather multiple adjacent memcopies and group them together … many small
tensors can be packed into a big data segment to speed up transfers."

JAX analogue: many small host arrays (e.g. the dozens of norm gains /
biases of a model, or a serving request batch) are flattened into ONE
contiguous staging buffer, moved with a single ``jax.device_put`` (one DMA
instead of N), and re-sliced on device with zero-copy ``lax.dynamic_slice``
views.  Below a size threshold the latency-optimized direct path is used —
exactly the paper's policy split.

Mesh serving: every ``device=`` parameter below is a ``jax.device_put``
target, so it accepts a ``Sharding`` as well as a single device.  The
mesh-mode server passes ``NamedSharding(mesh, P())`` (see
:func:`replicated`): the packed buffer broadcasts to every shard as one
host→device DMA, and the per-spec layout (batch split across ``data``,
heads across ``model``) happens device-to-device when the sharded
executable consumes the inputs — host staging stays a single gather
exactly as on one device."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LATENCY_THRESHOLD_BYTES = 1 << 14     # small transfers go direct

# Process-wide transfer accounting: how many DMAs (packed vs direct) the
# policy issued and how many host bytes crossed.  The serving scheduler and
# the staged-exactly-once deployment test read these.
TRANSFER_STATS = {"packed_dmas": 0, "direct_dmas": 0, "bytes": 0}


def reset_transfer_stats() -> Dict[str, int]:
    prev = dict(TRANSFER_STATS)
    TRANSFER_STATS.update(packed_dmas=0, direct_dmas=0, bytes=0)
    return prev


def replicated(mesh) -> Any:
    """The mesh-mode staging target: one packed buffer, broadcast to every
    shard (fully-replicated NamedSharding) — the single-DMA policy's
    closest analogue when 'the device' is a mesh."""
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


@dataclasses.dataclass
class PackedTransfer:
    buffer: jax.Array                  # packed uint8 staging buffer
    layout: List[Tuple[Tuple[int, ...], str, int]]  # (shape, dtype, offset)


def pack_transfer(arrays: Sequence[np.ndarray],
                  device=None) -> PackedTransfer:
    """Pack many host arrays into one device transfer."""
    layout: List[Tuple[Tuple[int, ...], str, int]] = []
    total = 0
    aligned: List[np.ndarray] = []
    for a in arrays:
        a = np.ascontiguousarray(a)
        off = (total + 127) & ~127     # 128-byte alignment (lane-friendly)
        layout.append((tuple(a.shape), str(a.dtype), off))
        total = off + a.nbytes
        aligned.append(a)
    buf = np.zeros(total, np.uint8)
    for a, (_, _, off) in zip(aligned, layout):
        buf[off:off + a.nbytes] = a.view(np.uint8).reshape(-1)
    dev_buf = jax.device_put(buf, device)
    return PackedTransfer(dev_buf, layout)


def unpack_on_device(pt: PackedTransfer) -> List[jax.Array]:
    """Zero-copy-ish on-device reslicing of the packed buffer.  The reslice
    of a whole layout is ONE jitted dispatch, cached per layout — a serving
    bucket pays the trace once and every subsequent step's unpack is a
    single executable call instead of 2·N eager ops."""
    return list(_unpack_jit(tuple(pt.layout))(pt.buffer))


@functools.lru_cache(maxsize=512)
def _unpack_jit(layout: Tuple[Tuple[Tuple[int, ...], str, int], ...]):
    def f(buf):
        out = []
        for shape, dtype, off in layout:
            item = np.dtype(dtype).itemsize
            n = int(np.prod(shape)) * item
            if n == 0:
                out.append(jnp.zeros(shape, dtype))
                continue
            chunk = jax.lax.dynamic_slice(buf, (off,), (n,))
            # bitcast uint8 → dtype folds the trailing itemsize dim
            arr = jax.lax.bitcast_convert_type(
                chunk.reshape(-1, item), jnp.dtype(dtype))
            out.append(arr.reshape(shape))
        return out
    return jax.jit(f)


def transfer(arrays: Sequence[np.ndarray], device=None) -> List[jax.Array]:
    """Policy split: small singletons direct (latency-optimized); batches of
    small tensors packed (bandwidth-optimized)."""
    total = sum(a.nbytes for a in arrays)
    TRANSFER_STATS["bytes"] += total
    if len(arrays) == 1 or total < LATENCY_THRESHOLD_BYTES:
        TRANSFER_STATS["direct_dmas"] += len(arrays)
        return [jax.device_put(a, device) for a in arrays]
    TRANSFER_STATS["packed_dmas"] += 1
    return unpack_on_device(pack_transfer(arrays, device))


def stage_inputs(arrays: Sequence[np.ndarray], device=None) -> List[jax.Array]:
    """Stage a heterogeneous input set host→device as ONE packed DMA.

    The serving decode step feeds one forward several arrays of different
    shapes and dtypes — token rows (f32), per-request cache lengths (int32)
    and the gathered KV caches (f32).  They are consumed together by a
    single dispatch, so like :func:`stage_batch` they are a bandwidth
    object regardless of size: always one packed segment, resliced on
    device, never N direct puts."""
    if not arrays:
        raise ValueError("stage_inputs needs at least one array")
    arrays = [np.ascontiguousarray(a) for a in arrays]
    TRANSFER_STATS["bytes"] += sum(a.nbytes for a in arrays)
    TRANSFER_STATS["packed_dmas"] += 1
    return unpack_on_device(pack_transfer(arrays, device))


def stage_batch(rows: Sequence[np.ndarray], device=None) -> jax.Array:
    """Stage a serving batch host→device as ONE DMA and stack on device.

    Every row must share shape and dtype (the scheduler has already padded
    them to a common bucket).  Unlike :func:`transfer`, a multi-row batch is
    ALWAYS gathered into one packed segment — the batch is about to be
    consumed as a single tensor, so it is a bandwidth object even when it
    is small (the paper's VEO-udma policy applied to request batches) —
    and the stack is a device-side reslice of the packed buffer."""
    if not rows:
        raise ValueError("stage_batch needs at least one row")
    rows = [np.ascontiguousarray(r) for r in rows]
    shapes = {r.shape for r in rows}
    if len(shapes) > 1 or len({str(r.dtype) for r in rows}) > 1:
        raise ValueError(
            f"stage_batch needs uniform rows, got shapes "
            f"{sorted(shapes)} — pad to a common bucket first")
    TRANSFER_STATS["bytes"] += sum(r.nbytes for r in rows)
    if len(rows) == 1:
        TRANSFER_STATS["direct_dmas"] += 1
        return jnp.stack([jax.device_put(rows[0], device)])
    TRANSFER_STATS["packed_dmas"] += 1
    return jnp.stack(unpack_on_device(pack_transfer(rows, device)))
