"""Packed memcopies (paper Sec. IV-C, the VEO-udma mechanism).

"We gather multiple adjacent memcopies and group them together … many small
tensors can be packed into a big data segment to speed up transfers."

JAX analogue: many small host arrays (e.g. the dozens of norm gains /
biases of a model, or a serving request batch) are flattened into ONE
contiguous staging buffer, moved with a single ``jax.device_put`` (one DMA
instead of N), and re-sliced on device with zero-copy ``lax.dynamic_slice``
views.  Below a size threshold the latency-optimized direct path is used —
exactly the paper's policy split."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LATENCY_THRESHOLD_BYTES = 1 << 14     # small transfers go direct


@dataclasses.dataclass
class PackedTransfer:
    buffer: jax.Array                  # packed uint8 staging buffer
    layout: List[Tuple[Tuple[int, ...], str, int]]  # (shape, dtype, offset)


def pack_transfer(arrays: Sequence[np.ndarray],
                  device=None) -> PackedTransfer:
    """Pack many host arrays into one device transfer."""
    layout: List[Tuple[Tuple[int, ...], str, int]] = []
    total = 0
    aligned: List[np.ndarray] = []
    for a in arrays:
        a = np.ascontiguousarray(a)
        off = (total + 127) & ~127     # 128-byte alignment (lane-friendly)
        layout.append((tuple(a.shape), str(a.dtype), off))
        total = off + a.nbytes
        aligned.append(a)
    buf = np.zeros(total, np.uint8)
    for a, (_, _, off) in zip(aligned, layout):
        buf[off:off + a.nbytes] = a.view(np.uint8).reshape(-1)
    dev_buf = jax.device_put(buf, device)
    return PackedTransfer(dev_buf, layout)


def unpack_on_device(pt: PackedTransfer) -> List[jax.Array]:
    """Zero-copy-ish on-device reslicing of the packed buffer."""
    out = []
    for shape, dtype, off in pt.layout:
        item = np.dtype(dtype).itemsize
        n = int(np.prod(shape)) * item
        if n == 0:
            out.append(jnp.zeros(shape, dtype))
            continue
        chunk = jax.lax.dynamic_slice(pt.buffer, (off,), (n,))
        # bitcast uint8 → dtype folds the trailing itemsize dim
        arr = jax.lax.bitcast_convert_type(
            chunk.reshape(-1, item), jnp.dtype(dtype))
        out.append(arr.reshape(shape))
    return out


def transfer(arrays: Sequence[np.ndarray], device=None) -> List[jax.Array]:
    """Policy split: small singletons direct (latency-optimized); batches of
    small tensors packed (bandwidth-optimized)."""
    total = sum(a.nbytes for a in arrays)
    if len(arrays) == 1 or total < LATENCY_THRESHOLD_BYTES:
        return [jax.device_put(a, device) for a in arrays]
    return unpack_on_device(pack_transfer(arrays, device))
