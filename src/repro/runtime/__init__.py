from .async_queue import AsyncQueue, VirtualAllocator, VirtualPtr
from .packed import pack_transfer, unpack_on_device, PackedTransfer
from .straggler import StragglerMonitor
from .failures import FailureSimulator, run_with_restart

__all__ = ["AsyncQueue", "VirtualAllocator", "VirtualPtr", "pack_transfer",
           "unpack_on_device", "PackedTransfer", "StragglerMonitor",
           "FailureSimulator", "run_with_restart"]
