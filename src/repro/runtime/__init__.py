from .async_queue import (AsyncQueue, UseAfterFreeError, VirtualAllocator,
                          VirtualPtr)
from .packed import (PackedTransfer, pack_transfer, stage_batch, transfer,
                     unpack_on_device)
from .straggler import StragglerMonitor
from .failures import (FailureSimulator, ReplicaFailure, RestartReport,
                       run_with_restart)

__all__ = ["AsyncQueue", "UseAfterFreeError", "VirtualAllocator",
           "VirtualPtr", "pack_transfer", "unpack_on_device", "transfer",
           "stage_batch", "PackedTransfer", "StragglerMonitor",
           "FailureSimulator", "ReplicaFailure", "RestartReport",
           "run_with_restart"]
