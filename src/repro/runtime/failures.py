"""Node-failure handling: checkpoint/restart with elastic re-shard.

``run_with_restart`` drives a training function through injected failures:
on failure the state is restored from the last checkpoint (possibly onto a
different mesh size — the checkpoint layer is mesh-agnostic) and the data
loader seeks to the restored step (deterministic stateless pipeline).
Unit-tested in tests/test_fault_tolerance.py; on a real fleet the failure
signal comes from the coordination service instead of the simulator.

Serving roles, post-mesh (ROADMAP "Sharded-mesh serving, then a serving
fleet").  Sharded-mesh serving landed: a replica is now a whole
mesh-wide ``launch/serve.SolServer`` (its shards live or die together —
a lost device kills the ``shard_map`` step, so shard failure IS replica
failure), which keeps the failure domain here per-replica, unchanged.
``run_with_restart`` is the respawn path: when the straggler monitor
(``runtime/straggler.py``) or a health check evicts a replica, the
fleet front-end restarts it through the same checkpoint-restore
machinery — the "state" being the model parameters plus the warmed
autotune cache, whose entries carry the mesh tag in their backend key
(``Backend.cache_name``), so a respawned replica re-enters
strict-provenance serving on the SAME mesh shape without re-measuring
its buckets (a different mesh shape means cold per-shard keys: re-warm
before serving); in-flight requests on the dead replica are re-queued
by the router, not recovered here.  The elastic re-shard path stays
training-only for now.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Dict, List, Optional, Tuple


class FailureSimulator:
    """Deterministic injected failures for testing restart logic."""

    def __init__(self, fail_at_steps: Optional[List[int]] = None,
                 p_fail: float = 0.0, seed: int = 0):
        self.fail_at = set(fail_at_steps or [])
        self.p = p_fail
        self.rng = random.Random(seed)
        self.failures: List[int] = []

    def check(self, step: int) -> None:
        if step in self.fail_at or (self.p and self.rng.random() < self.p):
            self.fail_at.discard(step)
            self.failures.append(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class RestartReport:
    total_steps: int
    restarts: int
    recovered_steps: List[int]


def run_with_restart(step_fn: Callable[[int, Any], Any],
                     init_state: Any,
                     n_steps: int,
                     ckpt,                       # CheckpointManager
                     failure_sim: Optional[FailureSimulator] = None,
                     max_restarts: int = 10) -> Tuple[Any, RestartReport]:
    """Run ``state = step_fn(step, state)`` for n_steps with checkpointing
    and restart-on-failure."""
    state = init_state
    step = 0
    restarts = 0
    recovered: List[int] = []
    while step < n_steps:
        try:
            if failure_sim is not None:
                failure_sim.check(step)
            state = step_fn(step, state)
            step += 1
            ckpt.maybe_save(step, state)
        except RuntimeError as e:
            if "injected node failure" not in str(e) or \
                    restarts >= max_restarts:
                raise
            restarts += 1
            ckpt.wait()
            restored_step, restored = ckpt.restore_latest(state)
            if restored is None:
                state, step = init_state, 0
            else:
                state, step = restored, restored_step
            recovered.append(step)
    ckpt.wait()
    return state, RestartReport(step, restarts, recovered)
