"""Node-failure handling: typed replica failures + checkpoint/restart.

``run_with_restart`` drives a step function through failures: on a
*restartable* failure the state is restored from the last checkpoint
(possibly onto a different mesh size — the checkpoint layer is
mesh-agnostic) and the data loader seeks to the restored step
(deterministic stateless pipeline).  What counts as restartable is a
property of the exception TYPE, not its message: anything raising
:class:`ReplicaFailure` (or passing an injected ``restartable=``
predicate) takes the restore path; everything else propagates.
Unit-tested in tests/test_fault_tolerance.py; on a real fleet the failure
signal comes from the coordination service instead of the simulator.

Serving roles (ROADMAP "Sharded-mesh serving, then a serving fleet" —
both landed).  A replica is a whole mesh-wide ``launch/serve.SolServer``
(its shards live or die together — a lost device kills the ``shard_map``
step, so shard failure IS replica failure), which keeps the failure
domain here per-replica.  ``launch/fleet.SolFleet`` is the live consumer:
its watcher tick treats any restartable exception out of a replica step
as replica death, re-queues the dead replica's in-flight requests at the
router (with their original ``SamplingParams`` seeds, so completed output
is token-identical to an undisturbed run), and respawns the replica
through ``run_with_restart`` — the "state" being the model parameters
(checkpoint-restored) plus the warmed autotune cache, whose entries carry
the mesh tag in their backend key (``Backend.cache_name``), so a
respawned replica re-enters strict-provenance serving on the SAME mesh
shape without re-measuring its buckets (a different mesh shape means cold
per-shard keys: re-warm before serving).  The elastic re-shard path stays
training-only for now.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, List, Optional, Tuple


class ReplicaFailure(RuntimeError):
    """A replica (node) died: injected by :class:`FailureSimulator`, or
    raised by real failure paths — device loss, OOM, a ``ShardingError``
    escaping a mesh step.  Restart logic keys on this TYPE (historically it
    string-matched the simulator's message, so every real failure escaped
    the checkpoint-restore path)."""


@dataclasses.dataclass
class RestartReport:
    total_steps: int
    restarts: int
    recovered_steps: List[int]


class FailureSimulator:
    """Deterministic injected failures for testing restart logic.

    A given step fires AT MOST ONCE over the simulator's lifetime,
    whichever path triggers it: a scheduled step is consumed when it
    fires, and a probabilistic (``p_fail``) firing consumes the step too.
    Restart loops replay steps, so without that rule a step could fail on
    every replay (``p_fail``) or fire once scheduled and again
    probabilistically — double-counting ``RestartReport.restarts``."""

    def __init__(self, fail_at_steps: Optional[List[int]] = None,
                 p_fail: float = 0.0, seed: int = 0):
        self.fail_at = set(fail_at_steps or [])
        self.p = p_fail
        self.rng = random.Random(seed)
        self.failures: List[int] = []
        self._fired: set = set()

    def check(self, step: int) -> None:
        if step in self._fired:
            return
        if step in self.fail_at or (self.p and self.rng.random() < self.p):
            self.fail_at.discard(step)
            self._fired.add(step)
            self.failures.append(step)
            raise ReplicaFailure(f"injected node failure at step {step}")


def _default_restartable(e: BaseException) -> bool:
    return isinstance(e, ReplicaFailure)


def run_with_restart(step_fn: Callable[[int, Any], Any],
                     init_state: Any,
                     n_steps: int,
                     ckpt,                       # CheckpointManager
                     failure_sim: Optional[FailureSimulator] = None,
                     max_restarts: int = 10,
                     restartable: Optional[
                         Callable[[BaseException], bool]] = None
                     ) -> Tuple[Any, RestartReport]:
    """Run ``state = step_fn(step, state)`` for n_steps with checkpointing
    and restart-on-failure.  ``restartable`` decides which exceptions take
    the restore path (default: ``isinstance(e, ReplicaFailure)``); others
    propagate unchanged."""
    state = init_state
    step = 0
    restarts = 0
    recovered: List[int] = []
    is_restartable = restartable or _default_restartable
    while step < n_steps:
        try:
            if failure_sim is not None:
                failure_sim.check(step)
            state = step_fn(step, state)
            step += 1
            ckpt.maybe_save(step, state)
        except Exception as e:
            if not is_restartable(e) or restarts >= max_restarts:
                raise
            restarts += 1
            ckpt.wait()
            restored_step, restored = ckpt.restore_latest(state)
            if restored is None:
                state, step = init_state, 0
            else:
                state, step = restored, restored_step
            recovered.append(step)
    ckpt.wait()
    return state, RestartReport(step, restarts, recovered)
