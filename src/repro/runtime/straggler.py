"""Straggler mitigation for 1000+-node fleets.

SPMD steps are synchronous, so one slow host stalls the fleet.  The
monitor tracks a rolling per-step latency distribution and flags hosts
whose EWMA exceeds ``threshold ×`` the fleet median.  Mitigations (hooked
by the trainer):

  * ``rebalance`` — shrink the flagged host's microbatch share (the data
    loader consumes the new assignment at the next boundary);
  * ``evict``     — treat the host as failed → elastic restart path
    (checkpoint restore onto the reduced mesh).

Membership is DYNAMIC: ``record_step`` auto-registers host ids it has not
seen before (a respawned or autoscaled replica arrives with a fresh id),
and ``retire`` drops an evicted host so its stale EWMA stops skewing the
fleet baseline.  Single-process here: the monitor is driven with recorded
per-step times in tests; on a real fleet the times come from each host's
step clock via the coordination service.

Serving roles (ROADMAP "Sharded-mesh serving, then a serving fleet" —
both landed).  Within one mesh-wide ``launch/serve.SolServer`` the
``shard_map`` step is synchronous — the slowest SHARD gates every
scheduler tick, exactly the SPMD straggler shape above; ``rebalance`` has
no in-server analogue (TP/DP shard sizes are fixed by the rule engine's
divisibility guards), so a persistently slow shard escalates straight to
``evict`` = recompiling the bucket models on a smaller debug mesh.
Across the fleet of such servers, ``launch/fleet.SolFleet`` drives this
monitor as its per-replica health watcher: every watcher tick feeds each
replica's step clock into ``record_step``; ``rebalance`` maps to draining
the flagged replica's share of the request router, and ``evict`` maps to
drain → evict → respawn through ``runtime/failures.run_with_restart``
(the evicted id is ``retire``d; the respawn arrives under a fresh id and
auto-registers).  Nothing here assumes training: the signal is "one
participant is slower than the fleet", whichever loop produces it.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    steps: int = 0


class StragglerMonitor:
    def __init__(self, n_hosts: int = 0, *, alpha: float = 0.2,
                 threshold: float = 1.5, evict_threshold: float = 3.0,
                 warmup_steps: int = 5):
        self.hosts: Dict[int, HostStats] = {
            i: HostStats() for i in range(n_hosts)}
        self.alpha = alpha
        self.threshold = threshold
        self.evict_threshold = evict_threshold
        self.warmup = warmup_steps
        self.history: List[Dict[int, float]] = []

    def record_step(self, times: Dict[int, float]) -> None:
        """Fold one step's per-host clocks into the EWMAs.  Unknown host
        ids are registered on first sight (dynamic membership: respawned /
        autoscaled replicas arrive with ids the monitor was never
        constructed with)."""
        self.history.append(dict(times))
        for h, t in times.items():
            st = self.hosts.setdefault(h, HostStats())
            st.ewma = t if st.steps == 0 else \
                (1 - self.alpha) * st.ewma + self.alpha * t
            st.steps += 1

    def retire(self, host: int) -> None:
        """Forget an evicted/retired host.  Its EWMA must stop feeding the
        fleet baseline, and a later re-registration under the same id
        starts from fresh stats (no-op for unknown ids)."""
        self.hosts.pop(host, None)

    def baseline(self) -> float:
        """Robust fleet baseline: lower quartile of host EWMAs (the median
        itself is dragged up when several hosts straggle).  Public: the
        fleet watcher clips raw step clocks against this before recording,
        so one compile/GC spike cannot masquerade as sustained slowness."""
        vals = sorted(s.ewma for s in self.hosts.values() if s.steps > 0)
        if not vals:
            return 0.0
        if len(vals) < 4:
            return vals[0]
        return statistics.quantiles(vals, n=4)[0]

    def flagged(self) -> Dict[int, str]:
        """host -> 'rebalance' | 'evict'."""
        med = self.baseline()
        out: Dict[int, str] = {}
        if med <= 0:
            return out
        for h, st in self.hosts.items():
            if st.steps < self.warmup:
                continue
            r = st.ewma / med
            if r >= self.evict_threshold:
                out[h] = "evict"
            elif r >= self.threshold:
                out[h] = "rebalance"
        return out

    def microbatch_shares(self, base: int = 1) -> Dict[int, float]:
        """Work shares inversely proportional to EWMA latency (bounded).
        A host with no samples — or a zero EWMA from a zero-duration
        recorded step — keeps the full share instead of dividing by it."""
        med = self.baseline()
        shares = {}
        for h, st in self.hosts.items():
            if st.steps == 0 or med == 0 or st.ewma <= 0:
                shares[h] = 1.0
            else:
                shares[h] = max(0.5, min(1.0, med / st.ewma))
        return shares
