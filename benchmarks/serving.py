"""Serving benchmark table: continuous batching THROUGH the SOL pipeline.

Earlier revisions of this table timed ``models/backbone.py`` decode steps —
a path that bypassed elections, pinned autotune configs and packed staging
entirely.  Now the table drives ``repro.launch.serve.SolServer``: the
workload is admitted into the KV-slot arena, padded to the autotune pow2
buckets, staged with one packed DMA per step and served by bucket models
whose every LINEAR/MATMUL/ATTENTION election is measured (the run warms
the autotune cache first and serves with ``strict_provenance``).

Rows (``name,us_per_call,derived``):

  serve_<backend>_step          mean wall time per scheduler step
  serve_<backend>_latency_p50   request latency percentile (us)
  serve_<backend>_latency_p99
  serve_<backend>_ttft_p50      time-to-first-token percentile (us)
  serve_<backend>_decode_tok    us/token with the incremental decode
                                program (ServeConfig.decode=True); derived
                                carries tokens/s and the speedup over the
                                re-forward baseline on the same weights
                                and workload
  serve_<backend>_reforward_tok us/token with the full re-forward baseline
                                (decode=False), measured back-to-back
  decode_step_cache<T>          one decode-program forward at resident
                                cache length T — the T=128 vs T=1024 pair
                                shows per-token decode cost is (near-)flat
                                in how much context is already resident,
                                where the re-forward rows below grow
  reforward_step_T<T>           one full-forward step over a T-token
                                context (what every decode step cost
                                before the decode program existed)
  decode_<arch>_smoke           per-architecture backbone decode step
                                (qwen2 / rwkv6 / recurrentgemma) — kept so
                                the sequence-model scan kernels retain a
                                serving-side perf trajectory
  serve_<backend>_mesh1x1_tok   decode tokens/s on ONE device — the
                                baseline half of the mesh-scaling pair
                                (same weights and workload as the row
                                below)
  serve_<backend>_mesh<D>x<M>_tok  decode tokens/s across a (data, model)
                                debug mesh via shard_map; derived carries
                                the speedup vs the 1x1 row.  Skipped when
                                the process sees fewer than D·M devices.
  serve_<backend>_fleet<R>_*    open-loop replay of >=1000 requests
                                against an R-replica SolFleet with ONE
                                injected replica kill (``fleet`` mode):
                                us/token, latency p50/p99, TTFT p50 and
                                the kill→respawn recovery time; the run
                                fails loudly on any dropped request or
                                any token diverging from an undisturbed
                                same-seed replay.

The derived column carries tokens/s, DMA count and the bucket histogram —
``benchmarks/run.py --json`` additionally snapshots these rows into
``BENCH_serve.json`` so the serving perf trajectory accumulates in CI.

Mesh runs: ``python -m benchmarks.serving --mesh 2,2 --json
BENCH_serve.json`` runs the serving rows ON the mesh plus the scaling
pair and merges them into an existing BENCH file (CI's mesh job, under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
from __future__ import annotations

import sys
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp


def serve_rows(backend: str = "xla", *, requests: int = 6,
               gen: int = 6, mesh: Tuple[int, int] = (1, 1)
               ) -> List[Tuple[str, float, str]]:
    from repro.core import autotune as AT
    from repro.launch.serve import ServeConfig, SolServer, _smoke_workload

    cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64,
                      max_seq=32, max_batch=4, slots=4, backend=backend,
                      mesh=tuple(mesh))
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())      # private cache: measure, don't leak
    try:
        server = SolServer(cfg, strict_provenance=True)
        for prompt, g in _smoke_workload(cfg, requests, gen):
            server.submit(prompt, g)
        server.warm_autotune(warmup=1, iters=3)
        s = server.run()
        server.close()
    finally:
        AT.set_cache(prev)

    wall_us = (s["tokens"] / s["tokens_per_s"] * 1e6
               if s["tokens_per_s"] else 0.0)
    step_us = wall_us / max(s["steps"], 1)
    buckets = "/".join(f"{k}:{v}" for k, v in sorted(s["buckets"].items()))
    # single-device rows keep their historical names so the bench_diff
    # trajectory is unbroken; mesh runs get their own row series
    tag = "" if tuple(mesh) == (1, 1) else f"_mesh{mesh[0]}x{mesh[1]}"
    return [
        (f"serve_{backend}{tag}_step", step_us,
         f"{s['tokens_per_s']:.1f}tok/s;dmas={s['dmas']};"
         f"buckets={buckets}"),
        (f"serve_{backend}{tag}_latency_p50", s["latency_ms"]["p50"] * 1e3,
         f"{s['requests']}req"),
        (f"serve_{backend}{tag}_latency_p99",
         s["latency_ms"]["p99"] * 1e3, ""),
        (f"serve_{backend}{tag}_ttft_p50", s["ttft_ms"]["p50"] * 1e3,
         f"prefills={s['prefills']};decodes={s['decodes']}"),
    ]


def mesh_scaling_rows(backend: str = "xla", mesh: Tuple[int, int] = (2, 2),
                      *, requests: int = 8, gen: int = 24
                      ) -> List[Tuple[str, float, str]]:
    """Decode throughput, single device vs a (data, model) debug mesh, on
    the SAME weights and workload — the tokens/s-scaling rows the PR-7
    regression gate tracks.  Skips (returns no rows) when the process does
    not see ``data·model`` devices; CI's mesh job forces host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count``.  Both servers
    share one private autotune cache: the mesh run's per-shard keys carry
    the mesh tag (``Backend.cache_name``), so warming one never satisfies
    (or corrupts) the other's strict-provenance audit."""
    import dataclasses

    import numpy as np

    from repro.core import autotune as AT
    from repro.launch.serve import ServeConfig, SolServer, build_lm

    need = int(mesh[0]) * int(mesh[1])
    if need <= 1 or len(jax.devices()) < need:
        print(f"[serving] mesh_scaling_rows: {need} devices needed, "
              f"{len(jax.devices())} visible — skipping (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count on CPU)",
              file=sys.stderr)
        return []

    base = ServeConfig(d_model=128, n_heads=4, n_layers=2, vocab=128,
                       max_seq=128, max_batch=8, slots=8, backend=backend)
    model = build_lm(base)
    rng = np.random.default_rng(7)
    workload = [(rng.integers(0, base.vocab, int(rng.integers(4, 8)),
                              dtype=np.int32), gen)
                for _ in range(requests)]
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())
    tps = {}
    try:
        for mc in ((1, 1), tuple(mesh)):
            cfg = dataclasses.replace(base, mesh=mc)
            server = SolServer(cfg, model, strict_provenance=True)
            for p, g in workload:          # compile pass: builds buckets
                server.submit(p, g)
            server.warm_autotune(warmup=1, iters=3)
            server.run()
            toks0 = server.stats["tokens"]
            t0 = time.perf_counter()
            for p, g in workload:          # timed pass: warm buckets only
                server.submit(p, g)
            server.run()
            dt = time.perf_counter() - t0
            tps[mc] = (server.stats["tokens"] - toks0) / dt
            server.close()
    finally:
        AT.set_cache(prev)
    single = tps[(1, 1)]
    sharded = tps[tuple(mesh)]
    speedup = sharded / single if single else 0.0
    return [
        (f"serve_{backend}_mesh1x1_tok", 1e6 / single if single else 0.0,
         f"{single:.1f}tok/s;devices=1"),
        (f"serve_{backend}_mesh{mesh[0]}x{mesh[1]}_tok",
         1e6 / sharded if sharded else 0.0,
         f"{sharded:.1f}tok/s;x{speedup:.2f}_vs_single;devices={need}"),
    ]


def fleet_rows(backend: str = "xla", *, replicas: int = 3,
               requests: int = 1000, gen: int = 4, rate: int = 3,
               kill_at_tick: int = 150, verify: bool = True
               ) -> List[Tuple[str, float, str]]:
    """Open-loop traffic replay against a ``launch/fleet.SolFleet`` with
    ONE injected replica kill mid-replay: ``rate`` requests arrive per
    watcher tick regardless of completions (open loop — queueing delay is
    visible in the latency rows, not hidden by flow control).  The
    default ``rate`` sits just under fleet capacity (~R·max_batch/(gen+1)
    requests/tick) so the latency rows measure service + moderate
    queueing, not an unbounded backlog.  Every
    request must complete with zero drops, and with ``verify`` the token
    output is checked identical to an undisturbed same-seed run on the
    same weights (the re-queue determinism claim, measured at scale).

    Rows (merged into BENCH_serve.json under the bench_diff gate):

      serve_<backend>_fleet<R>_tok          us/token across the fleet
      serve_<backend>_fleet<R>_latency_p50  request latency (us)
      serve_<backend>_fleet<R>_latency_p99
      serve_<backend>_fleet<R>_ttft_p50     time-to-first-token (us)
      serve_<backend>_fleet<R>_recovery     injected kill → respawn (us)
    """
    import numpy as np

    from repro.core import autotune as AT
    from repro.launch.fleet import FleetConfig, SolFleet
    from repro.launch.serve import (SamplingParams, ServeConfig, build_lm)

    cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64,
                      max_seq=32, max_batch=8, slots=16, backend=backend)
    model = build_lm(cfg)
    rng = np.random.default_rng(11)
    workload = [(rng.integers(0, cfg.vocab, int(rng.integers(4, 12)),
                              dtype=np.int32), gen,
                 SamplingParams(temperature=0.8, seed=10_000 + i))
                for i in range(requests)]

    def replay(n_replicas: int, kill: bool):
        # fixed-size fleet (min == max): the recovery row must measure the
        # kill → respawn path, not autoscaler drift, and the 1-replica
        # verification baseline must stay truly single-replica
        fleet = SolFleet(cfg, FleetConfig(
            n_replicas=n_replicas, min_replicas=n_replicas,
            max_replicas=n_replicas),
            model=model)
        reqs, i, killed = [], 0, None
        while i < len(workload) or any(fr.generated is None
                                       for fr in reqs):
            for _ in range(rate):
                if i < len(workload):
                    p, g, sp = workload[i]
                    reqs.append(fleet.submit(p, g, sampling=sp))
                    i += 1
            if kill and killed is None and fleet.stats["ticks"] >= \
                    kill_at_tick:
                killed = fleet.kill()
            fleet.tick()
        s = fleet.summary()
        fleet.close()
        return reqs, s, killed

    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())   # private cache: measure, don't leak
    try:
        reqs, s, killed = replay(replicas, kill=True)
        dropped = [fr.fid for fr in reqs if fr.generated is None]
        if dropped:
            raise RuntimeError(f"fleet replay dropped requests {dropped} "
                               f"after the injected kill")
        ident = ""
        if verify:
            base_reqs, _, _ = replay(1, kill=False)
            diverged = [fr.fid for fr, b in zip(reqs, base_reqs)
                        if fr.generated != b.generated]
            if diverged:
                raise RuntimeError(
                    f"fleet replay token output diverged from the "
                    f"undisturbed same-seed run for {diverged}")
            ident = ";identical=yes"
    finally:
        AT.set_cache(prev)

    tag = f"fleet{replicas}"
    tok_us = (1e6 / s["tokens_per_s"]) if s["tokens_per_s"] else 0.0
    recovery_us = s["recovery_s"]["max"] * 1e6
    return [
        (f"serve_{backend}_{tag}_tok", tok_us,
         f"{s['tokens_per_s']:.1f}tok/s;requests={s['requests']};"
         f"requeued={s['requeued']}{ident}"),
        (f"serve_{backend}_{tag}_latency_p50",
         s["latency_ms"]["p50"] * 1e3, f"open_loop_rate={rate}/tick"),
        (f"serve_{backend}_{tag}_latency_p99",
         s["latency_ms"]["p99"] * 1e3, ""),
        (f"serve_{backend}_{tag}_ttft_p50", s["ttft_ms"]["p50"] * 1e3,
         f"replicas={replicas}"),
        (f"serve_{backend}_{tag}_recovery", recovery_us,
         f"killed_replica={killed};kill_tick={kill_at_tick};"
         f"respawns={s['respawns']}"),
    ]


def decode_vs_reforward(backend: str = "xla", *, requests: int = 4,
                        gen: int = 120) -> List[Tuple[str, float, str]]:
    """Decode-heavy workload (short prompts, long generations) served twice
    on the SAME weights: once through the incremental decode program, once
    through the full re-forward baseline.  The first pass of each server
    compiles the bucket models; the timed pass replays the workload on the
    warm server, so the ratio is pure serving cost."""
    import dataclasses

    import numpy as np

    from repro.core import autotune as AT
    from repro.launch.serve import ServeConfig, SolServer, build_lm

    base = ServeConfig(d_model=128, n_heads=4, n_layers=2, vocab=128,
                       max_seq=256, max_batch=4, slots=4, backend=backend)
    model = build_lm(base)
    rng = np.random.default_rng(3)
    workload = [(rng.integers(0, base.vocab, int(rng.integers(4, 8)),
                              dtype=np.int32), gen)
                for _ in range(requests)]
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())
    tps = {}
    try:
        for decode in (False, True):
            cfg = dataclasses.replace(base, decode=decode)
            server = SolServer(cfg, model)
            for p, g in workload:          # compile pass: builds buckets
                server.submit(p, g)
            server.run()
            toks0 = server.stats["tokens"]
            t0 = time.perf_counter()
            for p, g in workload:          # timed pass: warm buckets only
                server.submit(p, g)
            server.run()
            dt = time.perf_counter() - t0
            tps[decode] = (server.stats["tokens"] - toks0) / dt
            server.close()
    finally:
        AT.set_cache(prev)
    ratio = tps[True] / tps[False] if tps[False] else 0.0
    return [
        (f"serve_{backend}_decode_tok", 1e6 / tps[True],
         f"{tps[True]:.1f}tok/s;x{ratio:.2f}_vs_reforward"),
        (f"serve_{backend}_reforward_tok", 1e6 / tps[False],
         f"{tps[False]:.1f}tok/s;baseline"),
    ]


def decode_flatness(backend: str = "xla", lengths=(128, 1024),
                    iters: int = 20) -> List[Tuple[str, float, str]]:
    """One decode-program forward at resident cache length T, next to one
    full-forward step over a T-token context: the decode step's cost must
    be (near-)flat in T while the re-forward step grows with it — the O(1)
    vs O(T)-per-token claim, measured."""
    import numpy as np

    from repro.frontends.extract import extract_decode
    from repro.frontends.optimize import compile_graph, optimize
    from repro.launch.serve import ServeConfig, build_lm

    cfg = ServeConfig(d_model=64, n_heads=4, n_layers=2, vocab=128,
                      max_seq=max(lengths), backend=backend)
    model = build_lm(cfg)
    rng = np.random.default_rng(0)
    rows: List[Tuple[str, float, str]] = []
    decode_us = {}
    for t_len in lengths:
        sol = compile_graph(
            model, extract_decode(model, 1, t_len, cfg.d_model), backend)
        vals = []
        for inp in sol.graph.inputs:
            if inp.spec.dtype.startswith("int"):
                vals.append(jnp.full(inp.spec.shape, t_len - 1, jnp.int32))
            else:
                vals.append(jnp.asarray(
                    rng.standard_normal(inp.spec.shape), jnp.float32))
        jax.block_until_ready(sol(*vals)[0])           # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = sol(*vals)
        jax.block_until_ready(out[0])
        decode_us[t_len] = (time.perf_counter() - t0) / iters * 1e6
    for t_len in lengths:
        ratio = decode_us[t_len] / decode_us[lengths[0]]
        rows.append((f"decode_step_cache{t_len}", decode_us[t_len],
                     f"x{ratio:.2f}_vs_cache{lengths[0]}"))
    for t_len in lengths:
        sol = optimize(model, (1, t_len, cfg.d_model), backend=backend)
        x = jnp.asarray(rng.standard_normal((1, t_len, cfg.d_model)),
                        jnp.float32)
        jax.block_until_ready(sol(x))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = sol(x)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append((f"reforward_step_T{t_len}", us,
                     f"x{us / decode_us[t_len]:.2f}_vs_decode_step"))
    return rows


def decode_bench(archs=("qwen2-1.5b", "rwkv6-1.6b", "recurrentgemma-9b"),
                 batch: int = 2, steps: int = 8
                 ) -> List[Tuple[str, float, str]]:
    """Per-architecture backbone decode-step timings (smoke configs) —
    keeps the reproduced sequence models (attention / RWKV6 / RG-LRU
    caches) on the serving perf trajectory next to the SolServer table."""
    from repro.configs import get_smoke
    from repro.models import backbone as B
    rows = []
    for arch in archs:
        cfg = get_smoke(arch)
        params = B.init_params(cfg, jax.random.PRNGKey(0))
        cache = B.init_cache(cfg, batch, 32)
        toks = jnp.zeros((batch, 1), jnp.int32)
        decode = jax.jit(
            lambda p, c, t, pos: B.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))
        logits, cache = decode(params, cache, toks, jnp.asarray(0))
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            logits, cache = decode(params, cache, toks, jnp.asarray(t))
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / steps * 1e6
        rows.append((f"decode_{arch}_smoke", us,
                     f"{batch * 1e6 / us:.0f}tok/s"))
    return rows


def csv_rows() -> List[Tuple[str, float, str]]:
    return (serve_rows("xla") + decode_vs_reforward("xla")
            + decode_flatness("xla") + decode_bench()
            + mesh_scaling_rows("xla"))        # no-op on a single device


def main(argv=None) -> int:
    """Standalone mesh/fleet-aware harness: the serving rows (or, with the
    ``fleet`` mode, the open-loop fleet-replay rows with one injected
    kill) without the rest of the serving table, so CI's dedicated jobs
    stay fast.  ``--json`` writes/merges the rows into a BENCH-schema
    file: existing rows with other names are preserved, so the mesh and
    fleet jobs can fold their rows into the main run's
    ``BENCH_serve.json``."""
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("mode", nargs="?", default="serve",
                    choices=["serve", "fleet"],
                    help="'serve': single-server rows (default); 'fleet': "
                         "open-loop replica-fleet replay with one "
                         "injected kill")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--mesh", default="1,1", metavar="DATA,MODEL")
    ap.add_argument("--replicas", type=int, default=3,
                    help="fleet mode: replica count")
    ap.add_argument("--requests", type=int, default=1000,
                    help="fleet mode: open-loop replay size")
    ap.add_argument("--json", help="write/merge rows into this BENCH file")
    args = ap.parse_args(argv)
    mesh = tuple(int(a) for a in args.mesh.split(","))
    if len(mesh) != 2:
        print("--mesh wants 'data,model'", file=sys.stderr)
        return 2

    if args.mode == "fleet":
        rows = fleet_rows(args.backend, replicas=args.replicas,
                          requests=args.requests)
    else:
        rows = serve_rows(args.backend, mesh=mesh)
        if mesh != (1, 1):
            rows += mesh_scaling_rows(args.backend, mesh)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        doc = {"tables": ["serving"], "rows": []}
        if os.path.exists(args.json):
            try:
                with open(args.json) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError):
                pass
        fresh = {n for n, _, _ in rows}
        doc["rows"] = ([r for r in doc.get("rows", [])
                        if r.get("name") not in fresh]
                       + [{"name": n, "us_per_call": us, "derived": d}
                          for n, us, d in rows])
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"[serving] wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
