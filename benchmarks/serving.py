"""Serving benchmark table: continuous batching THROUGH the SOL pipeline.

Earlier revisions of this table timed ``models/backbone.py`` decode steps —
a path that bypassed elections, pinned autotune configs and packed staging
entirely.  Now the table drives ``repro.launch.serve.SolServer``: the
workload is admitted into the KV-slot arena, padded to the autotune pow2
buckets, staged with one packed DMA per step and served by bucket models
whose every LINEAR/MATMUL/ATTENTION election is measured (the run warms
the autotune cache first and serves with ``strict_provenance``).

Rows (``name,us_per_call,derived``):

  serve_<backend>_step          mean wall time per scheduler step
  serve_<backend>_latency_p50   request latency percentile (us)
  serve_<backend>_latency_p99
  serve_<backend>_ttft_p50      time-to-first-token percentile (us)
  decode_<arch>_smoke           per-architecture backbone decode step
                                (qwen2 / rwkv6 / recurrentgemma) — kept so
                                the sequence-model scan kernels retain a
                                serving-side perf trajectory

The derived column carries tokens/s, DMA count and the bucket histogram —
``benchmarks/run.py --json`` additionally snapshots these rows into
``BENCH_serve.json`` so the serving perf trajectory accumulates in CI.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp


def serve_rows(backend: str = "xla", *, requests: int = 6,
               gen: int = 6) -> List[Tuple[str, float, str]]:
    from repro.core import autotune as AT
    from repro.launch.serve import ServeConfig, SolServer, _smoke_workload

    cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64,
                      max_seq=32, max_batch=4, slots=4, backend=backend)
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())      # private cache: measure, don't leak
    try:
        server = SolServer(cfg, strict_provenance=True)
        for prompt, g in _smoke_workload(cfg, requests, gen):
            server.submit(prompt, g)
        server.warm_autotune(warmup=1, iters=3)
        s = server.run()
        server.close()
    finally:
        AT.set_cache(prev)

    wall_us = (s["tokens"] / s["tokens_per_s"] * 1e6
               if s["tokens_per_s"] else 0.0)
    step_us = wall_us / max(s["steps"], 1)
    buckets = "/".join(f"{k}:{v}" for k, v in sorted(s["buckets"].items()))
    return [
        (f"serve_{backend}_step", step_us,
         f"{s['tokens_per_s']:.1f}tok/s;dmas={s['dmas']};"
         f"buckets={buckets}"),
        (f"serve_{backend}_latency_p50", s["latency_ms"]["p50"] * 1e3,
         f"{s['requests']}req"),
        (f"serve_{backend}_latency_p99", s["latency_ms"]["p99"] * 1e3, ""),
        (f"serve_{backend}_ttft_p50", s["ttft_ms"]["p50"] * 1e3,
         f"prefills={s['prefills']};decodes={s['decodes']}"),
    ]


def decode_bench(archs=("qwen2-1.5b", "rwkv6-1.6b", "recurrentgemma-9b"),
                 batch: int = 2, steps: int = 8
                 ) -> List[Tuple[str, float, str]]:
    """Per-architecture backbone decode-step timings (smoke configs) —
    keeps the reproduced sequence models (attention / RWKV6 / RG-LRU
    caches) on the serving perf trajectory next to the SolServer table."""
    from repro.configs import get_smoke
    from repro.models import backbone as B
    rows = []
    for arch in archs:
        cfg = get_smoke(arch)
        params = B.init_params(cfg, jax.random.PRNGKey(0))
        cache = B.init_cache(cfg, batch, 32)
        toks = jnp.zeros((batch, 1), jnp.int32)
        decode = jax.jit(
            lambda p, c, t, pos: B.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))
        logits, cache = decode(params, cache, toks, jnp.asarray(0))
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            logits, cache = decode(params, cache, toks, jnp.asarray(t))
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / steps * 1e6
        rows.append((f"decode_{arch}_smoke", us,
                     f"{batch * 1e6 / us:.0f}tok/s"))
    return rows


def csv_rows() -> List[Tuple[str, float, str]]:
    return serve_rows("xla") + decode_bench()
