"""Serving micro-benchmarks (beyond-paper table): smoke-size prefill/decode
throughput per architecture family on the host CPU."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp


def decode_bench(archs=("qwen2-1.5b", "rwkv6-1.6b", "recurrentgemma-9b"),
                 batch: int = 2, steps: int = 8) -> List[Tuple[str, float, str]]:
    from repro.configs import get_smoke
    from repro.models import backbone as B
    rows = []
    for arch in archs:
        cfg = get_smoke(arch)
        params = B.init_params(cfg, jax.random.PRNGKey(0))
        cache = B.init_cache(cfg, batch, 32)
        toks = jnp.zeros((batch, 1), jnp.int32)
        decode = jax.jit(
            lambda p, c, t, pos: B.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))
        logits, cache = decode(params, cache, toks, jnp.asarray(0))
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            logits, cache = decode(params, cache, toks, jnp.asarray(t))
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / steps * 1e6
        rows.append((f"decode_{arch}_smoke", us,
                     f"{batch * 1e6 / us:.0f}tok/s"))
    return rows
