"""Serving benchmark table: continuous batching THROUGH the SOL pipeline.

Earlier revisions of this table timed ``models/backbone.py`` decode steps —
a path that bypassed elections, pinned autotune configs and packed staging
entirely.  Now the table drives ``repro.launch.serve.SolServer``: the
workload is admitted into the KV-slot arena, padded to the autotune pow2
buckets, staged with one packed DMA per step and served by bucket models
whose every LINEAR/MATMUL/ATTENTION election is measured (the run warms
the autotune cache first and serves with ``strict_provenance``).

Rows (``name,us_per_call,derived``):

  serve_<backend>_step          mean wall time per scheduler step
  serve_<backend>_latency_p50   request latency percentile (us)
  serve_<backend>_latency_p99
  serve_<backend>_ttft_p50      time-to-first-token percentile (us)
  serve_<backend>_decode_tok    us/token with the incremental decode
                                program (ServeConfig.decode=True); derived
                                carries tokens/s and the speedup over the
                                re-forward baseline on the same weights
                                and workload
  serve_<backend>_reforward_tok us/token with the full re-forward baseline
                                (decode=False), measured back-to-back
  decode_step_cache<T>          one decode-program forward at resident
                                cache length T — the T=128 vs T=1024 pair
                                shows per-token decode cost is (near-)flat
                                in how much context is already resident,
                                where the re-forward rows below grow
  reforward_step_T<T>           one full-forward step over a T-token
                                context (what every decode step cost
                                before the decode program existed)
  decode_<arch>_smoke           per-architecture backbone decode step
                                (qwen2 / rwkv6 / recurrentgemma) — kept so
                                the sequence-model scan kernels retain a
                                serving-side perf trajectory

The derived column carries tokens/s, DMA count and the bucket histogram —
``benchmarks/run.py --json`` additionally snapshots these rows into
``BENCH_serve.json`` so the serving perf trajectory accumulates in CI.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp


def serve_rows(backend: str = "xla", *, requests: int = 6,
               gen: int = 6) -> List[Tuple[str, float, str]]:
    from repro.core import autotune as AT
    from repro.launch.serve import ServeConfig, SolServer, _smoke_workload

    cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64,
                      max_seq=32, max_batch=4, slots=4, backend=backend)
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())      # private cache: measure, don't leak
    try:
        server = SolServer(cfg, strict_provenance=True)
        for prompt, g in _smoke_workload(cfg, requests, gen):
            server.submit(prompt, g)
        server.warm_autotune(warmup=1, iters=3)
        s = server.run()
        server.close()
    finally:
        AT.set_cache(prev)

    wall_us = (s["tokens"] / s["tokens_per_s"] * 1e6
               if s["tokens_per_s"] else 0.0)
    step_us = wall_us / max(s["steps"], 1)
    buckets = "/".join(f"{k}:{v}" for k, v in sorted(s["buckets"].items()))
    return [
        (f"serve_{backend}_step", step_us,
         f"{s['tokens_per_s']:.1f}tok/s;dmas={s['dmas']};"
         f"buckets={buckets}"),
        (f"serve_{backend}_latency_p50", s["latency_ms"]["p50"] * 1e3,
         f"{s['requests']}req"),
        (f"serve_{backend}_latency_p99", s["latency_ms"]["p99"] * 1e3, ""),
        (f"serve_{backend}_ttft_p50", s["ttft_ms"]["p50"] * 1e3,
         f"prefills={s['prefills']};decodes={s['decodes']}"),
    ]


def decode_vs_reforward(backend: str = "xla", *, requests: int = 4,
                        gen: int = 120) -> List[Tuple[str, float, str]]:
    """Decode-heavy workload (short prompts, long generations) served twice
    on the SAME weights: once through the incremental decode program, once
    through the full re-forward baseline.  The first pass of each server
    compiles the bucket models; the timed pass replays the workload on the
    warm server, so the ratio is pure serving cost."""
    import dataclasses

    import numpy as np

    from repro.core import autotune as AT
    from repro.launch.serve import ServeConfig, SolServer, build_lm

    base = ServeConfig(d_model=128, n_heads=4, n_layers=2, vocab=128,
                       max_seq=256, max_batch=4, slots=4, backend=backend)
    model = build_lm(base)
    rng = np.random.default_rng(3)
    workload = [(rng.integers(0, base.vocab, int(rng.integers(4, 8)),
                              dtype=np.int32), gen)
                for _ in range(requests)]
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())
    tps = {}
    try:
        for decode in (False, True):
            cfg = dataclasses.replace(base, decode=decode)
            server = SolServer(cfg, model)
            for p, g in workload:          # compile pass: builds buckets
                server.submit(p, g)
            server.run()
            toks0 = server.stats["tokens"]
            t0 = time.perf_counter()
            for p, g in workload:          # timed pass: warm buckets only
                server.submit(p, g)
            server.run()
            dt = time.perf_counter() - t0
            tps[decode] = (server.stats["tokens"] - toks0) / dt
            server.close()
    finally:
        AT.set_cache(prev)
    ratio = tps[True] / tps[False] if tps[False] else 0.0
    return [
        (f"serve_{backend}_decode_tok", 1e6 / tps[True],
         f"{tps[True]:.1f}tok/s;x{ratio:.2f}_vs_reforward"),
        (f"serve_{backend}_reforward_tok", 1e6 / tps[False],
         f"{tps[False]:.1f}tok/s;baseline"),
    ]


def decode_flatness(backend: str = "xla", lengths=(128, 1024),
                    iters: int = 20) -> List[Tuple[str, float, str]]:
    """One decode-program forward at resident cache length T, next to one
    full-forward step over a T-token context: the decode step's cost must
    be (near-)flat in T while the re-forward step grows with it — the O(1)
    vs O(T)-per-token claim, measured."""
    import numpy as np

    from repro.frontends.extract import extract_decode
    from repro.frontends.optimize import compile_graph, optimize
    from repro.launch.serve import ServeConfig, build_lm

    cfg = ServeConfig(d_model=64, n_heads=4, n_layers=2, vocab=128,
                      max_seq=max(lengths), backend=backend)
    model = build_lm(cfg)
    rng = np.random.default_rng(0)
    rows: List[Tuple[str, float, str]] = []
    decode_us = {}
    for t_len in lengths:
        sol = compile_graph(
            model, extract_decode(model, 1, t_len, cfg.d_model), backend)
        vals = []
        for inp in sol.graph.inputs:
            if inp.spec.dtype.startswith("int"):
                vals.append(jnp.full(inp.spec.shape, t_len - 1, jnp.int32))
            else:
                vals.append(jnp.asarray(
                    rng.standard_normal(inp.spec.shape), jnp.float32))
        jax.block_until_ready(sol(*vals)[0])           # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = sol(*vals)
        jax.block_until_ready(out[0])
        decode_us[t_len] = (time.perf_counter() - t0) / iters * 1e6
    for t_len in lengths:
        ratio = decode_us[t_len] / decode_us[lengths[0]]
        rows.append((f"decode_step_cache{t_len}", decode_us[t_len],
                     f"x{ratio:.2f}_vs_cache{lengths[0]}"))
    for t_len in lengths:
        sol = optimize(model, (1, t_len, cfg.d_model), backend=backend)
        x = jnp.asarray(rng.standard_normal((1, t_len, cfg.d_model)),
                        jnp.float32)
        jax.block_until_ready(sol(x))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = sol(x)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append((f"reforward_step_T{t_len}", us,
                     f"x{us / decode_us[t_len]:.2f}_vs_decode_step"))
    return rows


def decode_bench(archs=("qwen2-1.5b", "rwkv6-1.6b", "recurrentgemma-9b"),
                 batch: int = 2, steps: int = 8
                 ) -> List[Tuple[str, float, str]]:
    """Per-architecture backbone decode-step timings (smoke configs) —
    keeps the reproduced sequence models (attention / RWKV6 / RG-LRU
    caches) on the serving perf trajectory next to the SolServer table."""
    from repro.configs import get_smoke
    from repro.models import backbone as B
    rows = []
    for arch in archs:
        cfg = get_smoke(arch)
        params = B.init_params(cfg, jax.random.PRNGKey(0))
        cache = B.init_cache(cfg, batch, 32)
        toks = jnp.zeros((batch, 1), jnp.int32)
        decode = jax.jit(
            lambda p, c, t, pos: B.decode_step(cfg, p, c, t, pos),
            donate_argnums=(1,))
        logits, cache = decode(params, cache, toks, jnp.asarray(0))
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            logits, cache = decode(params, cache, toks, jnp.asarray(t))
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / steps * 1e6
        rows.append((f"decode_{arch}_smoke", us,
                     f"{batch * 1e6 / us:.0f}tok/s"))
    return rows


def csv_rows() -> List[Tuple[str, float, str]]:
    return (serve_rows("xla") + decode_vs_reforward("xla")
            + decode_flatness("xla") + decode_bench())
