"""Benchmark harness — one function per paper table (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV.

  effort      paper Sec. VI-A programming-effort table (LOC; derived notes)
  inference   paper Fig. 3 left  (B=1, reference vs SOL)
  training    paper Fig. 3 right (B=16/64, reference vs SOL)
  roofline    deliverable (g): per (arch × shape) terms from the dry-run
  serving     beyond-paper decode throughput smoke

Run: PYTHONPATH=src python -m benchmarks.run [table ...]
"""
from __future__ import annotations

import sys


def main() -> None:
    which = set(sys.argv[1:]) or {"effort", "inference", "training",
                                  "roofline", "serving"}
    rows = []
    if "effort" in which:
        from . import paper_tables
        rows += [(n, v, d) for n, v, d in paper_tables.effort_table()]
    if "inference" in which:
        from . import paper_tables
        rows += paper_tables.inference_fig3()
    if "training" in which:
        from . import paper_tables
        rows += paper_tables.training_fig3()
    if "roofline" in which:
        from . import roofline
        rows += roofline.csv_rows()
    if "serving" in which:
        from . import serving
        rows += serving.decode_bench()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
