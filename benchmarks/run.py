"""Benchmark harness — one function per paper table (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV.

  effort      paper Sec. VI-A programming-effort table (LOC; derived notes)
  inference   paper Fig. 3 left  (B=1, reference vs SOL)
  training    paper Fig. 3 right (B=16/64, reference vs SOL)
  roofline    deliverable (g): per (arch × shape) terms from the dry-run
  layouts     oi/io Linear and NCHW/NHWC Conv timings driving assign_layouts
  matmul      tiled Pallas MXU matmul vs the einsum reference
  autotune    measured per-impl timings feeding the cache — a tiny sweep of
              every Tunable kernel family the registry declares (matmul
              tiles, attention blocks, DFP fusion sizing, scan blocks)
  serving     continuous batching through the SOL server (tokens/s +
              p50/p99 request latency + TTFT, measured elections only)
  sol         speed-of-light gap analysis: every elected kernel ranked by
              measured ÷ roofline-bound (with exact/nearest + measured/
              calibrated provenance), plus the gap-driven refinement
              planner's per-cell outcomes (configs found outside the
              declared tune_space, rewrite candidates)

Run: PYTHONPATH=src python -m benchmarks.run [table ...] [--json PATH]
     (also runnable as a plain script: python benchmarks/run.py sol)

``--json PATH`` additionally writes the rows as a JSON document (the
``BENCH_*.json`` series CI uploads as an artifact, so the perf trajectory
accumulates across commits).  When the ``matmul`` / ``serving`` / ``sol``
tables ran, stable-named siblings ``BENCH_matmul.json`` /
``BENCH_serve.json`` / ``BENCH_sol.json`` are emitted with just those
rows, so each perf trajectory has its own data points —
``tools/bench_diff.py`` diffs any two of them and CI fails on a >15%
regression in any shared row.

Exits non-zero if any requested table raises, so CI can gate on the smoke
step instead of silently shipping a partial CSV.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

if __package__ in (None, ""):            # plain-script mode: python benchmarks/run.py
    _here = os.path.dirname(os.path.abspath(__file__))
    _root = os.path.dirname(_here)
    for p in (_root, os.path.join(_root, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    import benchmarks                     # noqa: F401  (establish the package)
    __package__ = "benchmarks"


def _table_rows(name: str):
    if name == "effort":
        from . import paper_tables
        return [(n, v, d) for n, v, d in paper_tables.effort_table()]
    if name == "inference":
        from . import paper_tables
        return paper_tables.inference_fig3()
    if name == "training":
        from . import paper_tables
        return paper_tables.training_fig3()
    if name == "roofline":
        from . import roofline
        return roofline.csv_rows()
    if name == "layouts":
        from . import layouts
        return layouts.csv_rows()
    if name == "matmul":
        from . import autotune
        return autotune.matmul_rows()
    if name == "autotune":
        from . import autotune
        return autotune.csv_rows()
    if name == "serving":
        from . import serving
        return serving.csv_rows()
    if name == "sol":
        from . import autotune
        return autotune.sol_rows()
    if name == "train":
        from . import train_bench
        return train_bench.csv_rows()
    raise KeyError(f"unknown table {name!r}")


def main() -> int:
    argv = sys.argv[1:]
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            print("--json requires a path", file=sys.stderr)
            return 2
        argv = argv[:i] + argv[i + 2:]
    which = argv or ["effort", "inference", "training",
                     "roofline", "layouts", "matmul", "autotune", "serving"]
    rows, failed = [], []
    per_table = {}
    for name in which:
        try:
            table = _table_rows(name)
            per_table[name] = table
            rows += table
        except Exception:
            failed.append(name)
            print(f"[benchmarks] table {name!r} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if json_path:
        doc = {
            "tables": which,
            "failed": failed,
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
        }
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"[benchmarks] wrote {json_path}", file=sys.stderr)
    # stable-named side files so each table's perf trajectory has its own
    # data points across commits (written whenever the table ran, --json or
    # not — tools/bench_diff.py gates CI on these)
    for table, fname in (("matmul", "BENCH_matmul.json"),
                         ("serving", "BENCH_serve.json"),
                         ("sol", "BENCH_sol.json"),
                         ("train", "BENCH_train.json")):
        if not per_table.get(table):
            continue
        out_dir = os.path.dirname(json_path) if json_path else ""
        side = os.path.join(out_dir or ".", fname)
        with open(side, "w") as f:
            json.dump({"tables": [table],
                       "rows": [{"name": n, "us_per_call": us,
                                 "derived": d}
                                for n, us, d in per_table[table]]},
                      f, indent=2)
        print(f"[benchmarks] wrote {side}", file=sys.stderr)
    if failed:
        print(f"[benchmarks] failed tables: {', '.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
