"""Benchmark harness — one function per paper table (+ beyond-paper
tables).  Prints ``name,us_per_call,derived`` CSV.

  effort      paper Sec. VI-A programming-effort table (LOC; derived notes)
  inference   paper Fig. 3 left  (B=1, reference vs SOL)
  training    paper Fig. 3 right (B=16/64, reference vs SOL)
  roofline    deliverable (g): per (arch × shape) terms from the dry-run
  serving     beyond-paper decode throughput smoke

Run: PYTHONPATH=src python -m benchmarks.run [table ...]

Exits non-zero if any requested table raises, so CI can gate on the smoke
step instead of silently shipping a partial CSV.
"""
from __future__ import annotations

import sys
import traceback


def _table_rows(name: str):
    if name == "effort":
        from . import paper_tables
        return [(n, v, d) for n, v, d in paper_tables.effort_table()]
    if name == "inference":
        from . import paper_tables
        return paper_tables.inference_fig3()
    if name == "training":
        from . import paper_tables
        return paper_tables.training_fig3()
    if name == "roofline":
        from . import roofline
        return roofline.csv_rows()
    if name == "serving":
        from . import serving
        return serving.decode_bench()
    raise KeyError(f"unknown table {name!r}")


def main() -> int:
    which = sys.argv[1:] or ["effort", "inference", "training",
                             "roofline", "serving"]
    rows, failed = [], []
    for name in which:
        try:
            rows += _table_rows(name)
        except Exception:
            failed.append(name)
            print(f"[benchmarks] table {name!r} FAILED:", file=sys.stderr)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"[benchmarks] failed tables: {', '.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
