"""Benchmarks reproducing the paper's own tables/figures.

  * effort_table  — 'Programming effort' (Sec. VI-A): LOC per backend /
                    frontend, vs the paper's ≤3000-per-backend claim and
                    the 26k/47k inside-framework baselines.
  * inference_fig3 — Fig. 3 left: inference latency (B=1), framework-eager
                    reference vs SOL-optimized, on the host CPU.
  * training_fig3 — Fig. 3 right: training step latency (B=16 CNN / B=64
                    MLP), reference vs SOL.

The paper's absolute speedups are device-specific (Xeon 6126 / SX-Aurora /
GPUs); what reproduces here is the *direction and mechanism*: whole-graph
fusion beats op-at-a-time dispatch, with the win largest on memory-bound
nets (DenseNet-like chains) and smallest on pure-matmul MLPs (the paper:
'for the MLP there is no difference visible').
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn: Callable[[], object], warmup: int = 3, iters: int = 20
          ) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6      # µs


def effort_table() -> List[Tuple[str, float, str]]:
    import repro
    root = Path(repro.__file__).parent

    def loc(sub: str) -> int:
        return sum(len(p.read_text().splitlines())
                   for p in (root / sub).rglob("*.py"))

    rows = []
    rows.append(("loc_backend_registry", loc("backends"),
                 "paper: <=3000/backend"))
    rows.append(("loc_kernels_all", loc("kernels"),
                 "shared DFP codegen (5 kernels)"))
    rows.append(("loc_frontend", loc("frontends"),
                 "paper: ~2400/frontend"))
    rows.append(("loc_core_compiler", loc("core"), "IR+passes+executor"))
    rows.append(("loc_distributed", loc("distributed"), "beyond-paper"))
    rows.append(("loc_models", loc("models"), "beyond-paper (10 archs)"))
    return rows


def _bench_pair(model, shape, train: bool = False,
                batch: int = 1) -> Tuple[float, float]:
    """(reference_us, sol_us) for one model."""
    from repro.frontends.optimize import optimize
    x = np.random.default_rng(0).standard_normal(shape).astype(np.float32)
    xj = jnp.asarray(x)

    sol = optimize(model, shape)
    if not train:
        ref_us = _time(lambda: model(xj))
        sol_params = sol._params_for_call()
        fn = sol._fn
        sol_us = _time(lambda: fn(sol_params, xj))
        return ref_us, sol_us

    # training: d(loss)/d(params) through eager layers vs SOL whole-graph
    params = sol._params_for_call()
    graph_fn = sol._fn

    def sol_loss(p, xx):
        return jnp.mean(graph_fn(p, xx) ** 2)

    sol_grad = jax.jit(jax.grad(sol_loss))

    sd = model.state_dict()
    keys = sorted(sd)

    def eager_loss(plist, xx):
        model.load_state_dict(dict(zip(keys, plist)))
        return jnp.mean(model(xx) ** 2)

    # eager autograd re-traces through per-layer jits (dispatch per layer)
    eager_grad = jax.grad(eager_loss)
    ref_us = _time(lambda: eager_grad([sd[k] for k in keys], xj), 1, 5)
    sol_us = _time(lambda: sol_grad(params, xj), 1, 5)
    return ref_us, sol_us


def inference_fig3() -> List[Tuple[str, float, str]]:
    from repro.frontends import nn
    rows = []
    cases = [
        ("mlp_B1", nn.mlp_8192(3, 2048, 2048, 1000), (1, 2048)),
        ("small_cnn_B1", nn.small_cnn(), (1, 3, 64, 64)),
        ("depthwise_cnn_B1", nn.depthwise_cnn(), (1, 3, 64, 64)),
        # beyond-paper: sequence blocks through the pipeline (PR 2)
        ("transformer_B1", nn.transformer_block(64, 4), (1, 64, 64)),
        ("griffin_B1", nn.griffin_block(64), (1, 64, 64)),
    ]
    for name, model, shape in cases:
        ref, sol = _bench_pair(model, shape)
        rows.append((f"infer_{name}_reference", ref, ""))
        rows.append((f"infer_{name}_sol", sol,
                     f"speedup={ref / sol:.2f}x"))
    return rows


def training_fig3() -> List[Tuple[str, float, str]]:
    from repro.frontends import nn
    rows = []
    cases = [
        ("mlp_B64", nn.mlp_8192(3, 1024, 1024, 256), (64, 1024)),
        ("small_cnn_B16", nn.small_cnn(), (16, 3, 32, 32)),
    ]
    for name, model, shape in cases:
        ref, sol = _bench_pair(model, shape, train=True)
        rows.append((f"train_{name}_reference", ref, ""))
        rows.append((f"train_{name}_sol", sol,
                     f"speedup={ref / sol:.2f}x"))
    return rows
