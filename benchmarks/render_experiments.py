"""Render the data-driven sections of EXPERIMENTS.md (dry-run matrix +
roofline tables + baseline-vs-optimized comparison) from results/*.jsonl."""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def load(path):
    recs = {}
    p = ROOT / "results" / path
    if not p.exists():
        return {}
    for line in p.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        recs[(r["arch"], r["shape"], r.get("mesh", "1pod"))] = r
    return recs


def dryrun_matrix(recs) -> str:
    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    out = [f"| arch | " + " | ".join(shapes) + " |",
           "|---" * (len(shapes) + 1) + "|"]
    for a in archs:
        row = [a]
        for s in shapes:
            cells = []
            for m in ("1pod", "2pod"):
                r = recs.get((a, s, m))
                cells.append("✓" if r and r["status"] == "ok" else
                             ("skip" if r and r["status"] == "skipped"
                              else "?"))
            row.append("/".join(cells))
        out.append("| " + " | ".join(row) + " |")
    return "\n".join(out)


def roofline_md(recs, mesh="1pod") -> str:
    import sys
    sys.path.insert(0, str(ROOT / "src"))
    from benchmarks.roofline import roofline_row
    rows = []
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        row = roofline_row(r)
        if row:
            rows.append(row)
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | useful | roofline% | temp GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{100 * r['roofline_fraction']:.2f}% | "
            f"{r['temp_bytes'] / 2**30:.1f} |")
    return "\n".join(out)


def before_after(base, opt) -> str:
    out = ["| arch × shape | term | baseline | optimized | Δ |",
           "|---|---|---|---|---|"]
    for key in sorted(set(base) & set(opt)):
        a, s, m = key
        if m != "1pod":
            continue
        b, o = base[key], opt[key]
        if b.get("status") != "ok" or o.get("status") != "ok":
            continue
        for term, bw in (("flops", 197e12), ("hbm_bytes", 819e9),
                         ("ici_bytes", 50e9)):
            tb = b.get(f"{term}_per_device", 0) / bw
            to = o.get(f"{term}_per_device", 0) / bw
            if tb <= 0:
                continue
            d = (to - tb) / tb * 100
            if abs(d) < 1:
                continue
            out.append(f"| {a} × {s} | {term} | {tb:.3f}s | {to:.3f}s | "
                       f"{d:+.0f}% |")
    return "\n".join(out)


if __name__ == "__main__":
    opt = load("dryrun.jsonl")
    base = load("dryrun_baseline.jsonl")
    print("## matrix\n")
    print(dryrun_matrix(opt))
    print("\n## roofline 1pod\n")
    print(roofline_md(opt, "1pod"))
    print("\n## roofline 2pod\n")
    print(roofline_md(opt, "2pod"))
    print("\n## before/after\n")
    print(before_after(base, opt))
