"""Roofline analysis over the dry-run results (deliverable g).

Per (arch × shape × mesh) cell, from the loop-aware HLO accounting in
results/dryrun.jsonl:

  compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = ICI_bytes_per_device / link_bw

with the constants taken from the backend registry's HardwareSpec (the same
cost model the implementation-election pass in core.passes uses).  The spec
is resolved from the ACTIVE backend — ``SOL_BACKEND`` in the environment,
default ``"xla"`` whose spec is the production target (tpu_v5e: 197e12 bf16
/ 819e9 / 50e9) — never hardcoded at import time, so SOL ratios and
roofline rows describe the hardware that actually produced the
measurements.

dominant term = bottleneck; MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D
(MoE); usefulness ratio = MODEL_FLOPS / HLO_FLOPs (catches remat and
redundant compute).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Optional

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun.jsonl"

DEFAULT_BACKEND = "xla"          # its HardwareSpec is the tpu_v5e target


def active_backend_name() -> str:
    """The backend whose ``HardwareSpec`` the roofline rows describe —
    ``SOL_BACKEND`` from the environment, else :data:`DEFAULT_BACKEND`."""
    return os.environ.get("SOL_BACKEND", DEFAULT_BACKEND)


def active_hw(backend: Optional[str] = None):
    """Resolve the active backend's ``HardwareSpec`` through the registry
    (read per call, not at import, so ``SOL_BACKEND`` set by a test or a
    driver after import still takes effect)."""
    from repro.backends import get_backend
    return get_backend(backend or active_backend_name()).hw


def load_cells(path: Path = RESULTS) -> List[dict]:
    recs = {}
    if not path.exists():
        return []
    for line in path.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return list(recs.values())


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import get_config
    from repro.models import backbone as B
    from repro.models.config import SHAPES
    cfg = get_config(arch)
    shp = SHAPES[shape]
    n_active = B.count_active_params(cfg)
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens / n_devices
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n_active * shp.global_batch / n_devices


def roofline_row(r: dict, hw=None) -> Optional[dict]:
    if r.get("status") != "ok":
        return None
    hw = hw if hw is not None else active_hw()
    f = r["flops_per_device"]
    b = r["hbm_bytes_per_device"]
    i = r["ici_bytes_per_device"]
    terms = {"compute": hw.compute_s(f), "memory": hw.memory_s(b),
             "collective": hw.collective_s(i)}
    dom = max(terms, key=terms.get)
    mf = model_flops(r["arch"], r["shape"], r["n_devices"])
    bound = max(terms.values())
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "hw": hw.name,
        "compute_s": terms["compute"], "memory_s": terms["memory"],
        "collective_s": terms["collective"], "dominant": dom,
        "model_flops_per_device": mf,
        "useful_ratio": mf / f if f else 0.0,
        # roofline fraction: useful-compute time over the bound the program
        # actually hits (1.0 = the chip spends all time on model math)
        "roofline_fraction": ((mf / hw.peak_flops_bf16) / bound
                              if bound else 0.0),
        "temp_bytes": r.get("memory", {}).get("temp_size_in_bytes", 0),
    }


def table(mesh: str = "1pod", hw=None) -> List[dict]:
    hw = hw if hw is not None else active_hw()
    rows = []
    for r in load_cells():
        if r.get("mesh") != mesh:
            continue
        row = roofline_row(r, hw)
        if row:
            rows.append(row)
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def render(rows: List[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{100 * r['roofline_fraction']:6.2f}%")
    return "\n".join(out)


def csv_rows() -> List[tuple]:
    out = []
    for r in table("1pod"):
        name = f"roofline_{r['arch']}_{r['shape']}"
        bound_us = max(r["compute_s"], r["memory_s"],
                       r["collective_s"]) * 1e6
        out.append((name, bound_us,
                    f"dom={r['dominant']};roofline={r['roofline_fraction']:.3f}"))
    return out


if __name__ == "__main__":
    print(render(table("1pod")))
    print()
    print(render(table("2pod")))
