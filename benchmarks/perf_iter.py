"""Perf-iteration harness: lower one cell, print the three roofline terms
and the top FLOPs/traffic/collective contributors; append tagged results to
results/perf_log.jsonl for the §Perf before/after log.

  PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen2-1.5b \
      --shape train_4k --tag baseline

Each record also carries the whole-model elementwise profile
(``ew_flops``/``ew_elements`` from ``hlo_analysis.elementwise_profile``);
``--calibrate-ew`` fits the accumulated records back onto the DFP cost
model's per-element FLOP constant (``core.passes.calibrate_ew_flops``,
replacing the nominal hard-coded 5.0) and prints the SOL_EW_FLOPS export
that carries the fit into other processes.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
from pathlib import Path

LOG = Path(__file__).resolve().parents[1] / "results" / "perf_log.jsonl"


def top_contributors(text: str, n_devices: int, k: int = 8):
    from repro.launch import hlo_analysis as HA
    comps = HA.parse_module(text)
    mult = HA.compute_multipliers(comps)
    kinds = mult.pop("__kinds__")
    dots, traffic, colls = [], [], []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if m <= 0:
            continue
        is_fusion = kinds.get(name) in ("fusion", "apply")
        for op in comp.ops:
            if op.opcode == "dot":
                meta = ""
                i = op.attrs.find("op_name=")
                if i >= 0:
                    meta = op.attrs[i + 9:i + 89]
                dots.append((m * HA.dot_flops(op, comp.types), op.name,
                             op.type_str[:40], meta))
            if is_fusion:
                continue
            base = op.opcode.replace("-start", "")
            if base in HA.COLLECTIVES:
                kind, size, t = HA.collective_traffic(op, n_devices)
                meta = ""
                i = op.attrs.find("op_name=")
                if i >= 0:
                    meta = op.attrs[i + 9:i + 89]
                colls.append((m * t, kind, op.type_str[:40], meta))
                continue
            if op.opcode in HA._NO_TRAFFIC or op.opcode.endswith("-done"):
                continue
            traffic.append((m * HA._op_traffic(op, comp, comps),
                            op.opcode, op.name[:36], op.type_str[:40]))
    for lst, label, unit in ((dots, "FLOPS", 1e12), (traffic, "TRAFFIC", 1e9),
                             (colls, "COLLECTIVE", 1e9)):
        lst.sort(reverse=True, key=lambda r: r[0])
        print(f"-- top {label} --")
        for r in lst[:k]:
            u = "T" if unit == 1e12 else "GB"
            print(f"  {r[0] / unit:10.2f}{u} {' '.join(str(x) for x in r[1:])[:130]}")


def ew_samples(log_path: Path = LOG):
    """(ew_flops, ew_elements) pairs from every perf_log record that carries
    the elementwise profile — the input to ``passes.calibrate_ew_flops``."""
    samples = []
    if not log_path.exists():
        return samples
    with log_path.open() as fh:
        for line in fh:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            f, e = rec.get("ew_flops"), rec.get("ew_elements")
            if f and e:
                samples.append((float(f), float(e)))
    return samples


def calibrate_ew(log_path: Path = LOG) -> int:
    from repro.core import passes
    samples = ew_samples(log_path)
    if not samples:
        print(f"[perf_iter] {log_path} holds no elementwise profiles; run "
              "a --tag measurement first", file=sys.stderr)
        return 1
    old = passes.ew_flops()
    fitted = passes.calibrate_ew_flops(samples)
    print(f"[perf_iter] _EW_FLOPS calibrated from {len(samples)} "
          f"whole-model records: {old:.2f} → {fitted:.2f} FLOPs/element; "
          f"export SOL_EW_FLOPS={fitted:.4f} to apply in other processes")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="1pod")
    ap.add_argument("--tag")
    ap.add_argument("--top", type=int, default=8)
    ap.add_argument("--no-detail", action="store_true")
    ap.add_argument("--calibrate-ew", action="store_true",
                    help="fit the DFP per-element FLOP constant from the "
                         "accumulated perf_log records and stop")
    args = ap.parse_args()

    if args.calibrate_ew:
        sys.exit(calibrate_ew())
    if not args.arch or not args.tag:
        ap.error("--arch and --tag are required unless --calibrate-ew")

    from repro.launch.dryrun import lower_cell, memory_summary
    from repro.launch import hlo_analysis as HA

    t0 = time.time()
    lowered, mesh, cfg, shape = lower_cell(args.arch, args.shape,
                                           args.mesh == "2pod")
    compiled = lowered.compile()
    text = compiled.as_text()
    n_dev = mesh.devices.size
    res = HA.analyze(text, n_dev)
    mem = memory_summary(compiled)
    ew_f, ew_e = res["ew_flops"], res["ew_elements"]
    f, b, i = (res["flops_per_device"], res["hbm_bytes_per_device"],
               res["ici_bytes_per_device"])
    terms = {"compute_s": f / 197e12, "memory_s": b / 819e9,
             "collective_s": i / 50e9}
    rec = {"tag": args.tag, "arch": cfg.name, "shape": args.shape,
           "mesh": args.mesh, **terms,
           "flops_per_device": f, "hbm_bytes_per_device": b,
           "ici_bytes_per_device": i,
           "ew_flops": ew_f, "ew_elements": ew_e,
           "temp_bytes": mem.get("temp_size_in_bytes", 0),
           "collectives": res["collectives"],
           "compile_s": round(time.time() - t0, 1)}
    LOG.parent.mkdir(exist_ok=True, parents=True)
    with LOG.open("a") as fh:
        fh.write(json.dumps(rec) + "\n")
    dom = max(terms, key=terms.get)
    print(f"[{args.tag}] {cfg.name} × {args.shape}: "
          f"compute {terms['compute_s']:.3f}s  memory {terms['memory_s']:.3f}s  "
          f"collective {terms['collective_s']:.3f}s  → {dom} dominant; "
          f"temp {rec['temp_bytes'] / 2**30:.1f}GiB")
    if not args.no_detail:
        top_contributors(text, n_dev, args.top)


if __name__ == "__main__":
    main()
