"""Calibrate the election cost model from measured autotune data (ROADMAP:
'calibrate the cost model').

``passes._node_cost_terms`` produces analytic (flops, bytes) per node and the
election costs impls with the nominal ``HardwareSpec`` roofline.  This tool
regresses the autotune cache's measurements back onto those terms: for each
(backend, op) it fits

    time_s  ≈  s_per_flop · flops  +  s_per_byte · nbytes

by non-negative least squares over every recorded (impl, shape bucket)
measurement, where nbytes already reflects each impl's memory mode
(streamed vs roundtrip).  The reciprocals are the backend's *effective*
FLOP/s and bytes/s for that op — usually far below nameplate, which is
exactly the cold-start error the fit removes.

``--apply`` writes the coefficients into the cache file's ``calibration``
section (atomically); ``elect_implementations`` then uses them instead of
the nominal roofline whenever an (op, shape) has no direct measurement —
'calibrated' provenance in ``SolModel.impl_report(provenance=True)``.

Run:  PYTHONPATH=src python -m benchmarks.calibrate \\
          --cache results/autotune_cache.json --apply
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Tuple


def fit(cache) -> Dict[Tuple[str, str], Dict[str, float]]:
    """Per-(backend, op) non-negative least squares of measured seconds onto
    (flops, nbytes).  The 2×2 normal equations are solved directly; a
    negative coefficient is clamped to zero and the remaining 1-D fit
    re-solved through the origin."""
    samples: Dict[Tuple[str, str], List[Tuple[float, float, float]]] = {}
    for (op, _dtype, backend), _bucket, _impl, m in cache.entries():
        if m.us <= 0 or (m.flops <= 0 and m.nbytes <= 0):
            continue
        samples.setdefault((backend, op), []).append(
            (m.flops, m.nbytes, m.us * 1e-6))

    out: Dict[Tuple[str, str], Dict[str, float]] = {}
    for key, rows in samples.items():
        sff = sum(f * f for f, _, _ in rows)
        sbb = sum(b * b for _, b, _ in rows)
        sfb = sum(f * b for f, b, _ in rows)
        sft = sum(f * t for f, _, t in rows)
        sbt = sum(b * t for _, b, t in rows)
        det = sff * sbb - sfb * sfb
        if det > 0:
            a = (sft * sbb - sbt * sfb) / det
            b = (sbt * sff - sft * sfb) / det
        else:
            a = b = -1.0
        if a < 0 or b < 0:                     # clamp + re-solve 1-D
            a_only = sft / sff if sff else 0.0
            b_only = sbt / sbb if sbb else 0.0

            def sse(aa: float, bb: float) -> float:
                return sum((t - aa * f - bb * nb) ** 2
                           for f, nb, t in rows)

            a, b = min(((a_only, 0.0), (0.0, b_only)),
                       key=lambda ab: sse(*ab))
        out[key] = {"s_per_flop": a, "s_per_byte": b, "n": float(len(rows))}
    return out


def csv_rows(cache) -> List[Tuple[str, float, str]]:
    rows = []
    for (backend, op), c in sorted(fit(cache).items()):
        eff_flops = 1.0 / c["s_per_flop"] if c["s_per_flop"] else 0.0
        eff_bw = 1.0 / c["s_per_byte"] if c["s_per_byte"] else 0.0
        rows.append((f"calibrate_{backend}_{op}", c["n"],
                     f"eff_gflops={eff_flops / 1e9:.2f};"
                     f"eff_gbps={eff_bw / 1e9:.2f}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache", default="results/autotune_cache.json")
    ap.add_argument("--apply", action="store_true",
                    help="write fitted coefficients into the cache file's "
                         "calibration section")
    args = ap.parse_args()

    from repro.core import autotune as AT
    cache = AT.AutotuneCache.load(args.cache)
    if cache.stale or not len(cache):
        print(f"[calibrate] {args.cache} is empty or stale; run "
              "benchmarks.autotune first", file=sys.stderr)
        return 1
    coeffs = fit(cache)
    print("name,us_per_call,derived")
    for name, n, derived in csv_rows(cache):
        print(f"{name},{n:.1f},{derived}")
    if args.apply:
        for (backend, op), c in coeffs.items():
            cache.set_calibration(backend, op, c)
        cache.save(args.cache)
        print(f"[calibrate] wrote {len(coeffs)} coefficient sets to "
              f"{args.cache}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
