"""Layout-election benchmark (ROADMAP item): measure the choices
``assign_layouts`` currently asserts.

Two tables, both on whatever device jax defaults to:

  * Linear weight layout — 'oi' (out,in; contraction via ``...i,oi->...o``,
    the torch/CPU-BLAS convention) vs 'io' (in,out; ``...i,io->...o``, the
    long-vector/TPU convention the paper elects for NEC Aurora).
  * Conv data layout — NCHW vs NHWC (minor-most channels on the lane dim).

The derived column reports the measured winner and what each registered
backend's ``preferred_layout`` would have elected, so drift between the
model and the data is visible in every benchmark run.

``python -m benchmarks.layouts --apply`` closes the loop (the PR 2
follow-up): the measured winners replace every registered backend's static
layout strings for the session via ``set_layout_preference``, so subsequent
``assign_layouts`` runs elect what the data elected.
"""
from __future__ import annotations

import argparse
import functools
import sys
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .paper_tables import _time


@functools.partial(jax.jit)
def _linear_oi(x, w):          # w: (out, in)
    return jnp.einsum("bi,oi->bo", x, w)


@functools.partial(jax.jit)
def _linear_io(x, w):          # w: (in, out)
    return jnp.einsum("bi,io->bo", x, w)


@functools.partial(jax.jit, static_argnames=("dn",))
def _conv(x, w, dn):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), ((1, 1), (1, 1)), dimension_numbers=dn)


def _backend_prefs(kind: str) -> str:
    from repro.backends import available_backends
    from repro.core import ir
    from repro.core.ir import Node, OpKind, TensorSpec
    if kind == "linear":
        node = Node(OpKind.LINEAR, [ir.input_node((1, 8))],
                    TensorSpec((1, 8)), attrs={"out_features": 8})
    else:
        node = Node(OpKind.CONV2D, [ir.input_node((1, 8, 8, 8))],
                    TensorSpec((1, 8, 8, 8)), attrs={"out_channels": 8})
    return "|".join(f"{n}={b.preferred_layout(node)}"
                    for n, b in sorted(available_backends().items()))


def bench() -> Tuple[List[Tuple[str, float, str]], Dict[str, str]]:
    """Benchmark rows plus the overall measured winners, elected by total
    time across the shape sweep: {'linear': 'oi'|'io', 'conv': 'nchw'|'nhwc'}.
    """
    rng = np.random.default_rng(0)
    rows: List[Tuple[str, float, str]] = []
    totals = {"oi": 0.0, "io": 0.0, "nchw": 0.0, "nhwc": 0.0}

    for b, d_in, d_out in ((32, 1024, 1024), (8, 4096, 512)):
        x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
        w_oi = jnp.asarray(rng.standard_normal((d_out, d_in)), jnp.float32)
        w_io = w_oi.T
        t_oi = _time(lambda: _linear_oi(x, w_oi))
        t_io = _time(lambda: _linear_io(x, w_io))
        totals["oi"] += t_oi
        totals["io"] += t_io
        win = "oi" if t_oi <= t_io else "io"
        tag = f"linear_{b}x{d_in}x{d_out}"
        rows.append((f"layout_{tag}_oi", t_oi, ""))
        rows.append((f"layout_{tag}_io", t_io,
                     f"faster={win};{_backend_prefs('linear')}"))

    for b, c_in, c_out, hw in ((4, 32, 64, 32), (1, 64, 128, 16)):
        x = rng.standard_normal((b, c_in, hw, hw)).astype(np.float32)
        w = rng.standard_normal((c_out, c_in, 3, 3)).astype(np.float32)
        x_nchw, w_oihw = jnp.asarray(x), jnp.asarray(w)
        x_nhwc = jnp.asarray(x.transpose(0, 2, 3, 1))
        w_hwio = jnp.asarray(w.transpose(2, 3, 1, 0))
        t_nchw = _time(lambda: _conv(x_nchw, w_oihw,
                                     ("NCHW", "OIHW", "NCHW")))
        t_nhwc = _time(lambda: _conv(x_nhwc, w_hwio,
                                     ("NHWC", "HWIO", "NHWC")))
        totals["nchw"] += t_nchw
        totals["nhwc"] += t_nhwc
        win = "nchw" if t_nchw <= t_nhwc else "nhwc"
        tag = f"conv_{b}x{c_in}to{c_out}x{hw}"
        rows.append((f"layout_{tag}_nchw", t_nchw, ""))
        rows.append((f"layout_{tag}_nhwc", t_nhwc,
                     f"faster={win};{_backend_prefs('conv')}"))
    winners = {
        "linear": "oi" if totals["oi"] <= totals["io"] else "io",
        "conv": "nchw" if totals["nchw"] <= totals["nhwc"] else "nhwc",
    }
    return rows, winners


def csv_rows() -> List[Tuple[str, float, str]]:
    return bench()[0]


def apply_measured(winners: Dict[str, str]) -> Dict[str, str]:
    """Write the measured layout winners into every registered backend for
    the session (the --apply flag).  Returns {backend: 'old→new'} for the
    preferences that actually changed."""
    from repro.backends import (available_backends, get_backend,
                                set_layout_preference)
    changes: Dict[str, str] = {}
    for name in sorted(available_backends()):
        before = get_backend(name)
        set_layout_preference(name, linear=winners["linear"],
                              conv=winners["conv"])
        after = get_backend(name)
        diff = []
        if before.linear_weight_layout != after.linear_weight_layout:
            diff.append(f"linear:{before.linear_weight_layout}"
                        f"→{after.linear_weight_layout}")
        if before.conv_layout != after.conv_layout:
            diff.append(f"conv:{before.conv_layout}→{after.conv_layout}")
        if diff:
            changes[name] = ",".join(diff)
    return changes


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--apply", action="store_true",
                    help="write the measured winners into the backend "
                         "registry for this session")
    args = ap.parse_args()
    rows, winners = bench()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"[layouts] measured winners: {winners}", file=sys.stderr)
    if args.apply:
        changes = apply_measured(winners)
        print(f"[layouts] applied to registry; changed: "
              f"{changes or 'nothing (static strings already agree)'}",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
