"""Layout-election benchmark (ROADMAP item): measure the choices
``assign_layouts`` currently asserts.

Two tables, both on whatever device jax defaults to:

  * Linear weight layout — 'oi' (out,in; contraction via ``...i,oi->...o``,
    the torch/CPU-BLAS convention) vs 'io' (in,out; ``...i,io->...o``, the
    long-vector/TPU convention the paper elects for NEC Aurora).
  * Conv data layout — NCHW vs NHWC (minor-most channels on the lane dim).

The derived column reports the measured winner and what each registered
backend's ``preferred_layout`` would have elected, so drift between the
model and the data is visible in every benchmark run.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .paper_tables import _time


@functools.partial(jax.jit)
def _linear_oi(x, w):          # w: (out, in)
    return jnp.einsum("bi,oi->bo", x, w)


@functools.partial(jax.jit)
def _linear_io(x, w):          # w: (in, out)
    return jnp.einsum("bi,io->bo", x, w)


@functools.partial(jax.jit, static_argnames=("dn",))
def _conv(x, w, dn):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), ((1, 1), (1, 1)), dimension_numbers=dn)


def _backend_prefs(kind: str) -> str:
    from repro.backends import available_backends
    from repro.core import ir
    from repro.core.ir import Node, OpKind, TensorSpec
    if kind == "linear":
        node = Node(OpKind.LINEAR, [ir.input_node((1, 8))],
                    TensorSpec((1, 8)), attrs={"out_features": 8})
    else:
        node = Node(OpKind.CONV2D, [ir.input_node((1, 8, 8, 8))],
                    TensorSpec((1, 8, 8, 8)), attrs={"out_channels": 8})
    return "|".join(f"{n}={b.preferred_layout(node)}"
                    for n, b in sorted(available_backends().items()))


def csv_rows() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows: List[Tuple[str, float, str]] = []

    for b, d_in, d_out in ((32, 1024, 1024), (8, 4096, 512)):
        x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
        w_oi = jnp.asarray(rng.standard_normal((d_out, d_in)), jnp.float32)
        w_io = w_oi.T
        t_oi = _time(lambda: _linear_oi(x, w_oi))
        t_io = _time(lambda: _linear_io(x, w_io))
        win = "oi" if t_oi <= t_io else "io"
        tag = f"linear_{b}x{d_in}x{d_out}"
        rows.append((f"layout_{tag}_oi", t_oi, ""))
        rows.append((f"layout_{tag}_io", t_io,
                     f"faster={win};{_backend_prefs('linear')}"))

    for b, c_in, c_out, hw in ((4, 32, 64, 32), (1, 64, 128, 16)):
        x = rng.standard_normal((b, c_in, hw, hw)).astype(np.float32)
        w = rng.standard_normal((c_out, c_in, 3, 3)).astype(np.float32)
        x_nchw, w_oihw = jnp.asarray(x), jnp.asarray(w)
        x_nhwc = jnp.asarray(x.transpose(0, 2, 3, 1))
        w_hwio = jnp.asarray(w.transpose(2, 3, 1, 0))
        t_nchw = _time(lambda: _conv(x_nchw, w_oihw,
                                     ("NCHW", "OIHW", "NCHW")))
        t_nhwc = _time(lambda: _conv(x_nhwc, w_hwio,
                                     ("NHWC", "HWIO", "NHWC")))
        win = "nchw" if t_nchw <= t_nhwc else "nhwc"
        tag = f"conv_{b}x{c_in}to{c_out}x{hw}"
        rows.append((f"layout_{tag}_nchw", t_nchw, ""))
        rows.append((f"layout_{tag}_nhwc", t_nhwc,
                     f"faster={win};{_backend_prefs('conv')}"))
    return rows
