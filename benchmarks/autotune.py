"""Autotune driver — populates the persistent timing cache the election pass
prefers over the roofline model (``core.autotune``).

For each (op, shape) in the sweep it times **every impl the dispatch table
admits** for the chosen backend; for tunable kernels (the MXU matmul family)
it additionally sweeps the kernel's tile-config search space and records the
winner's config next to its time, so a later election can pin it on the node.

Run:  PYTHONPATH=src python -m benchmarks.autotune \\
          --backend pallas_interpret --tiny --cache autotune_cache.json --verify

``--verify`` reloads the cache from disk and re-runs the election on a small
model, failing unless the report shows 'measured' provenance — the
write → read → election round-trip CI smokes on every commit.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .paper_tables import _time

# (M, K, N) problem sweeps; --tiny keeps CI's interpret-mode runs quick
SHAPES: Dict[str, List[Tuple[int, int, int]]] = {
    "matmul": [(256, 256, 256), (512, 512, 512), (128, 512, 256)],
    "linear": [(32, 1024, 1024), (8, 4096, 512)],
}
TINY_SHAPES: Dict[str, List[Tuple[int, int, int]]] = {
    "matmul": [(32, 32, 32), (16, 48, 24)],
    "linear": [(8, 64, 32)],
}


def _node(op: str, shape: Tuple[int, int, int]):
    """One dispatchable node for an (op, M, K, N) problem."""
    from repro.core import ir
    from repro.core.ir import Node, OpKind, TensorSpec
    m, k, n = shape
    if op == "matmul":
        return Node(OpKind.MATMUL,
                    [ir.input_node((m, k)), ir.input_node((k, n))],
                    TensorSpec((m, n)))
    if op == "linear":
        return Node(OpKind.LINEAR,
                    [ir.input_node((m, k)), ir.param_node((n, k), name="w")],
                    TensorSpec((m, n)), attrs={"out_features": n})
    raise KeyError(f"unknown autotune op {op!r}")


def _build(op: str, shape: Tuple[int, int, int]):
    """The node plus concrete operand arrays to time it with."""
    m, k, n = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w_shape = (k, n) if op == "matmul" else (n, k)   # linear stores (out,in)
    w = jnp.asarray(rng.standard_normal(w_shape), jnp.float32)
    return _node(op, shape), [x, w]


def _time_impl(impl, node, vals: Sequence[jax.Array], backend,
               warmup: int, iters: int) -> float:
    fn = jax.jit(lambda *a: impl.fn(node, list(a), backend))
    return _time(lambda: fn(*vals), warmup=warmup, iters=iters)


def tune(backend_name: str = "pallas_interpret",
         ops: Sequence[str] = ("matmul", "linear"), *,
         tiny: bool = False, warmup: int = 2, iters: int = 5,
         cache=None) -> List[Tuple[str, float, str]]:
    """Measure every admissible impl of each (op, shape) through the dispatch
    table, recording best times (and winning tile configs) into ``cache``.
    Returns benchmark rows for the CSV/JSON harness."""
    from repro.backends import get_backend
    from repro.backends import registry as R
    from repro.core import autotune as AT
    from repro.core.passes import _node_cost_terms
    from repro.kernels.matmul.kernel import tile_space

    backend = get_backend(backend_name)
    cache = cache if cache is not None else AT.get_cache()
    rows: List[Tuple[str, float, str]] = []
    shapes = TINY_SHAPES if tiny else SHAPES
    for op in ops:
        for shape in shapes[op]:
            node, vals = _build(op, shape)
            flops, streamed, roundtrip = _node_cost_terms(node)
            for impl in R.candidates(backend, node):
                configs: List[Optional[Tuple[int, int, int]]] = [None]
                if impl.name.endswith("_mxu"):
                    m, k, n = shape
                    configs = list(tile_space(m, k, n, backend.hw))
                best_us, best_cfg = float("inf"), None
                for cfg in configs:
                    node.attrs.pop("mxu_block", None)
                    if cfg is not None:
                        node.attrs["mxu_block"] = cfg
                    us = _time_impl(impl, node, vals, backend, warmup, iters)
                    if us < best_us:
                        best_us, best_cfg = us, cfg
                nbytes = roundtrip if impl.memory == "roundtrip" else streamed
                cache.record(op, AT.node_shape(node), node.spec.dtype,
                             backend_name, impl.name, best_us,
                             config=best_cfg, flops=flops, nbytes=nbytes)
                tag = "x".join(str(d) for d in shape)
                derived = f"configs={len(configs)}"
                if best_cfg is not None:
                    derived += ";best=" + "x".join(str(d) for d in best_cfg)
                rows.append((f"autotune_{backend_name}_{op}_{tag}_"
                             f"{impl.name}", best_us, derived))
    return rows


def matmul_rows() -> List[Tuple[str, float, str]]:
    """The ``matmul`` benchmark table: tiled Pallas MXU matmul (interpret
    mode off-TPU) vs the einsum reference across aligned and ragged shapes,
    with max|Δ| in the derived column — the perf-trajectory data points
    BENCH_matmul.json accumulates."""
    from repro.kernels.matmul import matmul
    from repro.kernels.matmul.ref import matmul_ref

    rng = np.random.default_rng(0)
    rows: List[Tuple[str, float, str]] = []
    for m, k, n in ((128, 128, 128), (96, 80, 56), (64, 256, 128)):
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        ref = jax.jit(matmul_ref)
        t_ref = _time(lambda: ref(x, w), warmup=2, iters=5)
        t_mxu = _time(lambda: matmul(x, w, interpret=True),
                      warmup=2, iters=5)
        err = float(jnp.abs(matmul(x, w, interpret=True)
                            - matmul_ref(x, w)).max())
        tag = f"matmul_{m}x{k}x{n}"
        rows.append((f"{tag}_ref_einsum", t_ref, ""))
        rows.append((f"{tag}_pallas_mxu_interpret", t_mxu,
                     f"max_abs_err={err:.2e}"))
    return rows


def csv_rows() -> List[Tuple[str, float, str]]:
    """The ``autotune`` benchmark table: a tiny sweep on the pallas_interpret
    and host_cpu backends.  Uses a local cache so a benchmark run never
    perturbs the process-wide election state of the other tables."""
    from repro.core.autotune import AutotuneCache
    cache = AutotuneCache()
    rows = []
    for backend in ("pallas_interpret", "host_cpu"):
        rows += tune(backend, tiny=True, cache=cache)
    return rows


def verify_cache(path: str) -> int:
    """Reload ``path`` from disk, install it, and prove each tuned
    (backend, op) in the file yields a *measured* election on a fresh graph
    — the write → read → election round-trip CI runs after tuning."""
    from repro.backends import get_backend
    from repro.core import autotune as AT, passes
    from repro.core.ir import Graph

    cache = AT.AutotuneCache.load(path)
    if cache.stale:
        print(f"[autotune] {path} has a stale schema", file=sys.stderr)
        return 1
    if not len(cache):
        print(f"[autotune] {path} holds no measurements", file=sys.stderr)
        return 1
    groups = {}                                  # (op, dtype, backend) → bucket
    for key, bucket, _impl, _m in cache.entries():
        groups.setdefault(key, bucket)
    prev = AT.get_cache()                        # restore, don't reset: None
    AT.set_cache(cache)                          # would re-read the env var
    measured, cold = [], []
    try:
        for (op, _dtype, backend_name), bucket in sorted(groups.items()):
            try:
                backend = get_backend(backend_name)
                node = _node(op, bucket)
            except KeyError:                     # foreign backend / op kind
                continue
            g = Graph([node.inputs[0]], [node], {})
            passes.elect_implementations(g, backend)
            tag = f"{backend_name}:{op}→{node.impl}"
            if "measured" in g.election_provenance.get(node.impl, {}):
                measured.append(tag)
            else:
                cold.append(tag)
    finally:
        AT.set_cache(prev)
    print(f"[autotune] verified {path}: {len(cache)} measurements, "
          f"measured elections: {measured}")
    if cold or not measured:
        print(f"[autotune] elections that ignored the cache: {cold}",
              file=sys.stderr)
        return 1
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", action="append",
                    help="backend(s) to tune (default: pallas_interpret)")
    ap.add_argument("--ops", nargs="*", default=["matmul", "linear"])
    ap.add_argument("--cache", default="results/autotune_cache.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny shapes, few iterations")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--verify", action="store_true",
                    help="after saving, reload the cache from disk and "
                         "assert a measured election")
    args = ap.parse_args()

    from repro.core import autotune as AT
    cache = AT.AutotuneCache.load(args.cache)   # merge into prior runs
    rows: List[Tuple[str, float, str]] = []
    for backend in args.backend or ["pallas_interpret"]:
        rows += tune(backend, args.ops, tiny=args.tiny,
                     warmup=args.warmup, iters=args.iters, cache=cache)
    cache.save(args.cache)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"[autotune] wrote {len(cache)} measurements to {args.cache}",
          file=sys.stderr)
    if args.verify:
        return verify_cache(args.cache)
    return 0


if __name__ == "__main__":
    sys.exit(main())
