"""Autotune driver — populates the persistent timing cache the election pass
prefers over the roofline model (``core.autotune``).

For each (op, shape) in the sweep it times **every impl the dispatch table
admits** for the chosen backend; for impls that declare a ``Tunable`` (the
MXU matmul tile space, flash-attention (bq, bk) block sizes, DFP fused-group
sizing, the RG-LRU channel-block length — whatever the registry declares,
not a hard-coded list) it additionally sweeps the kernel's config search
space and records the winner's config next to its time, so a later election
can pin it on the node.

Run:  PYTHONPATH=src python -m benchmarks.autotune \\
          --backend pallas_interpret --tiny --cache autotune_cache.json --verify

``--verify`` reloads the cache from disk and re-runs the election on fresh
graphs, failing unless every tuned (backend, op) shows 'measured' provenance
— and additionally proves a cached attention block-size measurement flips an
election, with ``impl_report(provenance=True)`` surfacing the pinned config.
The write → read → election round-trip CI smokes on every commit.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .paper_tables import _time

# every tunable-kernel family the registry declares gets a sweep entry:
# (M, K, N) problems for the matmul family, output shapes for the rest;
# --tiny keeps CI's interpret-mode runs quick
SHAPES: Dict[str, List[Tuple[int, ...]]] = {
    "matmul": [(256, 256, 256), (512, 512, 512), (128, 512, 256)],
    "linear": [(32, 1024, 1024), (8, 4096, 512)],
    "attention": [(2, 256, 4, 64), (1, 512, 8, 64)],
    "fused": [(1024, 512), (4096, 256)],
    "rglru_scan": [(2, 128, 256), (1, 256, 512)],
    "rwkv6_scan": [(1, 128, 4, 32)],
    "avgpool": [(2, 64, 62, 62)],
}
TINY_SHAPES: Dict[str, List[Tuple[int, ...]]] = {
    "matmul": [(32, 32, 32), (16, 48, 24)],
    "linear": [(8, 64, 32)],
    "attention": [(1, 64, 2, 16)],
    "fused": [(64, 32)],
    "rglru_scan": [(1, 16, 32)],
    "rwkv6_scan": [(1, 16, 2, 8)],
    "avgpool": [(1, 8, 10, 10)],
}
DEFAULT_OPS = ("matmul", "linear", "attention", "fused", "rglru_scan",
               "rwkv6_scan", "avgpool")


def _node(op: str, shape: Tuple[int, ...]):
    """One dispatchable node for an (op, shape) problem — also used by
    ``verify_cache`` to rebuild a node from a cache bucket."""
    from repro.core import ir
    from repro.core.ir import Node, OpKind, TensorSpec
    if op == "matmul":
        m, k, n = shape
        return Node(OpKind.MATMUL,
                    [ir.input_node((m, k)), ir.input_node((k, n))],
                    TensorSpec((m, n)))
    if op == "linear":
        m, k, n = shape
        return Node(OpKind.LINEAR,
                    [ir.input_node((m, k)), ir.param_node((n, k), name="w")],
                    TensorSpec((m, n)), attrs={"out_features": n})
    if op == "attention":
        b, s, h, hd = shape
        qkv = [ir.input_node((b, s, h, hd), name=nm) for nm in "qkv"]
        return Node(OpKind.ATTENTION, qkv, TensorSpec((b, s, h, hd)),
                    attrs={"causal": True})
    if op == "rglru_scan":
        b, t, d = shape
        return Node(OpKind.RGLRU_SCAN,
                    [ir.input_node((b, t, d), name="a"),
                     ir.input_node((b, t, d), name="b"),
                     ir.input_node((b, d), name="h0")],
                    TensorSpec((b, t, d)))
    if op == "fused":
        # a representative DFP chain: gelu → residual add → tanh → scale
        rows, d = shape
        x = ir.input_node((rows, d), name="x")
        spec = TensorSpec((rows, d))
        g = Node(OpKind.GELU, [x], spec)
        a = Node(OpKind.ADD, [g, x], spec)
        t = Node(OpKind.TANH, [a], spec)
        sc = Node(OpKind.SCALE, [t], spec, attrs={"value": 1.3})
        return Node(OpKind.FUSED, [x], spec, attrs={"length": 4},
                    name="fused[gelu+add+tanh+scale]", body=[g, a, t, sc])
    if op == "rwkv6_scan":
        b, t, h, hd = shape
        seq = [(b, t, h, hd)] * 4
        ins = ([ir.input_node(s, name=nm) for s, nm in zip(seq, "rkvw")]
               + [ir.input_node((h, hd), name="u"),
                  ir.input_node((b, h, hd, hd), name="s0")])
        return Node(OpKind.RWKV6_SCAN, ins, TensorSpec((b, t, h, hd)))
    if op == "avgpool":
        # shape is the pooled OUTPUT (what the cache keys on); 3×3 VALID
        n, c, oh, ow = shape
        return Node(OpKind.AVGPOOL,
                    [ir.input_node((n, c, oh + 2, ow + 2), name="x")],
                    TensorSpec((n, c, oh, ow)),
                    attrs={"kernel": 3, "stride": 1})
    raise KeyError(f"unknown autotune op {op!r}")


def _build(op: str, shape: Tuple[int, ...]):
    """The node plus concrete operand arrays to time it with."""
    rng = np.random.default_rng(0)
    node = _node(op, shape)

    def arr(shp, scale=1.0):
        return jnp.asarray(rng.standard_normal(shp) * scale, jnp.float32)

    if op in ("matmul", "linear"):
        m, k, n = shape
        w_shape = (k, n) if op == "matmul" else (n, k)  # linear stores (o,i)
        return node, [arr((m, k)), arr(w_shape)]
    if op == "attention":
        b, s, h, hd = shape
        return node, [arr((b, s, h, hd)) for _ in range(3)]
    if op == "rglru_scan":
        b, t, d = shape
        a = jnp.asarray(1.0 / (1.0 + np.exp(-rng.standard_normal((b, t, d)))),
                        jnp.float32)
        return node, [a, arr((b, t, d), 0.1), arr((b, d), 0.1)]
    if op == "fused":
        return node, [arr(shape)]
    if op == "rwkv6_scan":
        b, t, h, hd = shape
        logw = jnp.asarray(-np.exp(rng.standard_normal((b, t, h, hd)) * 0.5),
                           jnp.float32)
        return node, [arr((b, t, h, hd), 0.5), arr((b, t, h, hd), 0.5),
                      arr((b, t, h, hd), 0.5), logw, arr((h, hd), 0.3),
                      jnp.zeros((b, h, hd, hd), jnp.float32)]
    if op == "avgpool":
        n, c, oh, ow = shape
        return node, [arr((n, c, oh + 2, ow + 2))]
    raise KeyError(f"unknown autotune op {op!r}")


def tune(backend_name: str = "pallas_interpret",
         ops: Sequence[str] = DEFAULT_OPS, *,
         tiny: bool = False, warmup: int = 2, iters: int = 5,
         cache=None, grads: bool = True) -> List[Tuple[str, float, str]]:
    """Measure every admissible impl of each (op, shape) through the dispatch
    table — sweeping each impl's declared ``Tunable`` config space — and
    record best times (plus winning configs) into ``cache``.  Returns
    benchmark rows for the CSV/JSON harness.

    Backward impls are swept alongside the forwards (``grads=True``): each
    family's registered gradient kernels go through their own ``Tunable``
    spaces and land under the ``_bwd``-suffixed cache op key
    (``registry.grad_cache_op``), which the training-mode election
    (``passes.elect_grad_implementations``) reads.

    The per-node sweeps live in ``repro.core.measure`` (``sweep_node`` /
    ``sweep_node_grad``) and are shared with the serving and training
    warmups, so the measurement paths cannot drift."""
    from repro.backends import get_backend
    from repro.core import autotune as AT
    from repro.core.measure import sweep_node, sweep_node_grad

    backend = get_backend(backend_name)
    cache = cache if cache is not None else AT.get_cache()
    rows: List[Tuple[str, float, str]] = []
    shapes = TINY_SHAPES if tiny else SHAPES
    for op in ops:
        for shape in shapes[op]:
            node, vals = _build(op, shape)
            tag = "x".join(str(d) for d in shape)
            for m in sweep_node(node, vals, backend, cache,
                                warmup=warmup, iters=iters):
                derived = f"configs={m.n_configs}"
                if m.config is not None:
                    derived += ";best=" + "x".join(str(d) for d in m.config)
                rows.append((f"autotune_{backend_name}_{op}_{tag}_"
                             f"{m.impl}", m.us, derived))
            if not grads:
                continue
            for m in sweep_node_grad(node, vals, backend, cache,
                                     warmup=warmup, iters=iters):
                derived = f"configs={m.n_configs}"
                if m.config is not None:
                    derived += ";best=" + "x".join(str(d) for d in m.config)
                rows.append((f"autotune_{backend_name}_{op}_bwd_{tag}_"
                             f"{m.impl}", m.us, derived))
    return rows


def refine_plan(cache, backend_name: str, *, top_k: int = 4,
                rounds: int = 3, budget: int = 32, min_gain: float = 0.05,
                rewrite_ratio: float = 10.0, warmup: int = 1,
                iters: int = 3, measure=None) -> List[dict]:
    """Gap-driven tuning planner (ISSUE tentpole): instead of sweeping every
    family's full config space uniformly, rank the cache's
    (op, bucket, backend) cells by SOL gap (``core.sol``) and spend the
    measurement ``budget`` where the gap is worst.

    For each of the ``top_k`` worst cells whose winning impl declares a
    ``Tunable``, probe the winner's ``refine_space`` neighborhood —
    adjacent tile/block sizes, typically OUTSIDE the initially declared
    space — for up to ``rounds`` rounds, re-centering on each improvement
    and stopping early when a round fails to close the gap by ``min_gain``
    (relative).  Improvements are recorded back into ``cache`` (the cache
    keeps the best time per impl, so a later election pins the refined
    config).  Cells whose ratio stays above ``rewrite_ratio`` after
    refinement — or whose impl has nothing to tune — are flagged
    ``rewrite_candidate``: no config in this family's neighborhood reaches
    the hardware limit, the kernel itself needs work.

    ``measure(node, vals, backend, impl, configs)`` is injectable for
    tests; the default measures for real through
    ``core.measure.measure_impl_configs`` (per-config errors are skipped —
    probing outside a declared space must never abort the plan).

    Returns one report dict per examined cell."""
    from repro.backends import get_backend
    from repro.backends import registry as R
    from repro.core import sol as SOL
    from repro.core.measure import measure_impl_configs
    from repro.core.passes import _node_cost_terms

    backend = get_backend(backend_name)
    hw = backend.hw

    if measure is None:
        def measure(node, vals, bk, impl, configs):
            return measure_impl_configs(node, vals, bk, impl, configs,
                                        warmup=warmup, iters=iters,
                                        skip_errors=True)

    cells = [r for r in SOL.rank(SOL.cache_rows(
        cache, backends=[backend_name], best_only=True)) if r.ratio > 0.0]
    reports: List[dict] = []
    for row in cells[:top_k]:
        rep = {"op": row.op, "bucket": row.bucket, "dtype": row.dtype,
               "backend": row.backend, "impl": row.impl,
               "before_us": row.us, "before_ratio": row.ratio,
               "after_us": row.us, "after_ratio": row.ratio,
               "bound_us": row.bound_us, "rounds": 0,
               "configs_measured": 0, "config": row.config,
               "refined_impl": None, "outside_space": False,
               "rewrite_candidate": False, "note": ""}
        reports.append(rep)
        # the refinement target is the cell's fastest impl that HAS a tuned
        # config space — usually the elected winner, but when an untunable
        # reference impl currently wins the cell, expanding the tunable
        # family's neighborhood is exactly what might flip the election
        target_impl, target_m = None, None
        for impl_name, m in cache.lookup(row.op, row.bucket, row.dtype,
                                         backend_name).items():
            impl = R.get_impl(impl_name)
            if impl is None or impl.tunable is None or m.config is None:
                continue
            if target_m is None or m.us < target_m.us:
                target_impl, target_m = impl, m
        if target_impl is None:
            rep["note"] = "nothing to refine (no impl with a tuned config)"
            rep["rewrite_candidate"] = row.ratio > rewrite_ratio
            continue
        try:
            node, vals = _build(row.op, row.bucket)
        except KeyError:
            rep["note"] = f"no synthetic builder for op {row.op!r}"
            rep["rewrite_candidate"] = row.ratio > rewrite_ratio
            continue
        rep["refined_impl"] = target_impl.name
        tun = target_impl.tunable
        flops, streamed, roundtrip = _node_cost_terms(node)
        nbytes = roundtrip if target_impl.memory == "roundtrip" else streamed
        initial_space = set(tun.tune_space(node, hw))
        seen = initial_space | {tuple(target_m.config)}
        cur_us, cur_cfg = target_m.us, tuple(target_m.config)
        for _round in range(rounds):
            if budget <= 0:
                rep["note"] = "budget exhausted"
                break
            cfgs = [c for c in tun.refine_space(node, hw, cur_cfg)
                    if c not in seen][:budget]
            if not cfgs:
                rep["note"] = rep["note"] or "neighborhood exhausted"
                break
            budget -= len(cfgs)
            seen |= set(cfgs)
            results = [r for r in measure(node, vals, backend,
                                          target_impl, cfgs)
                       if r.error is None]
            rep["configs_measured"] += len(cfgs)
            rep["rounds"] += 1
            if not results:
                break
            best = min(results, key=lambda r: r.us)
            if best.us < cur_us * (1.0 - min_gain):
                cur_us, cur_cfg = best.us, tuple(best.config)
                cache.record(row.op, row.bucket, row.dtype, backend_name,
                             target_impl.name, cur_us, config=cur_cfg,
                             flops=flops, nbytes=nbytes,
                             mean_us=best.mean_us)
            else:
                break                     # the gap stopped closing
        rep["config"] = cur_cfg
        # the cell's post-refinement election: the refined family wins only
        # if it now beats the previous cell winner
        rep["after_us"] = min(cur_us, row.us)
        if cur_us < row.us:
            rep["impl"] = target_impl.name
        rep["after_ratio"] = SOL.sol_ratio(rep["after_us"], row.bound_us)
        rep["outside_space"] = cur_cfg not in initial_space
        rep["rewrite_candidate"] = rep["after_ratio"] > rewrite_ratio
    return reports


def _plan_row(rep: dict) -> Tuple[str, float, str]:
    bucket = "x".join(str(d) for d in rep["bucket"])
    cfg = "x".join(str(d) for d in rep["config"]) if rep["config"] else "-"
    derived = (f"ratio={rep['before_ratio']:.2f}->{rep['after_ratio']:.2f};"
               f"cfg={cfg};outside_space={rep['outside_space']};"
               f"rewrite={rep['rewrite_candidate']};rounds={rep['rounds']};"
               f"measured={rep['configs_measured']}")
    if rep["note"]:
        derived += f";note={rep['note']}"
    return (f"sol_refine_{rep['backend']}_{rep['op']}_{bucket}",
            rep["after_us"], derived)


def sol_rows(backends: Sequence[str] = ("pallas_interpret", "host_cpu"),
             ) -> List[Tuple[str, float, str]]:
    """The ``sol`` benchmark table: tune every Tunable family (tiny
    shapes), run the gap-driven refinement planner on each backend's worst
    cells, then rank every elected kernel by measured ÷ roofline-bound.
    Renders the ranked SOL table to stderr and returns the CSV/JSON rows
    (``BENCH_sol.json``) — SOL cells first, then one ``sol_refine_*`` row
    per planner cell recording whether refinement elected a config outside
    the initially declared ``tune_space``."""
    from repro.core import sol as SOL
    from repro.core.autotune import AutotuneCache

    cache = AutotuneCache()
    for backend in backends:
        tune(backend, tiny=True, cache=cache)
    plan_reports = []
    for backend in backends:
        plan_reports += refine_plan(cache, backend, top_k=3, rounds=2,
                                    budget=24, iters=3)
    ranked = SOL.rank(SOL.cache_rows(cache, best_only=True))
    print(SOL.render(ranked), file=sys.stderr)
    rows: List[Tuple[str, float, str]] = []
    for r in ranked:
        bucket = "x".join(str(d) for d in r.bucket)
        cfg = "x".join(str(d) for d in r.config) if r.config else "-"
        rows.append((f"sol_{r.backend}_{r.op}_{bucket}_{r.impl}", r.us,
                     f"bound_us={r.bound_us:.3f};ratio={r.ratio:.2f};"
                     f"bneck={r.bottleneck};conf={r.confidence};"
                     f"src={r.source};cfg={cfg}"))
    rows += [_plan_row(rep) for rep in plan_reports]
    wins = [rep for rep in plan_reports
            if rep["outside_space"] and rep["after_us"] < rep["before_us"]]
    print(f"[sol] planner refined {len(wins)} cell(s) to a config outside "
          f"the declared tune_space; "
          f"{sum(r['rewrite_candidate'] for r in plan_reports)} rewrite "
          f"candidate(s)", file=sys.stderr)
    return rows


def matmul_rows() -> List[Tuple[str, float, str]]:
    """The ``matmul`` benchmark table: tiled Pallas MXU matmul (interpret
    mode off-TPU) vs the einsum reference across aligned and ragged shapes,
    with max|Δ| in the derived column — the perf-trajectory data points
    BENCH_matmul.json accumulates."""
    from repro.kernels.matmul import matmul
    from repro.kernels.matmul.ref import matmul_ref

    rng = np.random.default_rng(0)
    rows: List[Tuple[str, float, str]] = []
    for m, k, n in ((128, 128, 128), (96, 80, 56), (64, 256, 128)):
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        ref = jax.jit(matmul_ref)
        t_ref = _time(lambda: ref(x, w), warmup=2, iters=5)
        t_mxu = _time(lambda: matmul(x, w, interpret=True),
                      warmup=2, iters=5)
        err = float(jnp.abs(matmul(x, w, interpret=True)
                            - matmul_ref(x, w)).max())
        tag = f"matmul_{m}x{k}x{n}"
        rows.append((f"{tag}_ref_einsum", t_ref, ""))
        rows.append((f"{tag}_pallas_mxu_interpret", t_mxu,
                     f"max_abs_err={err:.2e}"))
    return rows


def csv_rows() -> List[Tuple[str, float, str]]:
    """The ``autotune`` benchmark table: a tiny sweep of every tunable
    kernel family on the pallas_interpret and host_cpu backends.  Uses a
    local cache so a benchmark run never perturbs the process-wide election
    state of the other tables."""
    from repro.core.autotune import AutotuneCache
    cache = AutotuneCache()
    rows = []
    for backend in ("pallas_interpret", "host_cpu"):
        rows += tune(backend, tiny=True, cache=cache)
    return rows


def _doctored(cache, key, bucket: Tuple[int, ...], impl_name: str,
              us: float):
    """A copy of ``cache`` (rebuilt through the public record API) with
    ``impl_name``'s measurement in (key, bucket) forced to ``us``."""
    from repro.core import autotune as AT
    out = AT.AutotuneCache()
    for k2, b2, nm, m in cache.entries():
        t = us if (k2 == key and b2 == bucket and nm == impl_name) else m.us
        op, dtype, backend_name = k2
        out.record(op, b2, dtype, backend_name, nm, t,
                   config=m.config, flops=m.flops, nbytes=m.nbytes)
    return out


def attention_flip_proof(cache) -> int:
    """ISSUE acceptance: a cached attention block-size measurement
    demonstrably flips an election.  Elects a MultiHeadAttention model under
    two doctored caches — one where the tuned flash-attention measurement
    loses, one where it wins — asserts the elected impl changes, and that
    the winning election pins the measured (bq, bk) config on the node and
    surfaces it in ``impl_report(provenance=True)``."""
    from repro.core import autotune as AT
    from repro.core.ir import OpKind
    from repro.frontends import nn
    from repro.frontends.optimize import optimize

    target = None
    for key, bucket, nm, m in cache.entries():
        op, dtype, backend_name = key
        if op == "attention" and dtype == "float32" and m.config:
            others = [m2.us for _k, b2, nm2, m2 in cache.entries()
                      if _k == key and b2 == bucket and nm2 != nm]
            if others:
                target = (key, bucket, nm, min(others))
                break
    if target is None:
        print("[autotune] no attention bucket holds a tuned config plus a "
              "competitor to flip against", file=sys.stderr)
        return 1
    key, bucket, tuned_impl, best_other_us = target
    _op, _dtype, backend_name = key
    b, s, h, hd = bucket

    def elect(c):
        prev = AT.get_cache()
        AT.set_cache(c)
        try:
            sol = optimize(nn.MultiHeadAttention(h * hd, h), (b, s, h * hd),
                           backend=backend_name)
        finally:
            AT.set_cache(prev)
        return sol, sol.graph.nodes_of(OpKind.ATTENTION)[0]

    sol_l, node_l = elect(_doctored(cache, key, bucket, tuned_impl,
                                    2.0 * best_other_us))
    sol_w, node_w = elect(_doctored(cache, key, bucket, tuned_impl,
                                    0.5 * best_other_us))
    rep = sol_w.impl_report(provenance=True)
    pinned = rep.get(tuned_impl, {}).get("pinned", [])
    cfg = node_w.attrs.get("attn_block")
    ok = (node_l.impl != tuned_impl and node_w.impl == tuned_impl
          and rep.get(tuned_impl, {}).get("sources", {}).get("measured", 0)
          and cfg is not None and tuple(cfg) in {tuple(p) for p in pinned})
    print(f"[autotune] attention flip on {backend_name} "
          f"{'x'.join(str(d) for d in bucket)}: slow measurement elects "
          f"{node_l.impl}, fast measurement flips to {node_w.impl} with "
          f"pinned attn_block={cfg}; impl_report(provenance=True) → {rep}")
    if not ok:
        print("[autotune] attention flip proof FAILED", file=sys.stderr)
        return 1
    return 0


def verify_cache(path: str) -> int:
    """Reload ``path`` from disk, install it, and prove each tuned
    (backend, op) in the file yields a *measured* election on a fresh graph
    — plus the attention block-size flip proof above.  CI runs this after
    tuning."""
    from repro.backends import get_backend
    from repro.backends import registry as R
    from repro.core import autotune as AT, passes
    from repro.core.ir import Graph, OpKind

    cache = AT.AutotuneCache.load(path)
    if cache.stale:
        print(f"[autotune] {path} has a stale schema", file=sys.stderr)
        return 1
    if not len(cache):
        print(f"[autotune] {path} holds no measurements", file=sys.stderr)
        return 1
    groups = {}                                  # (op, dtype, backend) → bucket
    for key, bucket, _impl, _m in cache.entries():
        groups.setdefault(key, bucket)
    prev = AT.get_cache()                        # restore, don't reset: None
    AT.set_cache(cache)                          # would re-read the env var
    measured, cold = [], []
    try:
        for (op, _dtype, backend_name), bucket in sorted(groups.items()):
            # backward measurements live under the _bwd-suffixed op key;
            # verify them through the grad election on the forward node
            is_bwd = op.endswith(R.GRAD_SUFFIX)
            fwd_op = op.removesuffix(R.GRAD_SUFFIX) if is_bwd else op
            try:
                backend = get_backend(backend_name)
                node = _node(fwd_op, bucket)
            except KeyError:                     # foreign backend / op kind
                continue
            ins = [i for i in node.inputs if i.op is OpKind.INPUT]
            g = Graph(ins, [node], {})
            passes.elect_implementations(g, backend)
            if is_bwd:
                passes.elect_grad_implementations(g, backend)
                elected = node.impl_bwd
                impl = R.get_grad_impl(elected) if elected else None
            else:
                elected = node.impl
                impl = R.get_impl(elected)
            tag = f"{backend_name}:{op}→{elected}"
            if impl is not None and impl.tunable is not None:
                cfg = node.attrs.get(impl.tunable.attr)
                if cfg:
                    tag += f"[{impl.tunable.attr}="
                    tag += "x".join(str(d) for d in cfg) + "]"
            if elected and "measured" in g.election_provenance.get(
                    elected, {}):
                measured.append(tag)
            else:
                cold.append(tag)
    finally:
        AT.set_cache(prev)
    print(f"[autotune] verified {path}: {len(cache)} measurements, "
          f"measured elections: {measured}")
    if cold or not measured:
        print(f"[autotune] elections that ignored the cache: {cold}",
              file=sys.stderr)
        return 1
    return attention_flip_proof(cache)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", action="append",
                    help="backend(s) to tune (default: pallas_interpret)")
    ap.add_argument("--ops", nargs="*", default=list(DEFAULT_OPS))
    ap.add_argument("--cache", default="results/autotune_cache.json")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny shapes, few iterations")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--verify", action="store_true",
                    help="after saving, reload the cache from disk and "
                         "assert measured elections + the attention flip")
    args = ap.parse_args()

    from repro.core import autotune as AT
    cache = AT.AutotuneCache.load(args.cache)   # merge into prior runs
    rows: List[Tuple[str, float, str]] = []
    for backend in args.backend or ["pallas_interpret"]:
        rows += tune(backend, args.ops, tiny=args.tiny,
                     warmup=args.warmup, iters=args.iters, cache=cache)
    cache.save(args.cache)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"[autotune] wrote {len(cache)} measurements to {args.cache}",
          file=sys.stderr)
    if args.verify:
        return verify_cache(args.cache)
    return 0


if __name__ == "__main__":
    sys.exit(main())
