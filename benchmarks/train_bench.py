"""Training-step benchmark: fwd-only vs fwd+bwd through elected graphs.

One row pair per model-zoo family: the jitted forward of the
``optimize(training=True)`` executable, and ``value_and_grad`` of an MSE
loss through the same executable — every grad-registered node runs its
elected backward impl via the per-node ``custom_vjp`` wrappers.  The
``ratio`` derived column (fwd+bwd ÷ fwd) is the number to watch: a backward
kernel regression shows up as ratio drift even when the forward is stable.

Rows land in ``BENCH_train.json`` (``benchmarks/run.py train``) and ride
the same ``tools/bench_diff.py`` CI gate as the other perf series.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):
    import os
    import sys
    _here = os.path.dirname(os.path.abspath(__file__))
    _root = os.path.dirname(_here)
    for p in (_root, os.path.join(_root, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

B, S, D = 2, 32, 64
BACKEND = "xla"      # CI tracks step-time trajectory; the kernel-level
                     # sweeps live in the autotune table


def _families():
    from repro.frontends import nn
    return [("transformer", lambda: nn.transformer_block(d_model=D,
                                                         n_heads=4)),
            ("griffin", lambda: nn.griffin_block(d_model=D)),
            ("rwkv6", lambda: nn.rwkv6_block(d_model=D))]


def csv_rows(warmup: int = 2, iters: int = 5) -> List[Tuple[str, float, str]]:
    from repro.core.measure import time_call
    from repro.frontends.optimize import optimize

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    rows: List[Tuple[str, float, str]] = []
    for name, build in _families():
        sm = optimize(build(), (B, S, D), backend=BACKEND, training=True)
        params = sm._params_for_call()
        n_bwd = sum(count
                    for kind, impls in sm.impl_report(by_kind=True).items()
                    if kind.endswith("_bwd")
                    for count in impls.values())

        fwd = jax.jit(sm._fn)
        t_fwd = time_call(lambda: fwd(params, x), warmup, iters)

        def loss(p):
            return ((sm._fn(p, x).astype(jnp.float32) - y) ** 2).mean()

        step = jax.jit(jax.value_and_grad(loss))
        t_bwd = time_call(lambda: step(params), warmup, iters)
        rows.append((f"train_{name}_fwd", t_fwd, f"bwd_nodes={n_bwd}"))
        rows.append((f"train_{name}_fwdbwd", t_bwd,
                     f"ratio={t_bwd / max(t_fwd, 1e-9):.2f}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for n, us, d in csv_rows():
        print(f"{n},{us:.1f},{d}")
