"""Sequence models through the full SOL pipeline (ISSUE 2 acceptance):
transformer / Griffin / RWKV6 blocks built from ``frontends.nn`` extract as
graphs containing ATTENTION / RGLRU_SCAN / RWKV6_SCAN nodes, the election
pass picks the Pallas flavours where capabilities allow, and the optimized
executable matches framework-eager execution to 1e-5 on every backend."""
from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax.numpy as jnp
import numpy as np
import pytest

from repro.frontends import nn
from repro.frontends.extract import (UnsupportedModuleError, extract,
                                     registered_emitters)
from repro.frontends.optimize import optimize
from repro.core.ir import OpKind

BACKENDS = ("xla", "host_cpu", "pallas_interpret")

BLOCKS = [
    ("transformer", lambda: nn.transformer_block(32, 4), (2, 16, 32),
     OpKind.ATTENTION, "pallas.flash_attention", "ref.attention"),
    ("griffin", lambda: nn.griffin_block(24), (2, 16, 24),
     OpKind.RGLRU_SCAN, "pallas.rglru_scan", "ref.rglru_scan"),
    ("rwkv6", lambda: nn.rwkv6_block(32, 4), (2, 32, 32),
     OpKind.RWKV6_SCAN, "pallas.rwkv6_scan", "ref.rwkv6_scan"),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,builder,shape,kind,pallas_impl,ref_impl",
                         BLOCKS, ids=[b[0] for b in BLOCKS])
def test_sequence_block_parity(name, builder, shape, kind, pallas_impl,
                               ref_impl, backend):
    """Eager (models/ functions) vs optimize()d output to 1e-5, and the
    per-OpKind election lands on the Pallas kernel iff capabilities allow."""
    model = builder()
    x = np.random.default_rng(7).standard_normal(shape).astype(np.float32)
    y_ref = np.asarray(model(jnp.asarray(x)))
    sol = optimize(model, shape, backend=backend)
    y = np.asarray(sol(x))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)

    kinds = [n.op for n in sol.graph.topo()]
    assert kind in kinds, f"{kind} missing from extracted graph"

    report = sol.impl_report(by_kind=True)
    elected = report[kind.value]
    want = pallas_impl if backend == "pallas_interpret" else ref_impl
    assert elected == {want: 1}, elected


def test_attention_variants_parity():
    """GQA + sliding window + softcap flow through the ATTENTION attrs."""
    model = nn.Sequential(
        nn.MultiHeadAttention(32, 4, n_kv_heads=2, window=8, cap=30.0))
    shape = (2, 16, 32)
    x = np.random.default_rng(1).standard_normal(shape).astype(np.float32)
    y_ref = np.asarray(model(jnp.asarray(x)))
    for backend in BACKENDS:
        y = np.asarray(optimize(model, shape, backend=backend)(x))
        np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_impl_report_by_kind_consistent_with_flat():
    sol = optimize(nn.transformer_block(32, 4), (2, 16, 32), backend="xla")
    flat = sol.impl_report()
    by_kind = sol.impl_report(by_kind=True)
    refolded = {}
    for impls in by_kind.values():
        for impl, c in impls.items():
            refolded[impl] = refolded.get(impl, 0) + c
    assert refolded == flat
    assert "matmul" in by_kind          # q/k/v/o projections
    assert "attention" in by_kind


def test_residual_and_nested_sequential_extract():
    """Containers recurse: the residual ADD is a genuine multi-input node
    and nested Sequential (previously a TypeError) extracts flat names."""
    model = nn.Sequential(
        nn.Sequential(nn.Linear(16, 16), nn.ReLU()),
        nn.Residual(nn.LayerNorm(16), nn.Linear(16, 16)),
    )
    g = extract(model, (2, 16))
    assert "0.0.weight" in g.params and "1.1.weight" in g.params
    adds = g.nodes_of(OpKind.ADD)
    assert adds, "residual ADD missing"
    skip_inputs = adds[-1].inputs
    assert len(skip_inputs) == 2 and skip_inputs[0] is not skip_inputs[1]

    x = np.random.default_rng(2).standard_normal((2, 16)).astype(np.float32)
    y_ref = np.asarray(model(jnp.asarray(x)))
    y = np.asarray(optimize(model, (2, 16))(x))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_unsupported_module_error_names_registry_and_path():
    class Mystery(nn.Module):
        def forward(self, x):
            return x

    model = nn.Sequential(nn.Linear(8, 8), nn.Sequential(Mystery()))
    with pytest.raises(UnsupportedModuleError) as ei:
        extract(model, (1, 8))
    msg = str(ei.value)
    assert "Mystery" in msg
    assert "1.0" in msg                      # path of the offender
    assert "MultiHeadAttention" in msg       # registry listing
    assert "register_emitter" in msg         # the fix, one message away


def test_registered_emitters_cover_sequence_layers():
    names = registered_emitters()
    for expect in ("MultiHeadAttention", "RGLRU", "RWKV6TimeMix",
                   "Residual", "Sequential", "Linear", "Conv2d"):
        assert expect in names


def test_sequence_ops_are_fusion_barriers():
    """ATTENTION / scans never end up inside a FUSED body."""
    for _, builder, shape, kind, _, _ in BLOCKS:
        sol = optimize(builder(), shape, backend="pallas_interpret")
        for n in sol.graph.topo():
            if n.op is OpKind.FUSED:
                assert all(b.op not in
                           (OpKind.ATTENTION, OpKind.RGLRU_SCAN,
                            OpKind.RWKV6_SCAN) for b in n.body)
        assert any(n.op is kind for n in sol.graph.topo())


_ZOO = st.sampled_from(["linear", "relu", "gelu", "ln", "res_mlp",
                        "attention", "rglru"])


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(layers=st.lists(_ZOO, min_size=1, max_size=4),
                  seed=st.integers(0, 1000))
def test_extractor_roundtrip_random_zoo(layers, seed):
    """Property: random module zoos (mixing chains, residual containers and
    sequence layers) extract, validate, optimize and match eager."""
    d, s = 16, 8
    mods = []
    for l in layers:
        if l == "linear":
            mods.append(nn.Linear(d, d))
        elif l == "relu":
            mods.append(nn.ReLU())
        elif l == "gelu":
            mods.append(nn.GELU())
        elif l == "ln":
            mods.append(nn.LayerNorm(d))
        elif l == "res_mlp":
            mods.append(nn.Residual(nn.LayerNorm(d), nn.Linear(d, d)))
        elif l == "attention":
            mods.append(nn.Residual(nn.MultiHeadAttention(d, 2)))
        else:
            mods.append(nn.RGLRU(d))
    model = nn.Sequential(*mods)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, s, d)).astype(np.float32)
    y_ref = np.asarray(model(jnp.asarray(x)))
    g = extract(model, (2, s, d))
    g.validate()
    sol = optimize(model, (2, s, d))
    np.testing.assert_allclose(np.asarray(sol(x)), y_ref,
                               rtol=1e-4, atol=1e-4)
