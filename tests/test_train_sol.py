"""Training through the SOL pipeline vs plain JAX AD on the same model.

The tentpole guarantee: differentiating an elected graph — every
grad-registered node a ``custom_vjp`` pairing its elected forward with its
elected backward — is numerically the *same training run* as eager JAX AD
through the framework module.  The parity test trains both paths from
identical weights on identical data and pins the loss curves together
step-for-step at 1e-4, with the final parameters matching too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.steps import StepOptions, make_sol_train_step
from repro.frontends import nn
from repro.frontends.optimize import optimize
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


B, S, D = 2, 16, 32
STEPS = 8


def _data():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    return x, y


def _mse(out, y):
    return ((out.astype(jnp.float32) - y) ** 2).mean()


def _train(step_fn, state, x, y, steps=STEPS):
    jitted = jax.jit(step_fn)
    losses = []
    for _ in range(steps):
        state, metrics = jitted(state, {"x": x, "y": y})
        losses.append(float(metrics["loss"]))
    return losses, state


def test_sol_training_matches_eager_jax_ad():
    """`optimize(training=True)` + make_sol_train_step reproduces the eager
    value_and_grad/AdamW run of the same module, step for step."""
    model = nn.transformer_block(d_model=D, n_heads=2)
    sd0 = {k: np.asarray(v) for k, v in model.state_dict().items()}
    sm = optimize(model, (B, S, D), backend="xla", training=True)

    # the graph must actually be differentiable THROUGH elected backwards
    assert any(k.endswith("_bwd") for k in sm.impl_report(by_kind=True)), \
        "training=True graph recorded no backward elections"

    opts = StepOptions(lr=1e-2, warmup=2, total_steps=STEPS, zero=False)
    x, y = _data()

    sol_step, sol_init = make_sol_train_step(sm, opts)
    sol_losses, sol_state = _train(sol_step, sol_init(), x, y)

    # eager twin: a second instance of the same architecture, same weights,
    # differentiated by plain JAX AD (no SOL pipeline anywhere)
    twin = nn.transformer_block(d_model=D, n_heads=2)
    twin.load_state_dict(sd0)
    ocfg = AdamWConfig(lr=opts.lr)

    def eager_loss(params, batch):
        twin.load_state_dict(params)        # tracer-safe raw assignment
        return _mse(twin(batch["x"]), batch["y"].astype(jnp.float32))

    def eager_step(state, batch):
        lval, grads = jax.value_and_grad(eager_loss)(state["params"], batch)
        lr = cosine_schedule(state["step"], peak_lr=opts.lr,
                             warmup=opts.warmup, total=opts.total_steps)
        new_p, new_opt, om = adamw_update(state["params"], grads,
                                          state["opt"], ocfg, lr)
        return ({"params": new_p, "opt": new_opt,
                 "step": state["step"] + 1},
                {"loss": lval, "lr": lr, **om})

    from repro.optim import init_opt_state
    p0 = {k: jnp.asarray(sd0[k]) for k in sm.graph.params}
    eager_state = {"params": p0, "opt": init_opt_state(p0, ocfg),
                   "step": jnp.zeros((), jnp.int32)}
    eager_losses, eager_state = _train(eager_step, eager_state, x, y)

    np.testing.assert_allclose(sol_losses, eager_losses, rtol=1e-4,
                               atol=1e-4)
    assert sol_losses[-1] < sol_losses[0], "loss did not improve"
    for k in sorted(sm.graph.params):
        np.testing.assert_allclose(
            np.asarray(sol_state["params"][k]),
            np.asarray(eager_state["params"][k]),
            rtol=1e-3, atol=1e-4, err_msg=f"param {k} diverged")


def test_sol_training_griffin_matches_eager():
    """Same parity through the recurrence family (RG-LRU backward rides the
    reverse-scan impl, not JAX AD)."""
    model = nn.griffin_block(d_model=D)
    sd0 = {k: np.asarray(v) for k, v in model.state_dict().items()}
    sm = optimize(model, (B, S, D), backend="pallas_interpret",
                  training=True)
    by_kind = sm.impl_report(by_kind=True)
    assert "rglru_scan_bwd" in by_kind
    assert all(not n.startswith("ref.") for n in by_kind["rglru_scan_bwd"])

    opts = StepOptions(lr=1e-2, warmup=1, total_steps=4, zero=False)
    x, y = _data()
    sol_step, sol_init = make_sol_train_step(sm, opts)
    sol_losses, _ = _train(sol_step, sol_init(), x, y, steps=4)

    twin = nn.griffin_block(d_model=D)
    twin.load_state_dict(sd0)
    ocfg = AdamWConfig(lr=opts.lr)

    def eager_loss(params, batch):
        twin.load_state_dict(params)
        return _mse(twin(batch["x"]), batch["y"].astype(jnp.float32))

    def eager_step(state, batch):
        lval, grads = jax.value_and_grad(eager_loss)(state["params"], batch)
        lr = cosine_schedule(state["step"], peak_lr=opts.lr,
                             warmup=opts.warmup, total=opts.total_steps)
        new_p, new_opt, _ = adamw_update(state["params"], grads,
                                         state["opt"], ocfg, lr)
        return ({"params": new_p, "opt": new_opt,
                 "step": state["step"] + 1}, {"loss": lval})

    from repro.optim import init_opt_state
    p0 = {k: jnp.asarray(sd0[k]) for k in sm.graph.params}
    state = {"params": p0, "opt": init_opt_state(p0, ocfg),
             "step": jnp.zeros((), jnp.int32)}
    eager_losses, _ = _train(eager_step, state, x, y, steps=4)
    np.testing.assert_allclose(sol_losses, eager_losses, rtol=1e-4,
                               atol=1e-4)


def test_mesh_training_grads_are_psum_correct():
    """Differentiating a mesh-compiled graph: the per-shard custom_vjp
    wrappers sit INSIDE shard_map while the row-parallel psums stay outside
    them, so JAX AD transposes the collectives — gradients must match the
    single-device run."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under "
                    "--xla_force_host_platform_device_count)")
    from repro.launch.mesh import make_debug_mesh
    model = nn.transformer_block(d_model=D, n_heads=2)
    sd0 = {k: np.asarray(v) for k, v in model.state_dict().items()}
    x, y = _data()

    flat = optimize(model, (B, S, D), backend="xla", training=True)
    mesh = make_debug_mesh(1, 2)
    meshed = optimize(model, (B, S, D), backend="xla", training=True,
                      mesh=mesh)

    def loss_of(sm):
        params = {k: jnp.asarray(sd0[k]) for k in sm.graph.params}
        def f(p):
            return _mse(sm._fn(p, x), y)
        return jax.grad(f)(params)

    g_flat, g_mesh = loss_of(flat), loss_of(meshed)
    for k in sorted(g_flat):
        np.testing.assert_allclose(np.asarray(g_mesh[k]),
                                   np.asarray(g_flat[k]),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"grad {k} diverged on mesh")
