"""SOL compiler unit + property tests: IR invariants, the paper's
high-level optimizations, module assignment, fusion-group formation."""
from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.core import ir, passes
from repro.core.executor import lower_graph
from repro.core.ir import Graph, Module, Node, OpKind, TensorSpec


def _chain_graph(ops):
    """Build input -> op chain -> output graph from OpKind list."""
    x = ir.input_node((4, 8, 8, 8), dims=(), name="x")  # NCHW-ish rank 4
    cur = x
    params = {}
    for i, op in enumerate(ops):
        if op is OpKind.MAXPOOL or op is OpKind.AVGPOOL:
            s = cur.spec.shape
            spec = TensorSpec((s[0], s[1], s[2] // 2, s[3] // 2))
            cur = Node(op, [cur], spec, attrs={"kernel": 2, "stride": 2})
        else:
            cur = Node(op, [cur], cur.spec)
    return Graph(inputs=[x], outputs=[cur], params=params)


def test_relu_maxpool_fold_forward():
    g = _chain_graph([OpKind.RELU, OpKind.MAXPOOL])
    passes.simplify(g)
    kinds = [n.op for n in g.topo()]
    assert OpKind.RELU not in kinds
    pool = g.nodes_of(OpKind.MAXPOOL)[0]
    assert pool.attrs["min_value"] == 0.0


def test_relu_maxpool_fold_backward():
    g = _chain_graph([OpKind.MAXPOOL, OpKind.RELU])
    passes.simplify(g)
    assert OpKind.RELU not in [n.op for n in g.topo()]


def test_fold_preserves_semantics():
    backend = get_backend("xla")
    for order in ([OpKind.RELU, OpKind.MAXPOOL], [OpKind.MAXPOOL, OpKind.RELU]):
        g = _chain_graph(order)
        ref_fn = lower_graph(g, backend)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8, 8))
        y_ref = ref_fn({}, x)
        g2 = _chain_graph(order)
        g2 = passes.run_pipeline(g2, backend)
        y_opt = lower_graph(g2, backend)({}, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_opt),
                                   rtol=1e-6)


def test_module_assignment_paper_rules():
    x = ir.input_node((1, 8, 8, 8))
    w = ir.param_node((8, 1, 3, 3))
    conv_dw = Node(OpKind.CONV2D, [x, w], TensorSpec((1, 8, 6, 6)),
                   attrs={"groups": 8, "out_channels": 8})
    conv = Node(OpKind.CONV2D, [conv_dw, ir.param_node((4, 8, 3, 3))],
                TensorSpec((1, 4, 4, 4)),
                attrs={"groups": 1, "out_channels": 4})
    relu = Node(OpKind.RELU, [conv], conv.spec)
    g = Graph([x], [relu], {})
    passes.assign_modules(g)
    # depthwise conv (groups == out_channels) → DFP (WeightedPooling case)
    assert conv_dw.module is Module.DFP
    assert conv_dw.attrs.get("as_weighted_pool")
    assert conv.module is Module.DNN
    assert relu.module is Module.DFP


def test_fusion_groups_formed():
    g = _chain_graph([OpKind.RELU, OpKind.TANH, OpKind.EXP])
    passes.assign_modules(g)
    passes.form_fusion_groups(g)
    fused = g.nodes_of(OpKind.FUSED)
    assert len(fused) == 1
    assert fused[0].attrs["length"] == 3


ELEMENTWISE = [OpKind.RELU, OpKind.GELU, OpKind.SILU, OpKind.TANH,
               OpKind.SIGMOID, OpKind.EXP]


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    ops=st.lists(st.sampled_from(ELEMENTWISE + [OpKind.MAXPOOL]),
                 min_size=1, max_size=6),
    seed=st.integers(0, 2 ** 16),
)
def test_pipeline_preserves_semantics_property(ops, seed):
    """Property: the full SOL pass pipeline never changes the function."""
    backend = get_backend("xla")
    # pooling halves spatial dims; cap pool count so shapes stay valid
    pools = sum(1 for o in ops if o is OpKind.MAXPOOL)
    hypothesis.assume(pools <= 2)
    g_ref = _chain_graph(ops)
    g_opt = _chain_graph(ops)
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 8, 8))
    y_ref = lower_graph(g_ref, backend)({}, x)
    g_opt = passes.run_pipeline(g_opt, backend)
    g_opt.validate()
    y_opt = lower_graph(g_opt, backend)({}, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_opt),
                               rtol=1e-5, atol=1e-5)


def test_graph_validate_rejects_cycles():
    x = ir.input_node((2, 2))
    a = Node(OpKind.RELU, [x], x.spec)
    b = Node(OpKind.TANH, [a], x.spec)
    a.inputs.append(b)  # cycle
    g = Graph([x], [b], {})
    with pytest.raises((AssertionError, RecursionError)):
        g.validate()


def test_layout_assignment_counts_reorders():
    backend = get_backend("xla")
    g = _chain_graph([OpKind.RELU])
    passes.run_pipeline(g, backend)
    assert hasattr(g, "layout_reorders")
