"""Optimizer unit tests: ``optim.adamw`` against a NumPy oracle (bias
correction, global-norm clipping, weight decay, bf16 moment storage) and
``optim.cosine_schedule`` at the edge steps (0, warmup boundary, total,
beyond-total)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_update, cosine_schedule,
                         global_norm, init_opt_state)


def _numpy_adamw(params, grads, m, v, step, ocfg, lr):
    """Straightforward NumPy re-derivation of one AdamW step (f32 math,
    moments stored back in ``ocfg.moment_dtype``)."""
    gnorm = np.sqrt(sum(np.sum(np.square(g.astype(np.float32)))
                        for g in grads.values()))
    scale = min(1.0, ocfg.grad_clip / (gnorm + 1e-9)) if ocfg.grad_clip \
        else 1.0
    c1 = 1.0 - ocfg.beta1 ** step
    c2 = 1.0 - ocfg.beta2 ** step
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        g = grads[k].astype(np.float32) * scale
        m32 = m[k].astype(np.float32) * ocfg.beta1 + (1 - ocfg.beta1) * g
        v32 = v[k].astype(np.float32) * ocfg.beta2 + (1 - ocfg.beta2) * g * g
        mh, vh = m32 / c1, v32 / c2
        delta = mh / (np.sqrt(vh) + ocfg.eps) \
            + ocfg.weight_decay * params[k].astype(np.float32)
        out_p[k] = params[k].astype(np.float32) - lr * delta
        out_m[k], out_v[k] = m32, v32
    return out_p, out_m, out_v, gnorm


def _tree(rng, scale=1.0):
    return {"w": jnp.asarray(rng.standard_normal((4, 8)) * scale,
                             jnp.float32),
            "b": jnp.asarray(rng.standard_normal((8,)) * scale,
                             jnp.float32)}


def test_adamw_first_step_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    ocfg = AdamWConfig()
    params, grads = _tree(rng), _tree(rng, 0.01)   # small grads: no clipping
    state = init_opt_state(params, ocfg)
    lr = 1e-3
    new_p, new_s, metrics = adamw_update(params, grads, state, ocfg,
                                         jnp.float32(lr))
    m0 = {k: np.zeros_like(np.asarray(v)) for k, v in params.items()}
    ref_p, ref_m, ref_v, ref_gnorm = _numpy_adamw(
        {k: np.asarray(v) for k, v in params.items()},
        {k: np.asarray(v) for k, v in grads.items()},
        m0, dict(m0), 1, ocfg, lr)
    assert int(new_s["step"]) == 1
    np.testing.assert_allclose(float(metrics["grad_norm"]), ref_gnorm,
                               rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(np.asarray(new_p[k]), ref_p[k],
                                   rtol=1e-6, atol=1e-7, err_msg=k)
        np.testing.assert_allclose(np.asarray(new_s["m"][k]), ref_m[k],
                                   rtol=1e-6, atol=1e-7, err_msg=k)
        np.testing.assert_allclose(np.asarray(new_s["v"][k]), ref_v[k],
                                   rtol=1e-6, atol=1e-9, err_msg=k)


def test_adamw_multi_step_bias_correction():
    """Three chained steps track the oracle — the bias-correction terms
    (1 - beta^t) must use the *incremented* step count each time."""
    rng = np.random.default_rng(1)
    ocfg = AdamWConfig(weight_decay=0.0, grad_clip=0.0)  # isolate moments
    params = _tree(rng)
    state = init_opt_state(params, ocfg)
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    lr = 1e-2
    for t in range(1, 4):
        grads = _tree(rng, 0.1)
        params, state, _ = adamw_update(params, grads, state, ocfg,
                                        jnp.float32(lr))
        np_p, np_m, np_v, _ = _numpy_adamw(
            np_p, {k: np.asarray(v) for k, v in grads.items()},
            np_m, np_v, t, ocfg, lr)
        assert int(state["step"]) == t
        for k in params:
            np.testing.assert_allclose(np.asarray(params[k]), np_p[k],
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"step {t} {k}")


def test_adamw_clips_large_gradients():
    """A gradient with global norm >> grad_clip is rescaled to the clip
    threshold before entering the moments."""
    ocfg = AdamWConfig(weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0, jnp.float32)}   # gnorm = 200
    state = init_opt_state(params, ocfg)
    _, new_s, metrics = adamw_update(params, grads, state, ocfg,
                                     jnp.float32(1e-3))
    np.testing.assert_allclose(float(metrics["grad_norm"]), 200.0, rtol=1e-6)
    # clipped g = 100 * (1/200) = 0.5 per element → m = (1-b1) * 0.5
    np.testing.assert_allclose(np.asarray(new_s["m"]["w"]),
                               np.full((4,), (1 - ocfg.beta1) * 0.5),
                               rtol=1e-5)


def test_adamw_bf16_moments_cast_and_store():
    ocfg = AdamWConfig(moment_dtype="bfloat16")
    rng = np.random.default_rng(2)
    params, grads = _tree(rng), _tree(rng, 0.1)
    state = init_opt_state(params, ocfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    new_p, new_s, _ = adamw_update(params, grads, state, ocfg,
                                   jnp.float32(1e-3))
    assert new_s["m"]["w"].dtype == jnp.bfloat16
    assert new_s["v"]["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.float32            # params stay f32
    # bf16 storage must still move in the oracle's direction, within the
    # format's ~3 digits
    m0 = {k: np.zeros_like(np.asarray(v), np.float32)
          for k, v in params.items()}
    ref_p, _, _, _ = _numpy_adamw(
        {k: np.asarray(v) for k, v in params.items()},
        {k: np.asarray(v) for k, v in grads.items()},
        m0, dict(m0), 1, ocfg, 1e-3)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref_p["w"],
                               rtol=3e-2, atol=3e-2)


def test_global_norm_matches_numpy():
    rng = np.random.default_rng(3)
    tree = _tree(rng)
    ref = np.sqrt(sum(np.sum(np.square(np.asarray(v)))
                      for v in tree.values()))
    np.testing.assert_allclose(float(global_norm(tree)), ref, rtol=1e-6)


def test_cosine_schedule_edges():
    peak, warmup, total = 1e-3, 10, 100
    lr = lambda s: float(cosine_schedule(jnp.asarray(s, jnp.int32),
                                         peak_lr=peak, warmup=warmup,
                                         total=total))
    assert lr(0) == 0.0                               # linear warmup from 0
    np.testing.assert_allclose(lr(5), peak * 0.5, rtol=1e-6)
    np.testing.assert_allclose(lr(warmup), peak, rtol=1e-6)  # cosine peak
    # halfway through decay: min_frac + (1-min_frac)/2 of peak
    np.testing.assert_allclose(lr(55), peak * (0.1 + 0.9 * 0.5), rtol=1e-6)
    np.testing.assert_allclose(lr(total), peak * 0.1, rtol=1e-6)  # floor
    np.testing.assert_allclose(lr(total + 50), peak * 0.1, rtol=1e-6)


def test_cosine_schedule_monotone_decay_after_warmup():
    peak, warmup, total = 3e-4, 5, 50
    vals = [float(cosine_schedule(jnp.asarray(s, jnp.int32), peak_lr=peak,
                                  warmup=warmup, total=total))
            for s in range(warmup, total + 1)]
    assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))


def test_cosine_schedule_zero_warmup():
    lr0 = float(cosine_schedule(jnp.asarray(0, jnp.int32), peak_lr=1e-3,
                                warmup=0, total=10))
    np.testing.assert_allclose(lr0, 1e-3, rtol=1e-6)  # no warmup: start at peak
