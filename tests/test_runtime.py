"""SOL runtime tests: async queue semantics, virtual-pointer arithmetic
(the paper's 32+32-bit encoding), packed memcopies."""
import threading

from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AsyncQueue, VirtualAllocator, VirtualPtr,
                           pack_transfer, unpack_on_device)
from repro.runtime.packed import transfer


def test_virtual_ptr_encoding():
    p = VirtualPtr((3 << 32) | 100)
    assert p.ref == 3 and p.offset == 100
    q = p + 28
    assert q.ref == 3 and q.offset == 128     # arithmetic keeps the ref
    r = q - 128
    assert r.offset == 0


def test_virtual_ptr_offset_range():
    p = VirtualPtr(1 << 32)
    with pytest.raises(ValueError):
        _ = p + (1 << 32)                      # overflows 32-bit offset


def test_async_malloc_is_nonblocking_and_ordered():
    q = AsyncQueue()
    ptr = q.malloc_async(1024)                 # returns immediately
    assert isinstance(ptr, VirtualPtr)
    src = np.arange(256, dtype=np.float32)
    q.memcpy_async(ptr, src)
    q.synchronize()
    buf = q.allocator.resolve(ptr)[:src.nbytes]
    np.testing.assert_array_equal(buf.view(np.float32), src)
    q.free_async(ptr)
    q.synchronize()
    assert q.allocator.live_refs == 0
    stats = q.stats()
    assert stats["executed"] >= 4              # malloc, memcpy, free, syncs
    q.close()


def test_async_queue_pointer_arithmetic_before_materialization():
    """The paper's point: the virtual pointer participates in arithmetic
    while the allocation has not happened yet."""
    q = AsyncQueue()
    ptr = q.malloc_async(4096)
    sub = ptr + 1024                           # arithmetic pre-materialize
    q.memcpy_async(sub, np.full(16, 7, np.uint8))
    q.synchronize()
    assert (q.allocator.resolve(ptr)[1024:1040] == 7).all()
    q.close()


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    shapes=st.lists(
        st.tuples(st.integers(1, 8), st.integers(1, 16)),
        min_size=1, max_size=8),
    dtype=st.sampled_from([np.float32, np.int32, np.float16]),
    seed=st.integers(0, 1000))
def test_packed_transfer_roundtrip(shapes, dtype, seed):
    rng = np.random.default_rng(seed)
    arrays = [rng.standard_normal(s).astype(dtype) for s in shapes]
    pt = pack_transfer(arrays)
    out = unpack_on_device(pt)
    assert len(out) == len(arrays)
    for a, o in zip(arrays, out):
        np.testing.assert_array_equal(np.asarray(o), a)


def test_packed_alignment():
    arrays = [np.ones(3, np.uint8), np.ones(5, np.float32)]
    pt = pack_transfer(arrays)
    for _, _, off in pt.layout:
        assert off % 128 == 0                  # lane-aligned segments


def test_transfer_policy_split():
    small = [np.ones(2, np.float32)]
    out = transfer(small)                      # latency path
    np.testing.assert_array_equal(np.asarray(out[0]), small[0])
    many = [np.full((64, 64), i, np.float32) for i in range(8)]
    out = transfer(many)                       # packed path
    for i, o in enumerate(out):
        assert float(np.asarray(o)[0, 0]) == i
