"""SOL runtime tests: async queue semantics, virtual-pointer arithmetic
(the paper's 32+32-bit encoding), packed memcopies."""
import threading

from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (AsyncQueue, UseAfterFreeError, VirtualAllocator,
                           VirtualPtr, pack_transfer, unpack_on_device)
from repro.runtime.packed import transfer


def test_virtual_ptr_encoding():
    p = VirtualPtr((3 << 32) | 100)
    assert p.ref == 3 and p.offset == 100
    q = p + 28
    assert q.ref == 3 and q.offset == 128     # arithmetic keeps the ref
    r = q - 128
    assert r.offset == 0


def test_virtual_ptr_offset_range():
    p = VirtualPtr(1 << 32)
    with pytest.raises(ValueError):
        _ = p + (1 << 32)                      # overflows 32-bit offset


def test_async_malloc_is_nonblocking_and_ordered():
    q = AsyncQueue()
    ptr = q.malloc_async(1024)                 # returns immediately
    assert isinstance(ptr, VirtualPtr)
    src = np.arange(256, dtype=np.float32)
    q.memcpy_async(ptr, src)
    q.synchronize()
    buf = q.allocator.resolve(ptr)[:src.nbytes]
    np.testing.assert_array_equal(buf.view(np.float32), src)
    q.free_async(ptr)
    q.synchronize()
    assert q.allocator.live_refs == 0
    stats = q.stats()
    assert stats["executed"] >= 4              # malloc, memcpy, free, syncs
    q.close()


def test_async_queue_pointer_arithmetic_before_materialization():
    """The paper's point: the virtual pointer participates in arithmetic
    while the allocation has not happened yet."""
    q = AsyncQueue()
    ptr = q.malloc_async(4096)
    sub = ptr + 1024                           # arithmetic pre-materialize
    q.memcpy_async(sub, np.full(16, 7, np.uint8))
    q.synchronize()
    assert (q.allocator.resolve(ptr)[1024:1040] == 7).all()
    q.close()


# -- ISSUE 5 regression tests: async-runtime correctness ----------------------

def test_async_queue_worker_survives_exception_and_reraises():
    """A failing queued op must not kill the worker thread: the queue keeps
    draining (no deadlocked synchronize) and the stored error is re-raised
    on the NEXT synchronize, CUDA-style."""
    q = AsyncQueue()
    q.launch(lambda: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        q.synchronize()
    # the worker is still alive: later work executes and syncs cleanly
    ptr = q.malloc_async(64)
    q.memcpy_async(ptr, np.arange(16, dtype=np.uint8))
    q.synchronize()                        # error already consumed
    assert (q.allocator.resolve(ptr)[:16] == np.arange(16)).all()
    assert q.stats()["errors"] == 1
    q.close()


def test_async_queue_close_never_hangs_or_raises():
    q = AsyncQueue()

    def boom():
        raise RuntimeError("kernel failed")

    q.launch(boom)
    q.close()                              # drains; neither hangs nor raises
    assert isinstance(q.pending_error(), RuntimeError)


def test_memcpy_async_snapshots_source_at_enqueue():
    """Mutating the source AFTER enqueue must not corrupt the transfer.
    The worker is parked on an event so the pre-fix by-reference capture
    would deterministically read the mutated bytes."""
    q = AsyncQueue()
    gate = threading.Event()
    q.launch(gate.wait)                    # park the worker
    ptr = q.malloc_async(64)
    src = np.arange(16, dtype=np.uint8)
    q.memcpy_async(ptr, src)
    src[:] = 0                             # mutate after enqueue
    gate.set()
    q.synchronize()
    assert (q.allocator.resolve(ptr)[:16] == np.arange(16)).all()
    q.close()


def test_use_after_free_is_loud():
    a = VirtualAllocator()
    p = a.malloc(32)
    a.free(p)
    with pytest.raises(UseAfterFreeError, match=str(p.ref)):
        a.resolve(p)
    with pytest.raises(UseAfterFreeError):
        a.materialize(p)
    with pytest.raises(UseAfterFreeError):
        a.free(p)                          # double free is loud too
    with pytest.raises(UseAfterFreeError):
        a.free(VirtualPtr(999 << 32))      # never-allocated ref


def test_async_use_after_free_surfaces_at_synchronize():
    q = AsyncQueue()
    ptr = q.malloc_async(32)
    q.free_async(ptr)
    q.memcpy_async(ptr, np.zeros(4, np.uint8))   # executes after the free
    with pytest.raises(UseAfterFreeError):
        q.synchronize()
    q.close()


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    shapes=st.lists(
        st.tuples(st.integers(1, 8), st.integers(1, 16)),
        min_size=1, max_size=8),
    dtype=st.sampled_from([np.float32, np.int32, np.float16]),
    seed=st.integers(0, 1000))
def test_packed_transfer_roundtrip(shapes, dtype, seed):
    rng = np.random.default_rng(seed)
    arrays = [rng.standard_normal(s).astype(dtype) for s in shapes]
    pt = pack_transfer(arrays)
    out = unpack_on_device(pt)
    assert len(out) == len(arrays)
    for a, o in zip(arrays, out):
        np.testing.assert_array_equal(np.asarray(o), a)


def test_packed_alignment():
    arrays = [np.ones(3, np.uint8), np.ones(5, np.float32)]
    pt = pack_transfer(arrays)
    for _, _, off in pt.layout:
        assert off % 128 == 0                  # lane-aligned segments


def test_transfer_policy_split():
    small = [np.ones(2, np.float32)]
    out = transfer(small)                      # latency path
    np.testing.assert_array_equal(np.asarray(out[0]), small[0])
    many = [np.full((64, 64), i, np.float32) for i in range(8)]
    out = transfer(many)                       # packed path
    for i, o in enumerate(out):
        assert float(np.asarray(o)[0, 0]) == i
