"""Speed-of-light (SOL) gap analysis tests: the roofline bound shares the
election pass's cost model, ratios stay finite and non-negative for ANY
cache entry (hypothesis), nearest-bucket provenance never masquerades as an
exact measurement, ``impl_report(sol=True)`` surfaces ranked per-node rows,
the gap-driven refinement planner provably closes a doctored wide gap by
electing a config OUTSIDE the initially declared tune_space (ISSUE
acceptance), and ``tools/bench_diff.py`` gates perf regressions."""
import importlib.util
import json
import math
import os
import sys

from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import pytest

from repro.backends import get_backend
from repro.backends import registry as R
from repro.core import autotune, ir, passes, sol
from repro.core.autotune import AutotuneCache, Tunable
from repro.core.ir import Graph, Node, OpKind, TensorSpec


@pytest.fixture(autouse=True)
def _fresh_cache():
    """Every test starts (and leaves the process) with a cold session cache.
    An explicit empty AutotuneCache, not set_cache(None): None means 'reset
    to default', which would re-read SOL_AUTOTUNE_CACHE from the env."""
    autotune.set_cache(AutotuneCache())
    yield
    autotune.set_cache(AutotuneCache())


def _linear_graph(b=2, d_in=16, d_out=32):
    x = ir.input_node((b, d_in), name="x")
    w = ir.param_node((d_out, d_in), name="w")
    lin = Node(OpKind.LINEAR, [x, w], TensorSpec((b, d_out)),
               attrs={"out_features": d_out})
    return Graph([x], [lin], {"w": w}), lin


# -- the bound: one cost model, shared with the election pass -------------------

def test_sol_bound_is_the_roofline_model():
    """sol_bound_us is HardwareSpec.roofline_s scaled to µs — the same
    cost model elections use, not a parallel formula."""
    hw = get_backend("xla").hw
    flops, nbytes = 2 * 256 ** 3, 3 * 256 * 256 * 4
    bound_us, dom = sol.sol_bound_us(hw, flops, nbytes)
    assert bound_us == pytest.approx(hw.roofline_s(flops, nbytes) * 1e6)
    assert dom in ("compute", "memory")
    # dominance follows the larger term
    assert sol.sol_bound_us(hw, 1e15, 1.0)[1] == "compute"
    assert sol.sol_bound_us(hw, 1.0, 1e12)[1] == "memory"
    # degenerate terms: no bound, never a division by zero downstream
    assert sol.sol_bound_us(hw, 0.0, 0.0) == (0.0, "")


def test_node_roofline_terms_matches_node_cost_terms():
    """passes.node_roofline_terms is a thin view over _node_cost_terms —
    the SOL report and the election literally share the numbers."""
    _g, lin = _linear_graph()
    hw = get_backend("xla").hw
    flops, streamed, roundtrip = passes._node_cost_terms(lin)
    f1, b1, s1 = passes.node_roofline_terms(lin, hw)  # streamed default
    assert (f1, b1) == (flops, streamed)
    assert s1 == pytest.approx(hw.roofline_s(flops, streamed))
    f2, b2, s2 = passes.node_roofline_terms(lin, hw, memory="roundtrip")
    assert (f2, b2) == (flops, roundtrip)
    assert s2 == pytest.approx(hw.roofline_s(flops, roundtrip))


# -- ratio guarantees (hypothesis) ----------------------------------------------

@hypothesis.given(us=st.floats(allow_nan=True, allow_infinity=True),
                  bound=st.floats(allow_nan=True, allow_infinity=True))
def test_sol_ratio_always_finite_nonnegative(us, bound):
    r = sol.sol_ratio(us, bound)
    assert math.isfinite(r) and r >= 0.0


@hypothesis.given(
    us=st.floats(min_value=0.0, allow_nan=True, allow_infinity=True),
    flops=st.floats(allow_nan=True, allow_infinity=True),
    nbytes=st.floats(allow_nan=True, allow_infinity=True),
    dims=st.lists(st.integers(min_value=1, max_value=2 ** 20),
                  min_size=1, max_size=4))
def test_cache_rows_ratios_finite_for_arbitrary_entries(us, flops, nbytes,
                                                        dims):
    """ISSUE satellite: ANY cache entry — degenerate terms, inf/nan times,
    whatever a corrupt or hand-edited file delivers — yields a SOL row with
    a finite, non-negative ratio."""
    c = AutotuneCache()
    c.record("matmul", tuple(dims), "float32", "xla", "ref.matmul", us,
             flops=flops, nbytes=nbytes)
    rows = sol.cache_rows(c)
    assert len(rows) == 1
    assert math.isfinite(rows[0].ratio) and rows[0].ratio >= 0.0
    assert math.isfinite(rows[0].bound_us) and rows[0].bound_us >= 0.0


# -- provenance: exact vs nearest, measured vs calibrated -----------------------

def test_cache_rows_are_exact_measured_and_best_only_elects():
    c = AutotuneCache()
    c.record("matmul", (256, 256, 256), "float32", "xla", "ref.matmul",
             50.0, flops=2 * 256 ** 3, nbytes=3 * 256 * 256 * 4)
    c.record("matmul", (256, 256, 256), "float32", "xla",
             "pallas.matmul_mxu", 30.0, config=(128, 128, 128),
             flops=2 * 256 ** 3, nbytes=3 * 256 * 256 * 4)
    rows = sol.cache_rows(c)
    assert len(rows) == 2
    assert all(r.confidence == "exact" and r.source == "measured"
               for r in rows)
    assert all(r.ratio == pytest.approx(r.us / r.bound_us) for r in rows)
    best = sol.cache_rows(c, best_only=True)
    assert len(best) == 1 and best[0].impl == "pallas.matmul_mxu"


def test_node_rows_nearest_bucket_is_tagged_nearest():
    """ISSUE satellite: a nearest-bucket hit surfaces confidence='nearest'
    — an estimate, visibly distinct from the shape's own measurement."""
    g, lin = _linear_graph(b=2, d_in=16, d_out=32)   # keys on (2, 16, 32)
    lin.impl = "ref.linear"
    backend = get_backend("xla")
    c = AutotuneCache()
    c.record("linear", (2, 16, 64), "float32", "xla", "ref.linear", 50.0,
             flops=1.0, nbytes=1.0)                  # only a NEIGHBOR bucket
    rows = sol.node_rows(g, backend, c)
    (row,) = [r for r in rows if r.op == "linear"]
    assert row.confidence == "nearest" and row.source == "measured"
    assert row.us == 50.0 and row.ratio > 0.0

    c.record("linear", (2, 16, 32), "float32", "xla", "ref.linear", 40.0,
             flops=1.0, nbytes=1.0)                  # now the exact bucket
    (row,) = [r for r in sol.node_rows(g, backend, c) if r.op == "linear"]
    assert row.confidence == "exact" and row.us == 40.0


def test_node_rows_cold_cache_stays_analytical():
    """No measurement, no calibration → source='analytical' with no ratio:
    silence stays visible, it never fakes a measurement."""
    g, lin = _linear_graph()
    lin.impl = "ref.linear"
    (row,) = [r for r in sol.node_rows(g, get_backend("xla"),
                                       AutotuneCache()) if r.op == "linear"]
    assert row.source == "analytical" and row.ratio == 0.0 and row.us == 0.0


def test_node_rows_calibrated_has_no_bucket_confidence():
    g, lin = _linear_graph()
    lin.impl = "ref.linear"
    c = AutotuneCache()
    c.set_calibration("xla", "linear",
                      {"s_per_flop": 1e-12, "s_per_byte": 1e-10, "n": 4.0})
    (row,) = [r for r in sol.node_rows(g, get_backend("xla"), c)
              if r.op == "linear"]
    assert row.source == "calibrated"
    assert row.confidence == ""           # an estimate has no bucket hit
    assert row.us > 0.0 and math.isfinite(row.ratio)


def test_rank_never_lets_estimates_outrank_exact_measurements():
    """A nearest-bucket or calibrated row NEVER sorts ahead of an
    exact-bucket measurement, no matter how large its ratio."""
    def row(ratio, conf, src):
        return sol.SolRow(op="matmul", bucket=(64, 64, 64), dtype="float32",
                          backend="xla", impl="ref.matmul", us=ratio,
                          bound_us=1.0, ratio=ratio, bottleneck="compute",
                          confidence=conf, source=src)
    exact_small = row(2.0, "exact", "measured")
    exact_big = row(90.0, "exact", "measured")
    nearest_huge = row(1e6, "nearest", "measured")
    calibrated_huge = row(1e9, "", "calibrated")
    ranked = sol.rank([nearest_huge, exact_small, calibrated_huge, exact_big])
    assert ranked[0] is exact_big and ranked[1] is exact_small
    assert all(r in (nearest_huge, calibrated_huge) for r in ranked[2:])
    # within the estimate tier, worst ratio still first
    assert ranked[2] is calibrated_huge


def test_render_lists_every_row():
    c = AutotuneCache()
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 9.0,
             flops=2 * 64 ** 3, nbytes=3 * 64 * 64 * 4)
    text = sol.render(sol.rank(sol.cache_rows(c)))
    assert "ref.matmul" in text and "ratio" in text and "64x64x64" in text


# -- impl_report(sol=True) ------------------------------------------------------

def test_impl_report_sol_surfaces_ranked_rows():
    from repro.frontends import nn
    from repro.frontends.optimize import optimize
    m = optimize(nn.Sequential(nn.Linear(16, 32), nn.GELU()), (2, 16),
                 backend="xla")
    rows = m.impl_report(sol=True)
    assert rows and all(
        {"op", "impl", "ratio", "bound_us", "confidence", "source"}
        <= set(r) for r in rows)
    assert all(math.isfinite(r["ratio"]) and r["ratio"] >= 0.0 for r in rows)
    # exact measurements (if any) must precede every estimate row
    tiers = [0 if (r["confidence"] == "exact" and r["source"] == "measured")
             else 1 for r in rows]
    assert tiers == sorted(tiers)


def test_impl_report_sol_reflects_cache_measurements():
    from repro.frontends import nn
    from repro.frontends.optimize import optimize
    m = optimize(nn.Linear(16, 32), (2, 16), backend="xla")
    lin = m.graph.nodes_of(OpKind.LINEAR)[0]
    cache = autotune.get_cache()
    cache.record("linear", autotune.node_shape(lin), "float32", "xla",
                 lin.impl, 25.0, flops=1.0, nbytes=1.0)
    (row,) = [r for r in m.impl_report(sol=True) if r["op"] == "linear"]
    assert row["source"] == "measured" and row["confidence"] == "exact"
    assert row["us"] == 25.0 and row["ratio"] > 0.0


# -- Tunable.refine_space -------------------------------------------------------

def test_refine_space_default_pow2_neighborhood():
    tun = Tunable("blk", lambda n, hw: [(64, 64), (128, 128)])
    neigh = tun.refine_space(None, None, (64, 64))
    assert neigh                                   # something to probe
    assert (64, 64) not in neigh                   # never the winner itself
    assert (128, 128) not in neigh                 # never the initial space
    assert (32, 32) in neigh and (64, 128) in neigh
    assert all(all(d >= 1 for d in c) for c in neigh)
    assert len(set(neigh)) == len(neigh)           # deduplicated


def test_refine_space_floor_at_one():
    tun = Tunable("blk", lambda n, hw: [])
    neigh = tun.refine_space(None, None, (1,))
    assert neigh == [(2,)]                         # 1//2 clamps to 1 == win


def test_refine_space_custom_hook_stays_legal():
    """Divisor-constrained families override refine: every avgpool probe
    must divide the channel count."""
    from repro.kernels.avgpool.ops import avgpool_refine_space
    n = Node(OpKind.AVGPOOL, [ir.input_node((1, 48, 10, 10))],
             TensorSpec((1, 48, 8, 8)), attrs={"kernel": 3, "stride": 1})
    hw = get_backend("xla").hw
    for (bc,) in avgpool_refine_space(n, hw, (8,)):
        assert 48 % bc == 0


# -- the gap-driven refinement planner (ISSUE acceptance) -----------------------

def _measurement(config, us):
    from repro.core.measure import ConfigMeasurement
    return ConfigMeasurement(config=config, us=us, mean_us=us)


def test_refine_plan_closes_doctored_gap_outside_tune_space():
    """ISSUE acceptance: a doctored wide-gap cell gets refinement rounds,
    elects a config OUTSIDE the initially declared tune_space, and its
    recorded SOL ratio strictly improves."""
    from benchmarks.autotune import _node, refine_plan
    backend = get_backend("pallas_interpret")
    node = _node("matmul", (32, 32, 32))
    tun = R.get_impl("pallas.matmul_mxu").tunable
    initial = set(tun.tune_space(node, backend.hw))
    assert initial, "test premise: the tiny matmul has a tune space"
    win = sorted(initial)[0]
    target = tun.refine_space(node, backend.hw, win)[0]
    assert target not in initial

    c = AutotuneCache()
    c.record("matmul", (32, 32, 32), "float32", "pallas_interpret",
             "pallas.matmul_mxu", 4000.0, config=win,
             flops=2 * 32 ** 3, nbytes=3 * 32 * 32 * 4)

    def fake_measure(node, vals, bk, impl, configs):
        # the probe at `target` is 4x faster; everything else is worse
        return [_measurement(c2, 1000.0 if tuple(c2) == target else 9000.0)
                for c2 in configs]

    (rep,) = refine_plan(c, "pallas_interpret", top_k=1, rounds=3,
                         budget=64, measure=fake_measure)
    assert rep["refined_impl"] == "pallas.matmul_mxu"
    assert rep["rounds"] >= 1 and rep["configs_measured"] > 0
    assert rep["config"] == target and rep["outside_space"]
    assert rep["after_us"] == 1000.0
    assert rep["after_ratio"] < rep["before_ratio"]     # strictly improves
    # the win is recorded back into the cache so a later election pins it
    m = c.lookup("matmul", (32, 32, 32), "float32",
                 "pallas_interpret")["pallas.matmul_mxu"]
    assert m.us == 1000.0 and m.config == target


def test_refine_plan_refines_tunable_even_when_ref_wins_the_cell():
    """When an untunable reference impl currently wins a cell, the planner
    still probes the tunable family's neighborhood — and flips the cell's
    election when refinement beats the old winner."""
    from benchmarks.autotune import _node, refine_plan
    backend = get_backend("pallas_interpret")
    node = _node("matmul", (32, 32, 32))
    tun = R.get_impl("pallas.matmul_mxu").tunable
    win = sorted(tun.tune_space(node, backend.hw))[0]
    target = tun.refine_space(node, backend.hw, win)[0]

    c = AutotuneCache()
    c.record("matmul", (32, 32, 32), "float32", "pallas_interpret",
             "ref.matmul", 500.0, flops=2 * 32 ** 3, nbytes=3 * 32 * 32 * 4)
    c.record("matmul", (32, 32, 32), "float32", "pallas_interpret",
             "pallas.matmul_mxu", 4000.0, config=win,
             flops=2 * 32 ** 3, nbytes=3 * 32 * 32 * 4)

    def fake_measure(node, vals, bk, impl, configs):
        return [_measurement(c2, 100.0 if tuple(c2) == target else 9000.0)
                for c2 in configs]

    (rep,) = refine_plan(c, "pallas_interpret", top_k=1, rounds=3,
                         budget=64, measure=fake_measure)
    assert rep["before_us"] == 500.0                    # ref won the cell
    assert rep["refined_impl"] == "pallas.matmul_mxu"
    assert rep["impl"] == "pallas.matmul_mxu"           # election flipped
    assert rep["after_us"] == 100.0 and rep["outside_space"]
    assert rep["after_ratio"] < rep["before_ratio"]


def test_refine_plan_early_stops_when_gap_stops_closing():
    from benchmarks.autotune import _node, refine_plan
    backend = get_backend("pallas_interpret")
    node = _node("matmul", (32, 32, 32))
    tun = R.get_impl("pallas.matmul_mxu").tunable
    win = sorted(tun.tune_space(node, backend.hw))[0]

    c = AutotuneCache()
    c.record("matmul", (32, 32, 32), "float32", "pallas_interpret",
             "pallas.matmul_mxu", 4000.0, config=win,
             flops=2 * 32 ** 3, nbytes=3 * 32 * 32 * 4)

    def no_gain(node, vals, bk, impl, configs):
        return [_measurement(c2, 3999.0) for c2 in configs]   # < min_gain

    (rep,) = refine_plan(c, "pallas_interpret", top_k=1, rounds=5,
                         budget=1000, measure=no_gain)
    assert rep["rounds"] == 1                           # stopped, not 5
    assert rep["config"] == win and not rep["outside_space"]
    assert rep["after_us"] == 4000.0


def test_refine_plan_flags_rewrite_candidates():
    """A cell with nothing to tune whose gap stays huge is a rewrite
    candidate: no config reaches the hardware limit, the kernel needs
    work."""
    from benchmarks.autotune import refine_plan
    c = AutotuneCache()
    c.record("matmul", (32, 32, 32), "float32", "pallas_interpret",
             "ref.matmul", 1e6, flops=2 * 32 ** 3, nbytes=3 * 32 * 32 * 4)

    def never_called(node, vals, bk, impl, configs):    # pragma: no cover
        raise AssertionError("no tunable impl — nothing to measure")

    (rep,) = refine_plan(c, "pallas_interpret", top_k=1,
                         measure=never_called)
    assert rep["rewrite_candidate"] and rep["rounds"] == 0
    assert "nothing to refine" in rep["note"]


# -- roofline backend resolution (satellite) ------------------------------------

def test_roofline_hw_resolves_from_active_backend(monkeypatch):
    from benchmarks import roofline
    monkeypatch.delenv("SOL_BACKEND", raising=False)
    assert roofline.active_backend_name() == roofline.DEFAULT_BACKEND
    assert roofline.active_hw().name == get_backend("xla").hw.name
    monkeypatch.setenv("SOL_BACKEND", "host_cpu")
    assert roofline.active_backend_name() == "host_cpu"
    assert roofline.active_hw().name == get_backend("host_cpu").hw.name
    # an explicit backend arg overrides the environment
    assert (roofline.active_hw("pallas_interpret").name
            == get_backend("pallas_interpret").hw.name)


# -- tools/bench_diff.py (the CI perf-regression gate) --------------------------

def _bench_diff():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "bench_diff.py")
    spec = importlib.util.spec_from_file_location("bench_diff", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _artifact(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(
        {"rows": [{"name": n, "us_per_call": us, "derived": ""}
                  for n, us in rows.items()]}))
    return str(p)


def test_bench_diff_missing_baseline_passes_trivially(tmp_path):
    bd = _bench_diff()
    cur = _artifact(tmp_path, "cur.json", {"a": 10.0})
    assert bd.main([str(tmp_path / "nope.json"), cur]) == 0
    assert bd.main([cur, str(tmp_path / "nope.json")]) == 2  # current missing


def test_bench_diff_catches_injected_2x_slowdown(tmp_path):
    """ISSUE acceptance: an injected 2x slowdown on a shared row fails."""
    bd = _bench_diff()
    base = _artifact(tmp_path, "base.json", {"a": 100.0, "b": 50.0})
    cur = _artifact(tmp_path, "cur.json", {"a": 200.0, "b": 50.0})
    assert bd.main([base, cur, "--threshold", "0.15"]) == 1
    regs, _ = bd.diff(bd.load_rows(base), bd.load_rows(cur))
    assert [r[0] for r in regs] == ["a"]


def test_bench_diff_within_threshold_and_improvements_pass(tmp_path):
    bd = _bench_diff()
    base = _artifact(tmp_path, "base.json", {"a": 100.0, "b": 50.0})
    cur = _artifact(tmp_path, "cur.json", {"a": 110.0, "b": 10.0})
    assert bd.main([base, cur, "--threshold", "0.15"]) == 0


def test_bench_diff_min_us_noise_floor(tmp_path):
    """Sub-noise-floor rows may double without failing the gate; rows
    crossing the floor still count."""
    bd = _bench_diff()
    base = _artifact(tmp_path, "base.json", {"tiny": 2.0, "real": 100.0})
    cur = _artifact(tmp_path, "cur.json", {"tiny": 4.0, "real": 100.0})
    assert bd.main([base, cur, "--min-us", "20"]) == 0
    assert bd.main([base, cur]) == 1                 # no floor → tiny fails
    crossing = _artifact(tmp_path, "cross.json", {"tiny": 40.0,
                                                  "real": 100.0})
    assert bd.main([base, crossing, "--min-us", "20"]) == 1


def test_bench_diff_disjoint_rows_pass(tmp_path):
    bd = _bench_diff()
    base = _artifact(tmp_path, "base.json", {"old": 10.0})
    cur = _artifact(tmp_path, "cur.json", {"new": 99.0})
    assert bd.main([base, cur]) == 0
