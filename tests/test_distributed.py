"""Sharding rule-engine tests: every spec the engine emits must be valid
(divisibility) for every architecture on the production mesh shape — checked
abstractly (AbstractMesh) so no 512 fake devices are needed in tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.distributed import sharding as S
from repro.distributed.zero import zero_opt_specs
from repro.models import backbone as B
from repro.models.config import SHAPES


def abstract_mesh(multi_pod=False):
    sizes = (2, 16, 16) if multi_pod else (16, 16)
    names = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return AbstractMesh(sizes, names)              # jax >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # jax 0.4.x signature


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(mesh.shape)[a]
    return n


def assert_spec_valid(mesh, spec, shape, what=""):
    assert isinstance(spec, P), f"{what}: not a PartitionSpec"
    assert len(spec) <= len(shape), f"{what}: spec rank > shape rank"
    for dim, axes in zip(shape, spec):
        n = _axis_size(mesh, axes)
        assert dim % n == 0, \
            f"{what}: dim {dim} not divisible by axis size {n} ({spec})"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = get_config(arch)
    mesh = abstract_mesh(multi_pod)
    shapes = B.param_specs(cfg)
    specs = S.param_specs(mesh, cfg, shapes)
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for (kp, sh), sp in zip(flat_sh, flat_sp):
        assert_spec_valid(mesh, sp, sh.shape, what=str(kp))


@pytest.mark.parametrize("arch", ["command-r-plus-104b", "qwen2-1.5b",
                                  "recurrentgemma-9b", "rwkv6-1.6b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    mesh = abstract_mesh()
    for shape_name in ("decode_32k", "long_500k"):
        shp = SHAPES[shape_name]
        if shape_name == "long_500k" and not cfg.subquadratic:
            continue
        cache = B.cache_specs(cfg, shp.global_batch, shp.seq_len)
        specs = S.cache_specs(mesh, cfg, cache)
        flat_sh = jax.tree_util.tree_flatten_with_path(cache)[0]
        flat_sp = jax.tree.leaves(specs,
                                  is_leaf=lambda x: isinstance(x, P))
        for (kp, sh), sp in zip(flat_sh, flat_sp):
            assert_spec_valid(mesh, sp, sh.shape, what=f"{arch}:{kp}")


def test_kv_fallback_to_sequence_sharding():
    """command-r kv=8 < model axis 16 → the engine must shard the cache's
    sequence dim instead (SP / flash-decoding)."""
    cfg = get_config("command-r-plus-104b")
    mesh = abstract_mesh()
    cache = B.cache_specs(cfg, 128, 32768)
    specs = S.cache_specs(mesh, cfg, cache)
    leaf_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    four_d = [s for s in leaf_specs if len(s) == 5]   # stacked (L,B,S,KV,hd)
    assert four_d, "expected stacked kv-cache specs"
    for s in four_d:
        assert s[2] == "model", f"expected SP on seq dim, got {s}"


def test_tp_sharding_of_projections():
    cfg = get_config("stablelm-3b")
    mesh = abstract_mesh()
    shapes = B.param_specs(cfg)
    specs = S.param_specs(mesh, cfg, shapes)
    wq = specs["macro"]["pos0"]["wq"]
    wo = specs["macro"]["pos0"]["wo"]
    assert wq == P(None, None, "model")      # stacked: (L, D, H·hd)
    assert wo == P(None, "model", None)
    assert specs["embed"] == P("model", None)


def test_moe_expert_parallel_specs():
    cfg = get_config("olmoe-1b-7b")
    mesh = abstract_mesh()
    specs = S.param_specs(mesh, cfg, B.param_specs(cfg))
    moe = specs["macro"]["pos0"]["moe"]
    assert moe["wg"] == P(None, "model", None, None)   # (L, E, D, F): EP
    assert moe["router"] == P(None, None, None)


def test_zero_adds_dp_axis():
    cfg = get_config("qwen2-1.5b")
    mesh = abstract_mesh()
    shapes = B.param_specs(cfg)
    pspecs = S.param_specs(mesh, cfg, shapes)
    ospecs = zero_opt_specs(mesh, pspecs, shapes)
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_m = jax.tree.leaves(ospecs["m"],
                             is_leaf=lambda x: isinstance(x, P))
    extra = sum(1 for p, m in zip(flat_p, flat_m)
                if ("data" in tuple(m) or ("data",) in
                    [a if isinstance(a, tuple) else (a,) for a in m])
                and m != p)
    assert extra > 0, "ZeRO should shard some moments over the data axis"
    for sh, m in zip(jax.tree.leaves(shapes), flat_m):
        assert_spec_valid(mesh, m, sh.shape, what="zero moment")


def test_batch_specs_long500k_batch1_replicated():
    cfg = get_config("rwkv6-1.6b")
    mesh = abstract_mesh()
    batch = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    specs = S.batch_specs(mesh, cfg, batch)
    assert specs["tokens"][0] is None   # bs=1 cannot shard over data


def test_debug_mesh_step_runs_sharded():
    """End-to-end jit with shardings on the single real device (mesh 1×1)."""
    from repro.distributed.steps import (StepOptions, init_train_state,
                                        make_train_step)
    from repro.launch.mesh import make_debug_mesh
    cfg = get_smoke("qwen2-1.5b")
    mesh = make_debug_mesh(1, 1)
    opts = StepOptions(remat=False, zero=False, lr=1e-3,
                       warmup=1, total_steps=4)
    step_fn, _ = make_train_step(mesh, cfg, opts)
    state = init_train_state(cfg, opts, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    with mesh:
        state2, metrics = jax.jit(step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
