"""Autotune subsystem tests: cache round-trip + schema/atomicity guarantees,
shape-bucket canonicalization with nearest-bucket lookup (plus hypothesis
property tests over both), measured-first election (provenance, config
pinning through the Tunable protocol, the roofline-contradicting flip), the
calibration fits (roofline coefficients and the DFP _EW_FLOPS constant),
and the MXU matmul as the elected LINEAR/MATMUL flavour."""
import json
import os

from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.backends import registry as R
from repro.core import autotune, ir, passes
from repro.core.autotune import (AutotuneCache, Measurement, bucket_dim,
                                 bucket_shape)
from repro.core.executor import lower_graph
from repro.core.ir import Graph, Node, OpKind, TensorSpec
from repro.frontends import nn
from repro.frontends.optimize import optimize


@pytest.fixture(autouse=True)
def _fresh_cache():
    """Every test starts (and leaves the process) with a cold session cache.
    An explicit empty AutotuneCache, not set_cache(None): None means 'reset
    to default', which would re-read SOL_AUTOTUNE_CACHE from the env."""
    autotune.set_cache(AutotuneCache())
    yield
    autotune.set_cache(AutotuneCache())


def _linear_graph(b=2, d_in=16, d_out=32):
    x = ir.input_node((b, d_in), name="x")
    w = ir.param_node((d_out, d_in), name="w")
    lin = Node(OpKind.LINEAR, [x, w], TensorSpec((b, d_out)),
               attrs={"out_features": d_out})
    return Graph([x], [lin], {"w": w}), lin


# -- cache mechanics -----------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    """save → load preserves measurements, configs, and calibration."""
    path = str(tmp_path / "cache.json")
    c = AutotuneCache()
    c.record("matmul", (256, 256, 256), "float32", "pallas_tpu",
             "pallas.matmul_mxu", 12.5, config=(128, 128, 128),
             flops=2 * 256 ** 3, nbytes=3 * 256 * 256 * 4)
    c.record("matmul", (256, 256, 256), "float32", "pallas_tpu",
             "ref.matmul", 20.0)
    c.set_calibration("pallas_tpu", "matmul",
                      {"s_per_flop": 1e-14, "s_per_byte": 2e-12, "n": 2.0})
    c.save(path)
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))

    c2 = AutotuneCache.load(path)
    assert not c2.stale
    got = c2.lookup("matmul", (256, 256, 256), "float32", "pallas_tpu")
    assert got["pallas.matmul_mxu"].us == 12.5
    assert got["pallas.matmul_mxu"].config == (128, 128, 128)
    assert got["ref.matmul"].us == 20.0
    assert c2.calibration("pallas_tpu", "matmul")["s_per_flop"] == 1e-14


def test_stale_schema_ignored_not_misread(tmp_path):
    """A cache written by a different schema version comes back empty with
    stale=True — old files are never misinterpreted."""
    path = tmp_path / "old.json"
    path.write_text(json.dumps({
        "schema": autotune.SCHEMA_VERSION + 1,
        "entries": {"matmul|float32|xla|256x256x256":
                    {"ref.matmul": {"us": 1.0}}}}))
    c = AutotuneCache.load(str(path))
    assert c.stale
    assert len(c) == 0
    assert c.lookup("matmul", (256, 256, 256), "float32", "xla") == {}


def test_corrupt_file_yields_empty_cache(tmp_path):
    path = tmp_path / "torn.json"
    path.write_text('{"schema": 1, "entr')      # torn write simulation
    c = AutotuneCache.load(str(path))
    assert len(c) == 0 and not c.stale


def test_record_keeps_best_time():
    c = AutotuneCache()
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 9.0)
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 5.0,
             config=(32, 32, 32))
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 7.0)
    m = c.lookup("matmul", (64, 64, 64), "float32", "xla")["ref.matmul"]
    assert m.us == 5.0 and m.config == (32, 32, 32)


def test_bucket_canonicalization_and_nearest_lookup():
    """Shapes bucket to nearest powers of two; unseen buckets resolve to the
    nearest same-rank bucket in log2-space."""
    assert bucket_shape((100, 70, 36)) == (128, 64, 32)
    c = AutotuneCache()
    c.record("matmul", (256, 256, 256), "float32", "xla", "ref.matmul", 3.0)
    c.record("matmul", (2048, 2048, 2048), "float32", "xla", "ref.matmul",
             90.0)
    # same bucket (250→256)
    assert c.lookup("matmul", (250, 260, 255), "float32", "xla")[
        "ref.matmul"].us == 3.0
    # unseen bucket (4096) → nearest is 2048
    assert c.lookup("matmul", (4096, 4096, 4096), "float32", "xla")[
        "ref.matmul"].us == 90.0
    # other backend/dtype/op stay isolated
    assert c.lookup("matmul", (256, 256, 256), "bfloat16", "xla") == {}
    assert c.lookup("matmul", (256, 256, 256), "float32", "host_cpu") == {}
    assert c.lookup("linear", (256, 256, 256), "float32", "xla") == {}


# -- hypothesis property tests ---------------------------------------------------

@hypothesis.settings(max_examples=100, deadline=None)
@hypothesis.given(a=st.integers(1, 1 << 20), b=st.integers(1, 1 << 20))
def test_bucket_dim_monotone_pow2(a, b):
    """bucket_dim is monotone, always a power of two, within a ×√2 factor
    of its argument, and bucket_shape applies it elementwise."""
    lo, hi = sorted((a, b))
    assert bucket_dim(lo) <= bucket_dim(hi)
    for d in (a, b):
        bd = bucket_dim(d)
        assert bd >= 1 and (bd & (bd - 1)) == 0
        assert bd / d <= 2 ** 0.5 + 1e-9 and d / bd <= 2 ** 0.5 + 1e-9
    assert bucket_shape((a, b)) == (bucket_dim(a), bucket_dim(b))


@hypothesis.settings(max_examples=50, deadline=None)
@hypothesis.given(
    shape=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    probe=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    us=st.floats(1e-3, 1e6, allow_nan=False, allow_infinity=False))
def test_lookup_never_crosses_ops_dtypes_backends(shape, probe, us):
    """Nearest-bucket lookup may roam across same-rank buckets but never
    across op kinds, dtypes, backends, or ranks."""
    c = AutotuneCache()
    c.record("matmul", tuple(shape), "float32", "xla", "ref.matmul", us)
    assert c.lookup("linear", tuple(probe), "float32", "xla") == {}
    assert c.lookup("matmul", tuple(probe), "bfloat16", "xla") == {}
    assert c.lookup("matmul", tuple(probe), "float32", "host_cpu") == {}
    got = c.lookup("matmul", tuple(probe), "float32", "xla")
    if len(probe) == len(shape):
        assert got["ref.matmul"].us == us     # the only same-rank bucket
    else:
        assert got == {}


_ENTRY = st.tuples(
    st.sampled_from(["matmul", "linear", "attention", "fused"]),
    st.lists(st.integers(1, 2048), min_size=1, max_size=4),
    st.sampled_from(["float32", "bfloat16"]),
    st.sampled_from(["xla", "host_cpu", "pallas_interpret"]),
    st.sampled_from(["ref.x", "pallas.y", "host_cpu.z"]),
    st.floats(1e-3, 1e6, allow_nan=False, allow_infinity=False),
    st.one_of(st.none(), st.lists(st.integers(1, 512), min_size=1,
                                  max_size=3)))


@hypothesis.settings(max_examples=25, deadline=None,
                     suppress_health_check=[
                         hypothesis.HealthCheck.function_scoped_fixture])
@hypothesis.given(entries=st.lists(_ENTRY, max_size=12))
def test_cache_save_load_roundtrip_idempotent(tmp_path, entries):
    """save → load reproduces the cache exactly, and a second save → load
    of the loaded cache is a fixed point (idempotence)."""
    c = AutotuneCache()
    for op, shape, dtype, backend, impl, us, cfg in entries:
        c.record(op, tuple(shape), dtype, backend, impl, us,
                 config=tuple(cfg) if cfg else None,
                 flops=us * 2, nbytes=us * 3)
    p1 = str(tmp_path / "c1.json")
    c.save(p1)
    c2 = AutotuneCache.load(p1)
    assert c2.to_json() == c.to_json()
    assert len(c2) == len(c)
    p2 = str(tmp_path / "c2.json")
    c2.save(p2)
    assert AutotuneCache.load(p2).to_json() == c2.to_json()


# -- the Tunable protocol --------------------------------------------------------

def _attention_graph(b=1, s=64, h=2, hd=16):
    q, k, v = (ir.input_node((b, s, h, hd), name=nm) for nm in "qkv")
    node = Node(OpKind.ATTENTION, [q, k, v], TensorSpec((b, s, h, hd)),
                attrs={"causal": True})
    return Graph([q, k, v], [node], {}), node


def test_registry_declares_tunables_for_kernel_families():
    """ISSUE tentpole: every Pallas kernel family — matmul, flash
    attention, dfp_fused, both recurrence scans and the Listing-3 avgpool —
    exposes a tune space through the registry, and bind_config pins/clears
    the declared node attr."""
    from benchmarks.autotune import _node
    R._load_entry_points()
    hw = get_backend("pallas_interpret").hw
    _g, attn = _attention_graph()
    _g2, lin = _linear_graph(8, 256, 128)
    for impl_name, node in (
            ("pallas.matmul_mxu", _node("matmul", (256, 256, 256))),
            ("pallas.linear_mxu", lin),
            ("pallas.flash_attention", attn),
            ("pallas.dfp_fused", _node("fused", (256, 128))),
            ("pallas.rglru_scan", _node("rglru_scan", (2, 32, 256))),
            ("pallas.rwkv6_scan", _node("rwkv6_scan", (1, 64, 2, 16))),
            ("pallas.avgpool", _node("avgpool", (1, 8, 14, 14)))):
        impl = R.get_impl(impl_name)
        assert impl is not None and impl.tunable is not None, impl_name
        space = impl.tunable.tune_space(node, hw)
        assert len(space) >= 2, (impl_name, space)
        impl.tunable.bind_config(node, space[0])
        assert tuple(node.attrs[impl.tunable.attr]) == tuple(space[0])
        impl.tunable.bind_config(node, None)
        assert impl.tunable.attr not in node.attrs


def test_measured_attention_election_pins_and_clears_block():
    """A measured attention win pins its (bq, bk) config under the generic
    Tunable attr; a cold re-election clears it."""
    c = AutotuneCache()
    c.record("attention", (1, 64, 2, 16), "float32", "pallas_interpret",
             "pallas.flash_attention", 3.0, config=(32, 64))
    c.record("attention", (1, 64, 2, 16), "float32", "pallas_interpret",
             "ref.attention", 9.0)
    autotune.set_cache(c)
    g, node = _attention_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert node.impl == "pallas.flash_attention"
    assert node.attrs["attn_block"] == (32, 64)
    assert g.election_pinned["pallas.flash_attention"] == [(32, 64)]

    autotune.set_cache(AutotuneCache())
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert "attn_block" not in node.attrs


def test_reelection_on_foreign_backend_clears_pin():
    """Re-electing on a backend where the tuned impl is inadmissible (no
    'pallas' capability on host_cpu) must still drop the stale pin."""
    c = AutotuneCache()
    c.record("attention", (1, 64, 2, 16), "float32", "pallas_interpret",
             "pallas.flash_attention", 3.0, config=(32, 64))
    autotune.set_cache(c)
    g, node = _attention_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert node.attrs["attn_block"] == (32, 64)

    passes.elect_implementations(g, get_backend("host_cpu"))
    assert node.impl == "ref.attention"
    assert "attn_block" not in node.attrs


def test_measured_attention_entry_flips_election():
    """ISSUE acceptance: a cached attention measurement flips the flavour
    choice — ref.attention wins only because the data says so."""
    g_cold, node_cold = _attention_graph()
    passes.elect_implementations(g_cold, get_backend("pallas_interpret"))
    assert node_cold.impl == "pallas.flash_attention"   # the roofline choice

    c = AutotuneCache()
    c.record("attention", (1, 64, 2, 16), "float32", "pallas_interpret",
             "pallas.flash_attention", 50.0, config=(64, 64))
    c.record("attention", (1, 64, 2, 16), "float32", "pallas_interpret",
             "ref.attention", 2.0)
    autotune.set_cache(c)
    g, node = _attention_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert node.impl == "ref.attention"
    assert g.election_provenance["ref.attention"] == {"measured": 1}
    assert "attn_block" not in node.attrs   # the loser's config is not pinned


def test_pinned_attention_block_executes_and_matches_reference():
    """End to end: elect with a warm cache, lower, execute — the pinned
    block size reaches the kernel and the output still matches the oracle."""
    from repro.kernels.flash_attention.ref import flash_attention_ref
    c = AutotuneCache()
    c.record("attention", (1, 64, 2, 16), "float32", "pallas_interpret",
             "pallas.flash_attention", 3.0, config=(32, 32))
    autotune.set_cache(c)
    g, node = _attention_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert node.attrs["attn_block"] == (32, 32)
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
               for _ in range(3))
    y = lower_graph(g, get_backend("pallas_interpret"))({}, q, k, v)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# -- measured election ----------------------------------------------------------

def test_cold_cache_falls_back_to_roofline():
    """ISSUE acceptance: a cold cache degrades gracefully to the analytical
    path — the MXU matmul wins on tier at equal roofline cost."""
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "pallas.linear_mxu"
    assert g.election_provenance["pallas.linear_mxu"] == {"analytical": 1}


def test_warm_cache_election_uses_measurement(tmp_path):
    """save → load → election: the measured entry drives the choice and the
    provenance says so."""
    path = str(tmp_path / "cache.json")
    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 4.0, config=(16, 128, 128))
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "ref.linear", 9.0)
    c.save(path)
    autotune.load_cache(path)

    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "pallas.linear_mxu"
    assert g.election_provenance["pallas.linear_mxu"] == {"measured": 1}
    # the winning measurement's tile config is pinned on the node
    assert lin.attrs["mxu_block"] == (16, 128, 128)


def test_reelection_clears_stale_tile_config():
    """A graph elected with a warm cache (pinned mxu_block) then re-elected
    cold must drop the stale tuned config — re-lowering on another backend
    or cache state is a supported flow."""
    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 4.0, config=(512, 256, 512))
    autotune.set_cache(c)
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.attrs["mxu_block"] == (512, 256, 512)

    autotune.set_cache(AutotuneCache())
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert "mxu_block" not in lin.attrs

    # a measured winner without a config also clears a prior pin
    lin.attrs["mxu_block"] = (512, 256, 512)
    c2 = AutotuneCache()
    c2.record("linear", (2, 16, 32), "float32", "pallas_interpret",
              "ref.linear", 1.0)
    autotune.set_cache(c2)
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "ref.linear" and "mxu_block" not in lin.attrs


def test_measured_entry_flips_roofline_choice():
    """ISSUE acceptance: a cache entry flips a flavour choice the roofline
    model would not make — ref.linear beats the MXU kernel only because the
    data says so."""
    g_cold, lin_cold = _linear_graph()
    passes.elect_implementations(g_cold, get_backend("pallas_interpret"))
    assert lin_cold.impl == "pallas.linear_mxu"       # the roofline choice

    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 50.0)
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "ref.linear", 2.0)
    autotune.set_cache(c)
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "ref.linear"
    assert g.election_provenance["ref.linear"] == {"measured": 1}


def test_impl_report_shows_measured_provenance():
    """ISSUE acceptance: with a warm cache, SolModel.impl_report() shows
    elections sourced from measurements."""
    model = nn.mlp_8192(2, 32, 16, 4)
    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 3.0)
    autotune.set_cache(c)
    sol = optimize(model, (2, 16), backend="pallas_interpret")
    report = sol.impl_report(provenance=True)
    assert report["pallas.linear_mxu"]["sources"].get("measured", 0) >= 1

    autotune.set_cache(AutotuneCache())               # cold again
    sol_cold = optimize(model, (2, 16), backend="pallas_interpret")
    cold = sol_cold.impl_report(provenance=True)
    assert all("measured" not in e["sources"] for e in cold.values())


def test_mxu_matmul_elected_and_correct_on_pallas_backends():
    """ISSUE acceptance: the tiled Pallas matmul is the elected
    LINEAR/MATMUL flavour for MXU-aligned shapes on pallas_tpu (election)
    and pallas_interpret (election + execution parity at 1e-5, including a
    ragged-tail shape)."""
    for b, d_in, d_out in ((2, 128, 128), (3, 100, 65)):
        g, lin = _linear_graph(b, d_in, d_out)
        passes.elect_implementations(g, get_backend("pallas_tpu"))
        assert lin.impl == "pallas.linear_mxu", (b, d_in, d_out)

        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(
            rng.standard_normal((d_out, d_in)), jnp.float32)}
        x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
        ys = {}
        for bk in ("pallas_interpret", "xla"):
            g2, lin2 = _linear_graph(b, d_in, d_out)
            passes.elect_implementations(g2, get_backend(bk))
            ys[bk] = np.asarray(lower_graph(g2, get_backend(bk))(params, x))
        assert lin2.impl == "ref.linear"              # xla has no mxu
        np.testing.assert_allclose(ys["pallas_interpret"], ys["xla"],
                                   rtol=1e-5, atol=1e-5)


# -- calibration -----------------------------------------------------------------

def test_calibration_fit_recovers_coefficients():
    """Synthetic measurements generated from known coefficients are
    recovered by the non-negative least-squares fit."""
    from benchmarks.calibrate import fit
    a_true, b_true = 5e-12, 2e-10
    c = AutotuneCache()
    for m in (64, 128, 256, 512):
        flops = 2.0 * m ** 3
        nbytes = 3.0 * m * m * 4.0
        us = (a_true * flops + b_true * nbytes) * 1e6
        c.record("matmul", (m, m, m), "float32", "xla", "ref.matmul", us,
                 flops=flops, nbytes=nbytes)
    coeffs = fit(c)[("xla", "matmul")]
    assert coeffs["s_per_flop"] == pytest.approx(a_true, rel=1e-3)
    assert coeffs["s_per_byte"] == pytest.approx(b_true, rel=1e-3)
    assert coeffs["n"] == 4.0


def test_calibrated_cost_model_drives_cold_election():
    """Calibration coefficients apply when the exact op has no measurement:
    provenance flips from 'analytical' to 'calibrated'."""
    c = AutotuneCache()
    c.set_calibration("pallas_interpret", "linear",
                      {"s_per_flop": 1e-12, "s_per_byte": 1e-11, "n": 4.0})
    autotune.set_cache(c)
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "pallas.linear_mxu"            # same relative order
    assert g.election_provenance["pallas.linear_mxu"] == {"calibrated": 1}


# -- the autotune driver (tiny, through the dispatch table) ----------------------

def test_driver_measures_every_admissible_impl(tmp_path):
    """benchmarks.autotune times each dispatch-table candidate and records
    tuned configs plus calibration terms."""
    from benchmarks.autotune import tune
    cache = AutotuneCache()
    rows = tune("pallas_interpret", ("linear",), tiny=True,
                warmup=0, iters=1, cache=cache)
    names = {r[0] for r in rows}
    assert any("pallas.linear_mxu" in n for n in names)
    assert any("ref.linear" in n for n in names)
    got = cache.lookup("linear", (8, 64, 32), "float32", "pallas_interpret")
    assert got["pallas.linear_mxu"].config is not None   # tuned tile config
    assert got["pallas.linear_mxu"].flops > 0            # calibration terms


def test_driver_sweeps_registry_declared_tunables():
    """ISSUE acceptance: the sweep iterates whatever Tunable spaces the
    registry declares — attention blocks, DFP fusion sizing and the scan
    block all come back with a winning config, not just the matmul."""
    from benchmarks.autotune import tune
    cache = AutotuneCache()
    tune("pallas_interpret", ("attention", "fused", "rglru_scan"),
         tiny=True, warmup=0, iters=1, cache=cache)
    att = cache.lookup("attention", (1, 64, 2, 16), "float32",
                       "pallas_interpret")
    assert att["pallas.flash_attention"].config is not None
    fus = cache.lookup("fused", (64, 32), "float32", "pallas_interpret")
    assert fus["pallas.dfp_fused"].config is not None
    scan = cache.lookup("rglru_scan", (1, 16, 32), "float32",
                        "pallas_interpret")
    assert scan["pallas.rglru_scan"].config is not None


def test_measure_unpins_swept_config_when_impl_raises():
    """ISSUE satellite (regression): an impl raising mid-sweep must not
    leave the swept Tunable config pinned on the node — a stale pin would
    silently change what a later election or lowering executes.  Fails
    before the try/finally fix in core.measure.measure_impl_configs."""
    import types

    from repro.core.autotune import Tunable
    from repro.core.measure import measure_impl_configs

    _g, lin = _linear_graph()
    backend = get_backend("host_cpu")
    calls = []

    def exploding(node, vals, bk):
        calls.append(tuple(node.attrs.get("boom_block") or ()))
        if len(calls) >= 2:
            raise RuntimeError("kernel rejects this config")
        return vals[0]

    impl = types.SimpleNamespace(
        fn=exploding, tunable=Tunable("boom_block", lambda n, hw: []))

    with pytest.raises(RuntimeError):
        measure_impl_configs(lin, [jnp.ones((2, 16))], backend, impl,
                             [(8,), (16,), (32,)], warmup=0, iters=1)
    assert "boom_block" not in lin.attrs          # restored despite the raise
    assert calls == [(8,), (16,)]                 # raised on the second config

    # skip_errors=True keeps sweeping, reports the error per config, and
    # still restores the node
    calls.clear()
    out = measure_impl_configs(lin, [jnp.ones((2, 16))], backend, impl,
                               [(8,), (16,), (32,)], warmup=0, iters=1,
                               skip_errors=True)
    assert "boom_block" not in lin.attrs
    assert [m.error is None for m in out] == [True, False, False]
    assert all(m.us == float("inf") for m in out if m.error)


def test_sweep_node_restores_attrs_and_records_min_and_mean():
    """The real sweep leaves no pin behind and records both timing stats
    (us = min for elections, mean_us for figure-grade views)."""
    from repro.core.measure import sweep_node

    g, lin = _linear_graph(8, 64, 32)
    x = jnp.ones((8, 64), jnp.float32)
    w = jnp.ones((32, 64), jnp.float32)
    cache = AutotuneCache()
    out = sweep_node(lin, [x, w], get_backend("pallas_interpret"), cache,
                     warmup=0, iters=2)
    assert "mxu_block" not in lin.attrs
    got = cache.lookup("linear", (8, 64, 32), "float32", "pallas_interpret")
    for m in out:
        rec = got[m.impl]
        assert rec.mean_us >= rec.us > 0.0        # mean can never beat min
        assert rec.mean_us == m.mean_us


def test_time_call_is_min_of_individually_timed_iters(monkeypatch):
    """ISSUE satellite: election-grade timings use the min over iters (a
    hiccup inflates a mean but never a min); time_call_stats carries both."""
    from repro.core import measure

    ticks = iter([0.0, 30e-6, 1.0, 1.0 + 10e-6, 2.0, 2.0 + 20e-6])
    monkeypatch.setattr(measure.time, "perf_counter", lambda: next(ticks))
    t = measure.time_call_stats(lambda: 0, warmup=1, iters=3)
    assert t.min_us == pytest.approx(10.0)
    assert t.mean_us == pytest.approx(20.0)

    ticks = iter([0.0, 30e-6, 1.0, 1.0 + 10e-6, 2.0, 2.0 + 20e-6])
    assert measure.time_call(lambda: 0, warmup=1, iters=3) \
        == pytest.approx(10.0)


def test_verify_cache_roundtrip_with_attention_flip(tmp_path):
    """benchmarks.autotune --verify end to end: a tuned cache written to
    disk yields measured elections on reload, and the attention flip proof
    (cached block-size measurement flips the election, impl_report shows
    the pinned config) passes."""
    from benchmarks.autotune import tune, verify_cache
    path = str(tmp_path / "cache.json")
    cache = AutotuneCache()
    for ops in (("linear",), ("attention",)):
        tune("pallas_interpret", ops, tiny=True, warmup=0, iters=1,
             cache=cache)
    cache.save(path)
    assert verify_cache(path) == 0


# -- _EW_FLOPS calibration (perf_iter whole-model numbers) -----------------------

def test_ew_flops_fit_recovery():
    """ISSUE satellite: synthetic whole-model elementwise profiles generated
    from a known per-element cost are recovered by the fit, installing the
    fit changes the DFP cost terms, and degenerate data falls back to the
    nominal default."""
    k_true = 7.25
    samples = [(k_true * e, e) for e in (1e6, 4e6, 9e6)]
    assert passes.fit_ew_flops(samples) == pytest.approx(k_true)
    try:
        passes.calibrate_ew_flops(samples)
        assert passes.ew_flops() == pytest.approx(k_true)
        n = Node(OpKind.RELU, [ir.input_node((4, 8))], TensorSpec((4, 8)))
        flops, _streamed, _roundtrip = passes._node_cost_terms(n)
        assert flops == pytest.approx(k_true * 32)
    finally:
        passes.set_ew_flops(None)
    assert passes.ew_flops() == 5.0
    assert passes.fit_ew_flops([]) == 5.0
    assert passes.fit_ew_flops([(0.0, 0.0)]) == 5.0
