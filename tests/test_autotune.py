"""Autotune subsystem tests: cache round-trip + schema/atomicity guarantees,
shape-bucket canonicalization with nearest-bucket lookup, measured-first
election (provenance, config pinning, the roofline-contradicting flip), the
calibration fit, and the MXU matmul as the elected LINEAR/MATMUL flavour."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.core import autotune, ir, passes
from repro.core.autotune import AutotuneCache, Measurement, bucket_shape
from repro.core.executor import lower_graph
from repro.core.ir import Graph, Node, OpKind, TensorSpec
from repro.frontends import nn
from repro.frontends.optimize import optimize


@pytest.fixture(autouse=True)
def _fresh_cache():
    """Every test starts (and leaves the process) with a cold session cache.
    An explicit empty AutotuneCache, not set_cache(None): None means 'reset
    to default', which would re-read SOL_AUTOTUNE_CACHE from the env."""
    autotune.set_cache(AutotuneCache())
    yield
    autotune.set_cache(AutotuneCache())


def _linear_graph(b=2, d_in=16, d_out=32):
    x = ir.input_node((b, d_in), name="x")
    w = ir.param_node((d_out, d_in), name="w")
    lin = Node(OpKind.LINEAR, [x, w], TensorSpec((b, d_out)),
               attrs={"out_features": d_out})
    return Graph([x], [lin], {"w": w}), lin


# -- cache mechanics -----------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    """save → load preserves measurements, configs, and calibration."""
    path = str(tmp_path / "cache.json")
    c = AutotuneCache()
    c.record("matmul", (256, 256, 256), "float32", "pallas_tpu",
             "pallas.matmul_mxu", 12.5, config=(128, 128, 128),
             flops=2 * 256 ** 3, nbytes=3 * 256 * 256 * 4)
    c.record("matmul", (256, 256, 256), "float32", "pallas_tpu",
             "ref.matmul", 20.0)
    c.set_calibration("pallas_tpu", "matmul",
                      {"s_per_flop": 1e-14, "s_per_byte": 2e-12, "n": 2.0})
    c.save(path)
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))

    c2 = AutotuneCache.load(path)
    assert not c2.stale
    got = c2.lookup("matmul", (256, 256, 256), "float32", "pallas_tpu")
    assert got["pallas.matmul_mxu"].us == 12.5
    assert got["pallas.matmul_mxu"].config == (128, 128, 128)
    assert got["ref.matmul"].us == 20.0
    assert c2.calibration("pallas_tpu", "matmul")["s_per_flop"] == 1e-14


def test_stale_schema_ignored_not_misread(tmp_path):
    """A cache written by a different schema version comes back empty with
    stale=True — old files are never misinterpreted."""
    path = tmp_path / "old.json"
    path.write_text(json.dumps({
        "schema": autotune.SCHEMA_VERSION + 1,
        "entries": {"matmul|float32|xla|256x256x256":
                    {"ref.matmul": {"us": 1.0}}}}))
    c = AutotuneCache.load(str(path))
    assert c.stale
    assert len(c) == 0
    assert c.lookup("matmul", (256, 256, 256), "float32", "xla") == {}


def test_corrupt_file_yields_empty_cache(tmp_path):
    path = tmp_path / "torn.json"
    path.write_text('{"schema": 1, "entr')      # torn write simulation
    c = AutotuneCache.load(str(path))
    assert len(c) == 0 and not c.stale


def test_record_keeps_best_time():
    c = AutotuneCache()
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 9.0)
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 5.0,
             config=(32, 32, 32))
    c.record("matmul", (64, 64, 64), "float32", "xla", "ref.matmul", 7.0)
    m = c.lookup("matmul", (64, 64, 64), "float32", "xla")["ref.matmul"]
    assert m.us == 5.0 and m.config == (32, 32, 32)


def test_bucket_canonicalization_and_nearest_lookup():
    """Shapes bucket to nearest powers of two; unseen buckets resolve to the
    nearest same-rank bucket in log2-space."""
    assert bucket_shape((100, 70, 36)) == (128, 64, 32)
    c = AutotuneCache()
    c.record("matmul", (256, 256, 256), "float32", "xla", "ref.matmul", 3.0)
    c.record("matmul", (2048, 2048, 2048), "float32", "xla", "ref.matmul",
             90.0)
    # same bucket (250→256)
    assert c.lookup("matmul", (250, 260, 255), "float32", "xla")[
        "ref.matmul"].us == 3.0
    # unseen bucket (4096) → nearest is 2048
    assert c.lookup("matmul", (4096, 4096, 4096), "float32", "xla")[
        "ref.matmul"].us == 90.0
    # other backend/dtype/op stay isolated
    assert c.lookup("matmul", (256, 256, 256), "bfloat16", "xla") == {}
    assert c.lookup("matmul", (256, 256, 256), "float32", "host_cpu") == {}
    assert c.lookup("linear", (256, 256, 256), "float32", "xla") == {}


# -- measured election ----------------------------------------------------------

def test_cold_cache_falls_back_to_roofline():
    """ISSUE acceptance: a cold cache degrades gracefully to the analytical
    path — the MXU matmul wins on tier at equal roofline cost."""
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "pallas.linear_mxu"
    assert g.election_provenance["pallas.linear_mxu"] == {"analytical": 1}


def test_warm_cache_election_uses_measurement(tmp_path):
    """save → load → election: the measured entry drives the choice and the
    provenance says so."""
    path = str(tmp_path / "cache.json")
    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 4.0, config=(16, 128, 128))
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "ref.linear", 9.0)
    c.save(path)
    autotune.load_cache(path)

    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "pallas.linear_mxu"
    assert g.election_provenance["pallas.linear_mxu"] == {"measured": 1}
    # the winning measurement's tile config is pinned on the node
    assert lin.attrs["mxu_block"] == (16, 128, 128)


def test_reelection_clears_stale_tile_config():
    """A graph elected with a warm cache (pinned mxu_block) then re-elected
    cold must drop the stale tuned config — re-lowering on another backend
    or cache state is a supported flow."""
    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 4.0, config=(512, 256, 512))
    autotune.set_cache(c)
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.attrs["mxu_block"] == (512, 256, 512)

    autotune.set_cache(AutotuneCache())
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert "mxu_block" not in lin.attrs

    # a measured winner without a config also clears a prior pin
    lin.attrs["mxu_block"] = (512, 256, 512)
    c2 = AutotuneCache()
    c2.record("linear", (2, 16, 32), "float32", "pallas_interpret",
              "ref.linear", 1.0)
    autotune.set_cache(c2)
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "ref.linear" and "mxu_block" not in lin.attrs


def test_measured_entry_flips_roofline_choice():
    """ISSUE acceptance: a cache entry flips a flavour choice the roofline
    model would not make — ref.linear beats the MXU kernel only because the
    data says so."""
    g_cold, lin_cold = _linear_graph()
    passes.elect_implementations(g_cold, get_backend("pallas_interpret"))
    assert lin_cold.impl == "pallas.linear_mxu"       # the roofline choice

    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 50.0)
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "ref.linear", 2.0)
    autotune.set_cache(c)
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "ref.linear"
    assert g.election_provenance["ref.linear"] == {"measured": 1}


def test_impl_report_shows_measured_provenance():
    """ISSUE acceptance: with a warm cache, SolModel.impl_report() shows
    elections sourced from measurements."""
    model = nn.mlp_8192(2, 32, 16, 4)
    c = AutotuneCache()
    c.record("linear", (2, 16, 32), "float32", "pallas_interpret",
             "pallas.linear_mxu", 3.0)
    autotune.set_cache(c)
    sol = optimize(model, (2, 16), backend="pallas_interpret")
    report = sol.impl_report(provenance=True)
    assert report["pallas.linear_mxu"]["sources"].get("measured", 0) >= 1

    autotune.set_cache(AutotuneCache())               # cold again
    sol_cold = optimize(model, (2, 16), backend="pallas_interpret")
    cold = sol_cold.impl_report(provenance=True)
    assert all("measured" not in e["sources"] for e in cold.values())


def test_mxu_matmul_elected_and_correct_on_pallas_backends():
    """ISSUE acceptance: the tiled Pallas matmul is the elected
    LINEAR/MATMUL flavour for MXU-aligned shapes on pallas_tpu (election)
    and pallas_interpret (election + execution parity at 1e-5, including a
    ragged-tail shape)."""
    for b, d_in, d_out in ((2, 128, 128), (3, 100, 65)):
        g, lin = _linear_graph(b, d_in, d_out)
        passes.elect_implementations(g, get_backend("pallas_tpu"))
        assert lin.impl == "pallas.linear_mxu", (b, d_in, d_out)

        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(
            rng.standard_normal((d_out, d_in)), jnp.float32)}
        x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
        ys = {}
        for bk in ("pallas_interpret", "xla"):
            g2, lin2 = _linear_graph(b, d_in, d_out)
            passes.elect_implementations(g2, get_backend(bk))
            ys[bk] = np.asarray(lower_graph(g2, get_backend(bk))(params, x))
        assert lin2.impl == "ref.linear"              # xla has no mxu
        np.testing.assert_allclose(ys["pallas_interpret"], ys["xla"],
                                   rtol=1e-5, atol=1e-5)


# -- calibration -----------------------------------------------------------------

def test_calibration_fit_recovers_coefficients():
    """Synthetic measurements generated from known coefficients are
    recovered by the non-negative least-squares fit."""
    from benchmarks.calibrate import fit
    a_true, b_true = 5e-12, 2e-10
    c = AutotuneCache()
    for m in (64, 128, 256, 512):
        flops = 2.0 * m ** 3
        nbytes = 3.0 * m * m * 4.0
        us = (a_true * flops + b_true * nbytes) * 1e6
        c.record("matmul", (m, m, m), "float32", "xla", "ref.matmul", us,
                 flops=flops, nbytes=nbytes)
    coeffs = fit(c)[("xla", "matmul")]
    assert coeffs["s_per_flop"] == pytest.approx(a_true, rel=1e-3)
    assert coeffs["s_per_byte"] == pytest.approx(b_true, rel=1e-3)
    assert coeffs["n"] == 4.0


def test_calibrated_cost_model_drives_cold_election():
    """Calibration coefficients apply when the exact op has no measurement:
    provenance flips from 'analytical' to 'calibrated'."""
    c = AutotuneCache()
    c.set_calibration("pallas_interpret", "linear",
                      {"s_per_flop": 1e-12, "s_per_byte": 1e-11, "n": 4.0})
    autotune.set_cache(c)
    g, lin = _linear_graph()
    passes.elect_implementations(g, get_backend("pallas_interpret"))
    assert lin.impl == "pallas.linear_mxu"            # same relative order
    assert g.election_provenance["pallas.linear_mxu"] == {"calibrated": 1}


# -- the autotune driver (tiny, through the dispatch table) ----------------------

def test_driver_measures_every_admissible_impl(tmp_path):
    """benchmarks.autotune times each dispatch-table candidate, persists the
    cache, and a reloaded cache elects from the measurements."""
    from benchmarks.autotune import tune, verify_cache
    path = str(tmp_path / "cache.json")
    cache = AutotuneCache()
    rows = tune("pallas_interpret", ("linear",), tiny=True,
                warmup=0, iters=1, cache=cache)
    names = {r[0] for r in rows}
    assert any("pallas.linear_mxu" in n for n in names)
    assert any("ref.linear" in n for n in names)
    got = cache.lookup("linear", (8, 64, 32), "float32", "pallas_interpret")
    assert got["pallas.linear_mxu"].config is not None   # tuned tile config
    assert got["pallas.linear_mxu"].flops > 0            # calibration terms
    cache.save(path)
    assert verify_cache(path) == 0
