"""End-to-end behaviour tests: training improves loss across architecture
families; the optimizer/step machinery composes; HLO analysis is sane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticTokenDataset
from repro.distributed.steps import (StepOptions, init_train_state,
                                     make_train_step)
from repro.launch.mesh import make_debug_mesh
from repro.models import backbone as B


def _run_training(arch, steps=12, microbatch=1, compression="none"):
    cfg = get_smoke(arch)
    mesh = make_debug_mesh(1, 1)
    opts = StepOptions(remat=False, microbatch=microbatch,
                       grad_compression=compression, zero=False,
                       lr=3e-3, warmup=2, total_steps=steps)
    step_fn, _ = make_train_step(mesh, cfg, opts)
    state = init_train_state(cfg, opts, jax.random.PRNGKey(0))
    ds = SyntheticTokenDataset(DataConfig(seed=0, vocab=cfg.vocab,
                                          seq_len=32, global_batch=4))
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    losses = []
    with mesh:
        for step in range(steps):
            batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
    return losses


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-1.6b",
                                  "recurrentgemma-9b", "olmoe-1b-7b"])
def test_training_improves_loss(arch):
    losses = _run_training(arch)
    assert all(np.isfinite(losses))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


def test_microbatch_accumulation_consistent():
    """Grad accumulation (4 microbatches) tracks the single-batch step."""
    l1 = _run_training("qwen2-1.5b", steps=8, microbatch=1)
    l4 = _run_training("qwen2-1.5b", steps=8, microbatch=4)
    assert all(np.isfinite(l4))
    assert abs(l1[0] - l4[0]) < 0.2          # same init, same first loss-ish
    assert np.mean(l4[-2:]) < l4[0]


def test_bf16_grad_compression_trains():
    losses = _run_training("qwen2-1.5b", steps=8, compression="bf16")
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_hlo_analysis_counts_scan_trips():
    """The loop-aware analyzer must multiply while-body costs by the scan
    trip count (the builtin cost_analysis does not)."""
    from repro.launch.hlo_analysis import analyze

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    ws = jnp.zeros((7, 64, 64))
    x = jnp.zeros((8, 64))
    text = jax.jit(f).lower(ws, x).compile().as_text()
    res = analyze(text, 1)
    expected = 2 * 8 * 64 * 64 * 7            # 7 scanned matmuls
    assert res["flops_per_device"] >= expected * 0.99
    assert any(l["trips"] == 7 for l in res["loops"])


def test_adamw_decreases_quadratic():
    from repro.optim import AdamWConfig, adamw_update, init_opt_state
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, ocfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, ocfg,
                                        jnp.asarray(0.1))
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_checkpoint_resume_training(tmp_path):
    """Stop training mid-way, restore, continue — bitwise state shape
    integrity and loss continuity."""
    from repro.checkpoint import CheckpointManager
    cfg = get_smoke("qwen2-1.5b")
    mesh = make_debug_mesh(1, 1)
    opts = StepOptions(remat=False, zero=False, lr=1e-3, warmup=1,
                       total_steps=10)
    step_fn, _ = make_train_step(mesh, cfg, opts)
    state = init_train_state(cfg, opts, jax.random.PRNGKey(0))
    ds = SyntheticTokenDataset(DataConfig(seed=0, vocab=cfg.vocab,
                                          seq_len=16, global_batch=2))
    ckpt = CheckpointManager(str(tmp_path), interval=3)
    jitted = jax.jit(step_fn)
    with mesh:
        for step in range(6):
            batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
            state, m = jitted(state, batch)
            ckpt.maybe_save(step + 1, state, block=True)
    restored_step, restored = ckpt.restore_latest(
        jax.eval_shape(lambda: state))
    assert restored_step == 6
    np.testing.assert_allclose(
        np.asarray(restored["params"]["embed"], np.float32),
        np.asarray(state["params"]["embed"], np.float32))
    with mesh:
        batch = {k: jnp.asarray(v) for k, v in ds.batch(6).items()}
        state2, m2 = jitted(restored, batch)
    assert np.isfinite(float(m2["loss"]))
