import os

# tests run on the single real CPU device; ONLY launch/dryrun.py forces the
# 512-device host platform (before any jax import), never the test suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest


@pytest.fixture(autouse=True, scope="session")
def _cold_autotune_cache():
    """Pin an empty autotune cache for the whole suite: a developer's
    exported SOL_AUTOTUNE_CACHE must not flip elections inside tests
    (set_cache(None) would re-read the env var on the next get_cache)."""
    from repro.core import autotune
    autotune.set_cache(autotune.AutotuneCache())
    yield
    autotune.set_cache(None)
