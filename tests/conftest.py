import os

# tests run on the single real CPU device; ONLY launch/dryrun.py forces the
# 512-device host platform (before any jax import), never the test suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
