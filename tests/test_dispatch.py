"""Dispatch-table tests: per-op impl registration, the capability fallback
chain, the cost-based election pass, and host_cpu↔xla numerical parity —
the PR's 'a backend is a table of flavours, not executor edits' claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import (Backend, available_backends, get_backend,
                            register_backend, register_impl)
from repro.backends import registry as R
from repro.core import ir, passes
from repro.core.executor import lower_graph
from repro.core.ir import Graph, Node, OpKind, TensorSpec
from repro.frontends import nn
from repro.frontends.optimize import optimize


def _relu_graph():
    x = ir.input_node((2, 8), name="x")
    y = Node(OpKind.RELU, [x], x.spec)
    return Graph([x], [y], {}), y


# -- registration & fallback chain -------------------------------------------

def test_register_impl_overrides_fallback():
    """A tier-0 backend-specific impl beats the shared and reference tiers,
    and a later registration beats an earlier one."""
    bk = register_backend(dataclasses.replace(
        get_backend("xla"), name="test_override"))
    g, node = _relu_graph()
    assert bk.resolve(node).name == "ref.relu"

    marker = 7.5
    register_impl("test_override", OpKind.RELU,
                  lambda n, vals, backend: jnp.maximum(vals[0], 0.0) + marker,
                  name="test_override.relu_v1")
    assert bk.resolve(node).name == "test_override.relu_v1"
    y = lower_graph(g, bk)({}, jnp.array([[-1.0, 2.0] * 4] * 2))
    np.testing.assert_allclose(np.asarray(y)[0, 0], marker)   # -1 → 0 → +7.5

    register_impl("test_override", OpKind.RELU,
                  lambda n, vals, backend: jnp.maximum(vals[0], 0.0),
                  name="test_override.relu_v2")
    assert bk.resolve(node).name == "test_override.relu_v2"


def test_unregistered_op_falls_back_to_reference():
    """Ops without backend-specific or shared impls resolve to the reference
    tier on every backend — the chain never dead-ends."""
    for name in ("xla", "host_cpu", "pallas_interpret", "pallas_tpu"):
        bk = get_backend(name)
        _, node = _relu_graph()
        impl = bk.resolve(node)
        assert impl.tier == R.TIER_REFERENCE
        assert impl.name == "ref.relu"


def test_capability_gates_shared_impls():
    """The shared Pallas DFP kernel is admissible only for backends with the
    'pallas' capability; others compose (reference tier)."""
    body = [Node(OpKind.RELU, [], TensorSpec((4, 32)))]
    fused = Node(OpKind.FUSED, [ir.input_node((4, 32))], TensorSpec((4, 32)),
                 body=body)
    names = {b: [i.name for i in get_backend(b).candidates(fused)]
             for b in ("xla", "host_cpu", "pallas_interpret")}
    assert names["xla"] == ["ref.compose"]
    assert names["host_cpu"] == ["ref.compose"]
    assert names["pallas_interpret"] == ["pallas.dfp_fused", "ref.compose"]


def test_attention_reference_fallback_runs():
    """An op only the kernel subpackages know (no executor branch) lowers
    through its registered reference impl."""
    q = ir.input_node((2, 16, 4, 8), name="q")
    k = ir.input_node((2, 16, 4, 8), name="k")
    v = ir.input_node((2, 16, 4, 8), name="v")
    att = Node(OpKind.ATTENTION, [q, k, v], q.spec)
    g = Graph([q, k, v], [att], {})
    key = jax.random.PRNGKey(0)
    qa, ka, va = (jax.random.normal(kk, (2, 16, 4, 8))
                  for kk in jax.random.split(key, 3))
    y = lower_graph(g, get_backend("xla"))({}, qa, ka, va)
    assert np.asarray(y).shape == (2, 16, 4, 8)

    from repro.kernels.flash_attention.ref import flash_attention_ref
    ref = flash_attention_ref(
        qa.transpose(0, 2, 1, 3), ka.transpose(0, 2, 1, 3),
        va.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# -- election pass ------------------------------------------------------------

def test_election_annotates_every_node():
    g, _ = _relu_graph()
    g = passes.run_pipeline(g, get_backend("xla"))
    for n in g.topo():
        if n.op not in (OpKind.INPUT, OpKind.PARAM, OpKind.OUTPUT):
            assert n.impl, f"{n} not elected"
    assert sum(g.elections.values()) == g.stats()["elected"]


def test_election_prefers_streamed_dfp_kernel():
    """On a pallas-capable backend the cost model elects the depth-first
    kernel for supported fusion groups (streamed beats roundtrip bytes)."""
    model = nn.mlp_8192(2, 32, 16, 4)
    sol_p = optimize(model, (2, 16), backend="pallas_interpret")
    sol_x = optimize(model, (2, 16), backend="xla")
    assert any(k == "pallas.dfp_fused" for k in sol_p.impl_report())
    assert all(not k.startswith("pallas.") for k in sol_x.impl_report())


def test_foreign_tier0_annotation_rejected():
    """A tier-0 impl is private to its backend: a stale annotation pointing
    at another backend's kernel must not leak across re-lowering."""
    from repro.core.executor import _impl_for
    x = ir.input_node((2, 16), name="x")
    w = ir.param_node((8, 16), name="w")
    lin = Node(OpKind.LINEAR, [x, w], TensorSpec((2, 8)),
               attrs={"out_features": 8})
    assert not R.get_impl("host_cpu.linear_oi").admissible(
        get_backend("xla"), lin)
    lin.impl = "host_cpu.linear_oi"        # elected on host_cpu earlier
    assert _impl_for(lin, get_backend("xla")).name == "ref.linear"
    assert _impl_for(lin, get_backend("host_cpu")).name == "host_cpu.linear_oi"


def test_stale_election_falls_back_on_other_backend():
    """A graph elected for one backend re-lowers correctly on another: the
    executor drops inadmissible annotations and walks the chain."""
    model = nn.mlp_8192(2, 32, 16, 4)
    x = np.random.default_rng(3).standard_normal((2, 16)).astype(np.float32)
    g_p = optimize(model, (2, 16), backend="pallas_interpret")
    y_p = np.asarray(g_p(x))
    # re-lower the pallas-elected graph with the xla backend
    fn = jax.jit(lower_graph(g_p.graph, get_backend("xla")))
    params = {k: jnp.asarray(model.state_dict()[k]) for k in g_p.graph.params}
    y_x = np.asarray(fn(params, jnp.asarray(x)))
    np.testing.assert_allclose(y_p, y_x, rtol=1e-5, atol=1e-5)


# -- host_cpu backend ----------------------------------------------------------

def test_host_cpu_registered_with_own_hw():
    assert "host_cpu" in available_backends()
    bk = get_backend("host_cpu")
    assert bk.hw.name == "host_cpu"
    assert bk.linear_weight_layout == "oi"
    assert bk.conv_layout == "nchw"
    assert "pallas" not in bk.capabilities


def test_host_cpu_elects_its_overrides():
    sol = optimize(nn.small_cnn(), (2, 3, 16, 16), backend="host_cpu")
    report = sol.impl_report()
    assert "host_cpu.linear_oi" in report
    assert "host_cpu.conv2d_nchw" in report
    # DFP groups fall back to the composed reference flavour (no pallas)
    assert "ref.compose" in report


@pytest.mark.parametrize("builder,shape", [
    (nn.small_cnn, (2, 3, 16, 16)),          # Conv + DFP chains + Linear
    (lambda: nn.mlp_8192(3, 64, 32, 10), (2, 32)),
    (nn.depthwise_cnn, (2, 3, 16, 16)),
])
def test_host_cpu_parity_vs_xla(builder, shape):
    """ISSUE acceptance: host_cpu output matches xla to atol 1e-5 on graphs
    mixing Linear, Conv and DFP fusion groups."""
    model = builder()
    x = np.random.default_rng(1).standard_normal(shape).astype(np.float32)
    ys = {}
    for bk in ("xla", "host_cpu"):
        ys[bk] = np.asarray(optimize(model, shape, backend=bk)(x))
    np.testing.assert_allclose(ys["host_cpu"], ys["xla"],
                               rtol=1e-5, atol=1e-5)
