"""Optional-hypothesis shim.

A bare environment (no ``hypothesis``) used to die at collection with
ImportError in four test modules.  Import ``hypothesis``/``st`` from here
instead: when the real package is present you get it unchanged; when it is
absent, property tests degrade to individual skips (the strategy objects are
inert placeholders and ``@hypothesis.given`` swaps the test body for a
``pytest.skip``) while every example-based test in the module still runs.
"""
from __future__ import annotations

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Inert stand-in: every strategy constructor returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    class _Hypothesis:
        HealthCheck = _Strategies()

        @staticmethod
        def settings(*a, **k):
            return lambda fn: fn

        @staticmethod
        def assume(*a, **k):
            return True

        @staticmethod
        def given(*a, **k):
            def deco(fn):
                # zero-arg wrapper: hides the strategy params from pytest's
                # fixture resolution so the item collects and skips cleanly
                def skipper():
                    pytest.skip("hypothesis not installed")
                skipper.__name__ = fn.__name__
                skipper.__doc__ = fn.__doc__
                return skipper
            return deco

    st = _Strategies()
    hypothesis = _Hypothesis()
