"""Frontend tests: graph extraction fidelity, sol.optimize ==
framework-eager numerics (the paper's core correctness claim), offloading
modes, deployment artifacts."""
from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.frontends import deploy as D
from repro.frontends import nn
from repro.frontends.extract import extract
from repro.frontends.offload import device
from repro.frontends.optimize import optimize


@pytest.fixture(autouse=True)
def _native_mode():
    device.set("cpu", 0, mode="native")
    yield
    device.set("cpu", 0, mode="native")


def test_extract_mlp_structure():
    m = nn.mlp_8192(3, 64, 32, 10)
    g = extract(m, (2, 32))
    kinds = [n.op.value for n in g.topo() if n.op.value not in
             ("input", "param")]
    assert kinds.count("linear") == 3
    assert kinds.count("relu") == 2
    assert set(g.params) == {"0.weight", "0.bias", "2.weight", "2.bias",
                             "4.weight", "4.bias"}


@pytest.mark.parametrize("builder,shape", [
    (lambda: nn.mlp_8192(3, 64, 32, 10), (2, 32)),
    (nn.small_cnn, (2, 3, 16, 16)),
    (nn.depthwise_cnn, (2, 3, 16, 16)),
])
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_sol_matches_framework(builder, shape, backend):
    model = builder()
    x = np.random.default_rng(0).standard_normal(shape).astype(np.float32)
    y_ref = np.asarray(model(jnp.asarray(x)))
    sol = optimize(model, shape, backend=backend)
    y_sol = np.asarray(sol(x))
    np.testing.assert_allclose(y_sol, y_ref, rtol=2e-4, atol=2e-4)


def test_parameter_update_invalidates_offload_context():
    """The paper's context caching: params are re-staged only on change."""
    model = nn.mlp_8192(2, 32, 16, 4)
    sol = optimize(model, (1, 16))
    x = np.ones((1, 16), np.float32)
    y1 = np.asarray(sol(x))
    sd = model.state_dict()
    sd["0.weight"] = sd["0.weight"] * 2.0
    sol.load_state_dict(sd)                    # framework-side update
    y2 = np.asarray(sol(x))
    assert not np.allclose(y1, y2), "stale offload context"


def test_transparent_offload_host_roundtrip():
    model = nn.mlp_8192(2, 32, 16, 4)
    sol = optimize(model, (2, 16))
    device.set("cpu", 0, mode="transparent")
    x = np.random.randn(2, 16).astype(np.float32)
    y = sol(x)
    assert isinstance(y, np.ndarray)           # host output, host input
    y_ref = np.asarray(model(jnp.asarray(x)))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_deploy_roundtrip_and_independence():
    model = nn.small_cnn()
    sol = optimize(model, (1, 3, 16, 16))
    x = np.random.randn(1, 3, 16, 16).astype(np.float32)
    y_ref = np.asarray(sol(x))
    blob = D.deploy(sol, (1, 3, 16, 16))
    loaded = D.load(blob)
    y = np.asarray(loaded(jnp.asarray(x)))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_deployed_params_staged_exactly_once():
    """ISSUE 5 regression: DeployedModel must device-put its params ONCE at
    load (via runtime.packed.transfer), not re-upload host arrays on every
    call."""
    from repro.runtime import packed as P
    model = nn.mlp_8192(2, 32, 16, 4)
    sol = optimize(model, (1, 16))
    blob = D.deploy(sol, (1, 16))
    P.reset_transfer_stats()
    served = D.load(blob)
    assert served.staged_leaves == len(sol._params_for_call())
    after_load = dict(P.TRANSFER_STATS)
    assert after_load["packed_dmas"] + after_load["direct_dmas"] >= 1
    # staged buffers are device arrays, not host ndarrays
    leaves = jax.tree.leaves(served.params)
    assert leaves and all(isinstance(v, jax.Array) for v in leaves)
    x = jnp.ones((1, 16), jnp.float32)
    y1 = np.asarray(served(x))
    y2 = np.asarray(served(x))
    assert dict(P.TRANSFER_STATS) == after_load, \
        "params were re-staged after load"
    np.testing.assert_allclose(y1, y2)
    np.testing.assert_allclose(y1, np.asarray(sol(np.ones((1, 16),
                                                          np.float32))),
                               rtol=1e-5, atol=1e-5)


def test_export_fn_nested_pytree_roundtrip():
    """ISSUE 5 regression: the artifact format must round-trip NESTED dict
    params, not just the flat SolModel dict."""
    params = {
        "block": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                  "b": np.ones(3, np.float32)},
        "scale": np.float32(2.0),
    }

    def fn(p, x):
        return (x @ p["block"]["w"] + p["block"]["b"]) * p["scale"]

    blob = D.export_fn(fn, params,
                       jax.ShapeDtypeStruct((4, 2), jnp.float32))
    m = D.load(blob)
    assert set(m.params) == {"block", "scale"}
    assert set(m.params["block"]) == {"w", "b"}
    x = np.random.default_rng(0).standard_normal((4, 2)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(m(jnp.asarray(x))),
                               np.asarray(fn(params, x)),
                               rtol=1e-6, atol=1e-6)


def test_deployed_model_carries_election_metadata():
    model = nn.mlp_8192(2, 32, 16, 4)
    sol = optimize(model, (1, 16))
    loaded = D.load(D.deploy(sol, (1, 16)))
    assert loaded.impl_report() == sol.impl_report()
    assert loaded.impl_report(by_kind=True) == sol.impl_report(by_kind=True)
    live = sol.impl_report(provenance=True)
    dep = loaded.impl_report(provenance=True)
    assert {k: v["sources"] for k, v in dep.items()} \
        == {k: v["sources"] for k, v in live.items()}


_LAYER = st.sampled_from(["linear", "relu", "gelu", "ln"])


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(layers=st.lists(_LAYER, min_size=1, max_size=6),
                  seed=st.integers(0, 1000))
def test_random_models_property(layers, seed):
    """Property: for random Sequential models, SOL's optimized executable is
    numerically identical to framework-eager execution."""
    rng = np.random.default_rng(seed)
    mods, d = [], 24
    for l in layers:
        if l == "linear":
            d2 = int(rng.integers(8, 40))
            mods.append(nn.Linear(d, d2))
            d = d2
        elif l == "relu":
            mods.append(nn.ReLU())
        elif l == "gelu":
            mods.append(nn.GELU())
        else:
            mods.append(nn.LayerNorm(d))
    model = nn.Sequential(*mods)
    x = rng.standard_normal((3, 24)).astype(np.float32)
    y_ref = np.asarray(model(jnp.asarray(x)))
    sol = optimize(model, (3, 24))
    np.testing.assert_allclose(np.asarray(sol(x)), y_ref,
                               rtol=1e-4, atol=1e-4)


def test_programming_effort_loc_table():
    """The paper's Table 'programming effort': our backends must stay small
    (≤3000 LOC/backend in the paper; ours are far smaller because DFP
    codegen is shared — assert the invariant holds)."""
    from pathlib import Path
    import repro
    root = Path(repro.__file__).parent
    be = sum(len(p.read_text().splitlines())
             for p in (root / "backends").glob("*.py"))
    assert be < 3000
    fe = sum(len(p.read_text().splitlines())
             for p in (root / "frontends").glob("*.py"))
    assert fe < 3000   # paper: ≤2400 per frontend
