"""Per-architecture smoke tests (reduced same-family configs) + decode
consistency + recurrent-form equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import backbone as B

KEY = jax.random.PRNGKey(0)


def _batch(cfg, bsz=2, s=32):
    batch = {"tokens": jax.random.randint(KEY, (bsz, s), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (bsz, s), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.full((bsz, cfg.n_patches, cfg.d_model), 0.01)
    if cfg.frontend == "audio":
        batch["frames"] = jnp.full((bsz, cfg.enc_dec.enc_seq, cfg.d_model),
                                   0.01)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward(arch):
    """Instantiate the reduced config, run one forward: shapes + no NaNs."""
    cfg = get_smoke(arch)
    params = B.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = B.forward(cfg, params, batch)
    s = batch["tokens"].shape[1] + (cfg.n_patches if cfg.frontend == "vision"
                                    else 0)
    assert logits.shape == (2, s, cfg.vocab_padded)
    assert not np.isnan(np.asarray(logits)).any()
    loss, metrics = B.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One gradient step on CPU: finite grads, loss finite."""
    cfg = get_smoke(arch)
    params = B.init_params(cfg, KEY)
    batch = _batch(cfg, bsz=2, s=16)

    def loss(p):
        return B.loss_fn(cfg, p, batch)[0]

    l, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["stablelm-3b", "gemma2-9b",
                                  "recurrentgemma-9b", "rwkv6-1.6b",
                                  "olmoe-1b-7b", "whisper-tiny"])
def test_decode_matches_forward(arch):
    """Token-by-token decode with caches reproduces the parallel forward."""
    cfg = get_smoke(arch)
    params = B.init_params(cfg, KEY)
    bsz, s = 2, 8
    toks = jax.random.randint(KEY, (bsz, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    enc_out = None
    if cfg.frontend == "audio":
        batch["frames"] = jnp.full((bsz, cfg.enc_dec.enc_seq, cfg.d_model),
                                   0.01)
        enc_out = B.run_encoder(cfg, params, batch["frames"])
    full, _ = B.forward(cfg, params, batch)
    cache = B.init_cache(cfg, bsz, 16)
    lg = None
    for t in range(s):
        lg, cache = B.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                  jnp.asarray(t), enc_out=enc_out)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=5e-2, atol=5e-4)


def test_local_ring_cache_matches_full():
    """Griffin local attention: the window-sized ring cache must equal a
    full-length cache decode."""
    import dataclasses
    cfg = get_smoke("recurrentgemma-9b")
    cfg = dataclasses.replace(cfg, window=8)
    params = B.init_params(cfg, KEY)
    bsz, s = 1, 16
    toks = jax.random.randint(KEY, (bsz, s), 0, cfg.vocab)
    full, _ = B.forward(cfg, params, {"tokens": toks})
    cache = B.init_cache(cfg, bsz, cfg.window)   # ring = window slots
    for t in range(s):
        lg, cache = B.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                  jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-2, atol=5e-4)


def test_param_counts_match_assignment():
    """Full-size configs hit their published parameter classes."""
    expect = {
        "stablelm_3b": (2.5e9, 3.3e9),
        "command_r_plus_104b": (100e9, 108e9),
        "qwen2_1_5b": (1.3e9, 1.8e9),
        "gemma2_9b": (8.5e9, 10.5e9),
        "recurrentgemma_9b": (8.5e9, 10.5e9),
        "kimi_k2_1t_a32b": (0.95e12, 1.1e12),
        "olmoe_1b_7b": (6.5e9, 7.3e9),
        "rwkv6_1_6b": (1.4e9, 1.8e9),
        "internvl2_26b": (18e9, 21e9),   # LM backbone (ViT is a stub)
    }
    for arch, (lo, hi) in expect.items():
        n = B.count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:,},{hi:,}]"


def test_moe_active_params():
    cfg = get_config("kimi_k2_1t_a32b")
    na = B.count_active_params(cfg)
    assert 28e9 <= na <= 36e9       # "a32b"


def test_rwkv_chunked_equals_stepwise():
    from repro.models.recurrent import _wkv_chunked
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
    ks = jax.random.split(KEY, 5)
    shape = (2, 64, 2, 16)
    r, k, v = (jax.random.normal(ks[i], shape) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5)
    u = jax.random.normal(ks[4], (2, 16)) * 0.3
    s0 = jnp.zeros((2, 2, 16, 16))
    o1, s1 = _wkv_chunked(r, k, v, logw, u, s0)
    o2, s2 = rwkv6_scan_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-5)


def test_moe_routing_mass_conservation():
    """Property: with capacity ≥ demand, every token's top-k weights are
    fully applied (combine weights sum to ≈1 per token)."""
    import dataclasses
    from repro.models import layers as L
    cfg = get_smoke("olmoe_1b_7b")
    p = B.init_params(cfg, KEY)
    moe_p = p["macro"]["pos0"]["moe"]
    moe_p = jax.tree.map(lambda x: x[0], moe_p)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.5
    big_cap = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    out, aux = L.moe_apply(moe_p, x, big_cap)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0
