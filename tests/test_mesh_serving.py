"""Sharded-mesh serving: elected graphs under shard_map.

Three layers of coverage:

* **Parity** (subprocess, 8 forced host devices): the prefill, decode and
  plain-forward programs compiled on a 2×2 (data, model) mesh must match
  the single-device compile on shared weights at 1e-5 — TP column/row
  sharding, the psum at every row-parallel matmul, head-local attention
  and the KV-sharded decode caches all have to agree bit-for-bit-ish.
* **Per-shard autotune keys** (single device, hypothesis property): a
  measurement recorded under a mesh-tagged backend key
  (``Backend.cache_name`` = ``name@tag``) must NEVER be visible to a
  global-shape lookup under the plain backend name, and vice versa — a
  per-shard local shape divided out of a pow2 global shape lands in some
  other global bucket, so without the tag the nearest-bucket fallback
  would happily serve a flat-backend timing to a mesh election.
* **Provenance on the mesh** (subprocess): a strict-provenance SolServer
  on the mesh warms per-shard shapes, serves, and reports every
  served-kind election as 'measured' with zero exact-bucket violations.

The test process itself keeps 1 device (conftest pins JAX_PLATFORMS=cpu);
only the child processes force more, mirroring tests/test_moe_spmd.py.
"""
import dataclasses
import os
import subprocess
import sys

import pytest

from _hypo import hypothesis, st

_ENV_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
from repro.frontends.optimize import compile_graph, optimize
from repro.frontends.extract import extract_prefill, extract_decode
from repro.launch.serve import ServeConfig, build_lm
from repro.launch.mesh import make_debug_mesh

cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64, max_seq=32,
                  max_batch=4, slots=4)
m = build_lm(cfg)
rng = np.random.default_rng(0)
mesh = make_debug_mesh(data=2, model=2)

# plain forward
x = rng.standard_normal((2, 8, 32)).astype("float32")
ref = optimize(m, (2, 8, 32))(x)
shr = optimize(m, (2, 8, 32), mesh=mesh)(x)
d = float(np.max(np.abs(np.asarray(ref) - np.asarray(shr))))
assert d < 1e-5, f"forward diverged: {d}"
print("FORWARD PARITY OK", d)

# prefill: logits AND the kv rows that seed the cache slots
ref = compile_graph(m, extract_prefill(m, (2, 8, 32)), "xla")(x)
shr = compile_graph(m, extract_prefill(m, (2, 8, 32)), "xla", mesh=mesh)(x)
for i, (a, b) in enumerate(zip(ref, shr)):
    d = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
    assert d < 1e-5, f"prefill out[{i}] diverged: {d}"
print("PREFILL PARITY OK")

# decode: ragged lens, KV-sharded caches
gd = extract_decode(m, 2, 16, 32)
kv_shapes = [tuple(n.spec.shape) for n in gd.inputs[2:]]
xd = rng.standard_normal((2, 1, 32)).astype("float32")
lens = np.array([5, 9], np.int32)
caches = [rng.standard_normal(s).astype("float32") * 0.5 for s in kv_shapes]
ref = compile_graph(m, extract_decode(m, 2, 16, 32), "xla")(xd, lens, *caches)
shr = compile_graph(m, extract_decode(m, 2, 16, 32), "xla",
                    mesh=mesh)(xd, lens, *caches)
for i, (a, b) in enumerate(zip(ref, shr)):
    d = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
    assert d < 1e-5, f"decode out[{i}] diverged: {d}"
print("DECODE PARITY OK")
"""

_CHILD_SERVE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
from repro.core import autotune as AT
from repro.core.ir import OpKind
from repro.launch.serve import (SERVED_KINDS, ServeConfig, SolServer,
                                _smoke_workload)

AT.set_cache(AT.AutotuneCache())
cfg = ServeConfig(d_model=32, n_heads=2, n_layers=1, vocab=64, max_seq=32,
                  max_batch=4, slots=4, mesh=(2, 2))
server = SolServer(cfg, strict_provenance=True)
for p, g in _smoke_workload(cfg, 4, 4):
    server.submit(p, g)
counts = server.warm_autotune()
assert counts["nodes"] > 0 and counts["impls"] > 0, counts
s = server.run()
assert s["tokens"] > 0 and s["mesh"] == [2, 2], s

served = {k.value for k in SERVED_KINDS}
for key, sol in server._models.items():
    # the autotune keys this model elected from carry the mesh tag
    assert sol.backend.cache_name == "xla@data2model2", sol.backend.cache_name
    prov = sol.impl_report(provenance=True)
    for kind, impls in sol.impl_report(by_kind=True).items():
        if kind not in served:
            continue
        for name in impls:
            srcs = prov[name]["sources"]
            assert srcs and set(srcs) <= {"measured", "pinned"}, (
                key, kind, name, srcs)
    assert not server._exact_bucket_violations(sol), key

# elections keyed on PER-SHARD shapes: the decode q/k/v projections are
# head-local (H*hd/model = 32/2 = 16 features), not global
dk = next(k for k in server._models if k[0] == "decode")
g = server._models[dk].graph
mm_out = [n.spec.shape[-1] for n in g.topo() if n.op is OpKind.MATMUL]
assert 16 in mm_out, mm_out
# ...and the batch dim is data-split: bucket batch / 2 locally
assert all(n.spec.shape[0] == dk[1] // 2 for n in g.topo()
           if n.op is OpKind.DECODE_ATTENTION), dk
server.close()
print("MESH SERVE PROVENANCE OK")
"""


def _run_child(src: str) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = _ENV_SRC
    r = subprocess.run([sys.executable, "-c", src], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, (f"stdout:\n{r.stdout}\n"
                               f"stderr:\n{r.stderr[-2000:]}")
    return r.stdout


@pytest.mark.slow
def test_mesh_parity_prefill_decode():
    out = _run_child(_CHILD_PARITY)
    assert "FORWARD PARITY OK" in out
    assert "PREFILL PARITY OK" in out
    assert "DECODE PARITY OK" in out


@pytest.mark.slow
def test_mesh_serving_strict_provenance():
    out = _run_child(_CHILD_SERVE)
    assert "MESH SERVE PROVENANCE OK" in out


# ---------------------------------------------------------------------------
# single-device: mesh validation + per-shard cache keys
# ---------------------------------------------------------------------------

def test_make_debug_mesh_validates_device_count():
    """A short device slice must raise with the XLA_FLAGS hint, never build
    a silently smaller mesh (satellite fix)."""
    import jax

    from repro.launch.mesh import make_debug_mesh
    have = len(jax.devices())
    with pytest.raises(RuntimeError, match="XLA_FLAGS"):
        make_debug_mesh(data=have + 1, model=1)


def test_mesh_backend_tags_cache_key():
    import jax

    from repro.backends import get_backend
    from repro.distributed.sharding import mesh_backend
    from repro.launch.mesh import make_debug_mesh
    bk = get_backend("xla")
    assert bk.cache_name == bk.name            # single device: unchanged
    mesh = make_debug_mesh(data=1, model=1)
    mk = mesh_backend(bk, mesh)
    assert mk.name == bk.name                  # dispatch matching unchanged
    assert mk.cache_name == "xla@data1model1"  # cache keys qualified


def test_per_shard_keys_never_hit_global_entries():
    """The collision the tag exists to prevent, concretely: a (64,) local
    shape divided out of a (128,) global shape IS the (64,) global bucket;
    with the tag, neither direction of lookup crosses over — not even via
    the nearest-bucket fallback."""
    from repro.backends import get_backend
    from repro.core import autotune as AT
    bk = get_backend("xla")
    mk = dataclasses.replace(bk, shard_tag="data2model2")
    cache = AT.AutotuneCache()
    cache.record("linear", (8, 64, 64), "float32", mk.cache_name,
                 "pallas.matmul", 5.0)
    cache.record("linear", (8, 64, 64), "float32", bk.cache_name,
                 "xla.linear", 9.0)
    shard_hits = cache.lookup("linear", (8, 64, 64), "float32",
                              mk.cache_name)
    global_hits = cache.lookup("linear", (8, 64, 64), "float32",
                               bk.cache_name)
    assert set(shard_hits) == {"pallas.matmul"}
    assert set(global_hits) == {"xla.linear"}
    # nearest-bucket fallback also stays within the tagged keyspace
    assert set(cache.lookup("linear", (4, 64, 64), "float32",
                            mk.cache_name)) == {"pallas.matmul"}
    assert not cache.lookup("attention", (8, 64, 64), "float32",
                            bk.cache_name)


@hypothesis.given(
    op=st.sampled_from(["linear", "matmul", "attention",
                        "decode_attention"]),
    shape=st.lists(st.integers(1, 1024), min_size=1, max_size=4),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    data=st.integers(1, 16),
    model=st.integers(1, 16),
)
@hypothesis.settings(max_examples=80, deadline=None)
def test_hypothesis_per_shard_and_global_keys_disjoint(op, shape, dtype,
                                                       data, model):
    """Property: for ANY op/shape/dtype and ANY mesh factorization, an
    entry recorded under the mesh-tagged backend key is invisible to the
    untagged lookup and vice versa — per-shard bucket keys cannot collide
    with global-shape keys by construction (distinct backend component),
    independent of how local shapes alias global pow2 buckets."""
    if (data, model) == (1, 1):
        return                                  # no tag — nothing to test
    from repro.backends import get_backend
    from repro.core import autotune as AT
    bk = get_backend("xla")
    mk = dataclasses.replace(bk, shard_tag=f"data{data}model{model}")
    assert mk.cache_name != bk.cache_name
    shape = tuple(shape)
    cache = AT.AutotuneCache()
    cache.record(op, shape, dtype, mk.cache_name, "impl.shard", 1.0)
    assert not cache.lookup(op, shape, dtype, bk.cache_name)
    assert not cache.has_bucket(op, shape, dtype, bk.cache_name)
    # the mirror direction: global entries stay invisible to shard lookups
    cache2 = AT.AutotuneCache()
    cache2.record(op, shape, dtype, bk.cache_name, "impl.global", 1.0)
    assert not cache2.lookup(op, shape, dtype, mk.cache_name)
    assert not cache2.has_bucket(op, shape, dtype, mk.cache_name)
