"""Serving-fleet tests: router + watcher-driven replica lifecycle.

Covers the PR 9 acceptance surface: a mid-stream replica kill on a
3-replica fleet completes every request with token output identical to an
undisturbed single-replica run (re-queue determinism); a monitor-driven
evict → respawn re-enters strict-provenance serving WITHOUT re-measuring
(the warmed autotune cache is process-wide, keyed on the mesh-tagged
backend name); respawn goes through ``runtime/failures.run_with_restart``
(an injected bring-up failure restores params and retries); one-off step
clock spikes do not evict (join grace + spike clip); and admission
pressure scales the fleet up and back down.
"""
import numpy as np
import pytest

from repro.core import autotune as AT
from repro.frontends.offload import device
from repro.launch.fleet import FleetConfig, SolFleet
from repro.launch.serve import SamplingParams, ServeConfig, build_lm
from repro.runtime import FailureSimulator


def tiny_cfg(**kw) -> ServeConfig:
    base = dict(d_model=32, n_heads=2, n_layers=1, vocab=64, max_seq=32,
                max_batch=4, slots=6, backend="xla")
    base.update(kw)
    return ServeConfig(**base)


def workload(cfg, n, gen=4, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab, int(rng.integers(4, 12)),
                          dtype=np.int32), gen,
             SamplingParams(temperature=0.8, seed=1000 + i))
            for i in range(n)]


@pytest.fixture(autouse=True)
def _native_mode_and_local_cache():
    device.set("cpu", 0, mode="native")
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())
    yield
    AT.set_cache(prev)
    device.set("cpu", 0, mode="native")


# ---------------------------------------------------------------------------
# kill → re-queue → token identity
# ---------------------------------------------------------------------------

def test_fleet_kill_midstream_token_identical():
    """Kill the busiest replica mid-stream: every request completes (the
    dead replica's in-flight work re-queues with its original sampling
    seeds) and the output is token-identical to an undisturbed
    single-replica run on the same weights."""
    cfg = tiny_cfg()
    model = build_lm(cfg)
    work = workload(cfg, 12)

    fleet = SolFleet(cfg, FleetConfig(n_replicas=3), model=model)
    reqs = [fleet.submit(p, g, sampling=sp) for p, g, sp in work]
    fleet.tick()
    fleet.tick()
    killed = fleet.kill()
    s = fleet.run()
    fleet.close()
    assert all(r.done for r in reqs)
    assert s["requeued"] >= 1 and s["kills"] == 1 and s["respawns"] == 1
    # replica ids are never reused: the respawn is a NEW member
    assert killed not in {ev.get("replica") for ev in fleet.events
                          if ev["event"] == "respawn"}
    assert sum(r.requeues for r in reqs) == s["requeued"]

    base = SolFleet(cfg, FleetConfig(n_replicas=1), model=model)
    breqs = [base.submit(p, g, sampling=sp) for p, g, sp in work]
    base.run()
    base.close()
    assert [r.generated for r in reqs] == [b.generated for b in breqs]


def test_fleet_respawn_goes_through_run_with_restart():
    """A respawn bring-up failure (injected via ``respawn_sim``) takes the
    checkpoint-restore path inside ``run_with_restart`` and retries — the
    replacement still comes up and the fleet completes."""
    cfg = tiny_cfg()
    fleet = SolFleet(cfg, FleetConfig(n_replicas=2),
                     respawn_sim=FailureSimulator(fail_at_steps=[0]))
    reqs = [fleet.submit(p, g, sampling=sp)
            for p, g, sp in workload(cfg, 6)]
    fleet.tick()
    fleet.kill()
    fleet.run()
    fleet.close()
    assert all(r.done for r in reqs)
    respawns = [ev for ev in fleet.events if ev["event"] == "respawn"]
    assert len(respawns) == 1 and respawns[0]["restarts"] == 1


# ---------------------------------------------------------------------------
# watcher: evict → respawn without re-measuring
# ---------------------------------------------------------------------------

def test_monitor_evict_respawns_without_rewarming(monkeypatch):
    """A sustained straggler is drained, evicted and respawned by the
    watcher; the respawned replica re-enters STRICT-provenance serving
    without a single new measurement (the warmed autotune cache is
    process-wide) — any post-warm sweep call fails the test."""
    from repro.core import measure

    cfg = tiny_cfg()
    fleet_cfg = FleetConfig(n_replicas=3, warmup_steps=2, join_grace=0,
                            spike_clip=0.0, drain_cooldown=2,
                            drain_grace=4)

    def slow_replica_0(rep, dt):
        return 100.0 if rep.id == 0 else 1.0

    fleet = SolFleet(cfg, fleet_cfg, strict_provenance=True,
                     step_time_fn=slow_replica_0)
    reqs = [fleet.submit(p, g, sampling=sp)
            for p, g, sp in workload(cfg, 16, gen=6)]
    fleet.warm_autotune()

    def no_more_measuring(*a, **kw):
        raise AssertionError("respawn re-measured: sweep_node called "
                             "after warm_autotune")
    monkeypatch.setattr(measure, "sweep_node", no_more_measuring)

    s = fleet.run()
    fleet.close()
    assert all(r.done for r in reqs)
    assert s["evicted"] >= 1 and s["respawns"] >= 1
    assert 0 not in fleet.replicas         # the straggler is gone
    evs = [ev["event"] for ev in fleet.events if ev.get("replica") == 0]
    assert "drain" in evs and "evict" in evs


def test_one_off_spike_does_not_evict():
    """Join grace plus the spike clip: a single 1000× step-clock spike on
    one replica (a bucket compile, a GC pause) must not drain or evict
    it — only SUSTAINED slowness may."""
    cfg = tiny_cfg()
    spiked = []

    def spike_once(rep, dt):
        # fire on a post-grace serving step, so the spike is actually
        # recorded (grace steps never reach the monitor)
        if rep.id == 0 and rep.serving_steps >= 2 and not spiked:
            spiked.append(rep.id)
            return 1000.0
        return 1.0

    fleet = SolFleet(cfg, FleetConfig(n_replicas=3, join_grace=1,
                                      warmup_steps=2),
                     step_time_fn=spike_once)
    reqs = [fleet.submit(p, g, sampling=sp)
            for p, g, sp in workload(cfg, 16, gen=6)]
    s = fleet.run()
    fleet.close()
    assert all(r.done for r in reqs)
    assert spiked == [0]                   # the spike did happen
    assert s["drained"] == 0 and s["evicted"] == 0 and s["respawns"] == 0


# ---------------------------------------------------------------------------
# autoscaling
# ---------------------------------------------------------------------------

def test_admission_pressure_scales_up_then_down():
    cfg = tiny_cfg(max_batch=2, slots=3)
    fleet = SolFleet(cfg, FleetConfig(n_replicas=1, min_replicas=1,
                                      max_replicas=3, scale_up_ticks=2,
                                      scale_down_ticks=3))
    reqs = [fleet.submit(p, g, sampling=sp)
            for p, g, sp in workload(cfg, 30)]
    fleet.run()
    assert all(r.done for r in reqs)
    assert fleet.stats["scale_ups"] >= 1 and len(fleet.replicas) >= 2
    for _ in range(20):                    # sustained empty queue
        fleet.tick()
    fleet.close()
    assert fleet.stats["scale_downs"] >= 1
    assert fleet._desired < fleet.stats["scale_ups"] + 1 or \
        fleet._desired == fleet.fleet_cfg.min_replicas


def test_fleet_config_validates_sizing():
    with pytest.raises(ValueError):
        FleetConfig(n_replicas=2, min_replicas=3)
    with pytest.raises(ValueError):
        FleetConfig(n_replicas=5, max_replicas=4)
    with pytest.raises(ValueError):
        FleetConfig(n_replicas=0)
