"""SPMD-vs-dense MoE equivalence: the shard_map expert-parallel path must
compute exactly what the single-device dense path computes.

Runs in a subprocess with 8 forced host devices (the test process itself
keeps 1 device; only launch/dryrun and this child may force more)."""
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import layers as L
from repro.models import backbone as B
from repro.distributed import ctx

cfg = get_smoke("olmoe_1b_7b")
key = jax.random.PRNGKey(0)
params = B.init_params(cfg, key)
moe_p = jax.tree.map(lambda x: x[0], params["macro"]["pos0"]["moe"])
x = (jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model)) * 0.5
     ).astype(jnp.float32)

dense_out, dense_aux = L._moe_apply_dense(moe_p, x, cfg.moe)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh, ctx.use_mesh(mesh):
    f = jax.jit(lambda p, xx: L._moe_apply_shard_map(p, xx, cfg.moe, mesh))
    spmd_out, spmd_aux = f(moe_p, x)

d = float(jnp.abs(dense_out - spmd_out).max())
da = abs(float(dense_aux) - float(spmd_aux))
print(f"out diff {d:.3e} aux diff {da:.3e}")
assert d < 2e-2, f"out mismatch {d}"
# aux is a per-group load-balance estimator; dp sharding partitions tokens
# into different groups, so only approximate agreement is expected
assert da < 5e-2, f"aux mismatch {da}"
# grads agree too
g1 = jax.grad(lambda p: jnp.sum(L._moe_apply_dense(p, x, cfg.moe)[0] ** 2))(moe_p)
with mesh, ctx.use_mesh(mesh):
    g2 = jax.jit(jax.grad(lambda p: jnp.sum(
        L._moe_apply_shard_map(p, x, cfg.moe, mesh)[0] ** 2)))(moe_p)
for k in g1:
    dd = float(jnp.abs(g1[k].astype(jnp.float32) -
                       g2[k].astype(jnp.float32)).max())
    scale = float(jnp.abs(g1[k].astype(jnp.float32)).max()) + 1e-6
    assert dd / scale < 5e-2, f"grad {k} mismatch {dd} (scale {scale})"
print("GRADS OK")
"""


@pytest.mark.slow
def test_moe_shard_map_equals_dense():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-2000:]}"
    assert "GRADS OK" in r.stdout
