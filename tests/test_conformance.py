"""Cross-backend conformance matrix (the 'Mind the Gap' lesson: backends
that silently diverge numerically are worse than backends that fail).

For every OpKind with a kernel family, the matrix runs **every impl the
dispatch table admits** — per backend (incl. ``host_cpu`` and
``pallas_interpret``) × dtype (f32/bf16) — against the family's ``ref.py``
oracle, under the single documented tolerance table below.  Impls that
declare a ``Tunable`` are additionally run at **every config in their tune
space**: a tuned config is a pure perf knob and must never change numerics.

CI runs this file standalone with ``--junitxml`` so the matrix ships as an
artifact next to the BENCH/cache series.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backends import get_backend
from repro.backends import registry as R
from repro.core import ir
from repro.core.ir import Node, OpKind, TensorSpec

BACKENDS = ("xla", "host_cpu", "pallas_interpret")
DTYPES = ("float32", "bfloat16")

# The documented per-(op, dtype) tolerance table: (rtol, atol) applied to
# every impl and every tuned config of that op.  f32 pins kernels to the
# oracle at 1e-5 (1e-4 for the recurrences, whose long dependency chains
# reorder summation); bf16 bounds follow the ~3 decimal digits the format
# carries, with extra headroom for the state-matrix accumulation in rwkv6.
TOLERANCE = {
    "linear":     {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "matmul":     {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "attention":  {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "attention_tp_shard": {"float32": (1e-5, 1e-5),
                           "bfloat16": (3e-2, 3e-2)},
    "decode_attention": {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "rglru_scan": {"float32": (1e-4, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "rwkv6_scan": {"float32": (1e-4, 1e-5), "bfloat16": (5e-2, 5e-2)},
    "fused":      {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "avgpool":    {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
    "conv2d":     {"float32": (1e-5, 1e-5), "bfloat16": (3e-2, 3e-2)},
}

_RNG = np.random.default_rng(0)


def _arr(shape, dtype, scale=1.0):
    return jnp.asarray(_RNG.standard_normal(shape) * scale).astype(dtype)


def _case_linear(dtype):
    from repro.kernels.matmul.ref import matmul_ref
    x, w = _arr((4, 32), dtype), _arr((16, 32), dtype)   # w stored (out, in)
    node = Node(OpKind.LINEAR,
                [ir.input_node((4, 32), dtype),
                 ir.param_node((16, 32), dtype, name="w")],
                TensorSpec((4, 16), dtype), attrs={"out_features": 16})
    return node, [x, w], matmul_ref(x, w.T)


def _case_matmul(dtype):
    from repro.kernels.matmul.ref import matmul_ref
    x, w = _arr((12, 40), dtype), _arr((40, 24), dtype)
    node = Node(OpKind.MATMUL,
                [ir.input_node((12, 40), dtype),
                 ir.input_node((40, 24), dtype)],
                TensorSpec((12, 24), dtype))
    return node, [x, w], matmul_ref(x, w)


def _case_attention(dtype):
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b, s, h, hd = 1, 64, 2, 16
    q, k, v = (_arr((b, s, h, hd), dtype) for _ in range(3))
    node = Node(OpKind.ATTENTION,
                [ir.input_node((b, s, h, hd), dtype) for _ in range(3)],
                TensorSpec((b, s, h, hd), dtype), attrs={"causal": True})
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    return node, [q, k, v], ref


def _case_attention_tp_shard(dtype):
    """The PER-SHARD attention problem a ``model=2`` mesh shard executes
    for the ``_case_attention`` family: heads are split across the model
    axis, so each shard runs the same kernel on h=1 of the 2-head global
    problem (see distributed/sharding.py).  Keeping this in the matrix pins
    every attention impl on the head-local shapes the sharded serving path
    actually dispatches — which sit in different autotune buckets than the
    global shapes."""
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b, s, h, hd = 1, 64, 1, 16            # h = 2 heads / model axis of 2
    q, k, v = (_arr((b, s, h, hd), dtype) for _ in range(3))
    node = Node(OpKind.ATTENTION,
                [ir.input_node((b, s, h, hd), dtype) for _ in range(3)],
                TensorSpec((b, s, h, hd), dtype), attrs={"causal": True})
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    return node, [q, k, v], ref


def _case_decode_attention(dtype):
    """One query token vs a ragged KV cache; the oracle is pinned to the
    full causal re-forward path by the cross-check in the family's ref.py
    (and by tests/test_serving.py's decode-vs-reforward parity)."""
    from repro.kernels.decode_attention.ref import decode_attention_ref
    b, s, h, kv, hd = 3, 24, 4, 2, 16
    q = _arr((b, 1, h, hd), dtype)
    k, v = _arr((b, s, kv, hd), dtype), _arr((b, s, kv, hd), dtype)
    k_new, v_new = _arr((b, 1, kv, hd), dtype), _arr((b, 1, kv, hd), dtype)
    lens = jnp.asarray([0, 7, s], jnp.int32)      # empty / ragged / full
    node = Node(OpKind.DECODE_ATTENTION,
                [ir.input_node((b, 1, h, hd), dtype),
                 ir.input_node((b, s, kv, hd), dtype),
                 ir.input_node((b, s, kv, hd), dtype),
                 ir.input_node((b, 1, kv, hd), dtype),
                 ir.input_node((b, 1, kv, hd), dtype),
                 ir.input_node((b,), "int32")],
                TensorSpec((b, 1, h, hd), dtype))
    ref = decode_attention_ref(q[:, 0], k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), k_new[:, 0],
                               v_new[:, 0], lens)[:, None]
    return node, [q, k, v, k_new, v_new, lens], ref


def _case_rglru_scan(dtype):
    from repro.kernels.rglru_scan.ref import rglru_scan_ref
    b, t, d = 2, 24, 32
    a = jax.nn.sigmoid(_arr((b, t, d), "float32")).astype(dtype)
    bb, h0 = _arr((b, t, d), dtype, 0.1), _arr((b, d), dtype, 0.1)
    node = Node(OpKind.RGLRU_SCAN,
                [ir.input_node((b, t, d), dtype),
                 ir.input_node((b, t, d), dtype),
                 ir.input_node((b, d), dtype)],
                TensorSpec((b, t, d), dtype))
    return node, [a, bb, h0], rglru_scan_ref(a, bb, h0)[0]


def _case_rwkv6_scan(dtype):
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
    b, t, h, hd = 1, 16, 2, 8
    r, k, v = (_arr((b, t, h, hd), dtype, 0.5) for _ in range(3))
    logw = (-jnp.exp(_arr((b, t, h, hd), "float32", 0.5))).astype(dtype)
    u = _arr((h, hd), dtype, 0.3)
    s0 = jnp.zeros((b, h, hd, hd), dtype)
    node = Node(OpKind.RWKV6_SCAN,
                [ir.input_node((b, t, h, hd), dtype) for _ in range(4)]
                + [ir.input_node((h, hd), dtype),
                   ir.input_node((b, h, hd, hd), dtype)],
                TensorSpec((b, t, h, hd), dtype))
    return node, [r, k, v, logw, u, s0], rwkv6_scan_ref(r, k, v, logw,
                                                        u, s0)[0]


def _case_fused(dtype):
    from repro.kernels.dfp_fused.program import encode_program
    from repro.kernels.dfp_fused.ref import dfp_fused_ref
    rows, d = 24, 32
    spec = TensorSpec((rows, d), dtype)
    x = ir.input_node((rows, d), dtype, name="x")
    bias = ir.param_node((d,), dtype, name="bias")
    gain = ir.param_node((d,), dtype, name="gain")
    g = Node(OpKind.GELU, [x], spec)
    ba = Node(OpKind.BIAS_ADD, [g, bias], spec)
    a = Node(OpKind.ADD, [ba, x], spec)
    rn = Node(OpKind.RMSNORM, [a, gain], spec)
    node = Node(OpKind.FUSED, [x, bias, gain], spec, attrs={"length": 4},
                name="fused[gelu+bias+add+rmsnorm]", body=[g, ba, a, rn])
    vals = [_arr((rows, d), dtype), _arr((d,), dtype, 0.1),
            (jnp.ones((d,)) * 1.1).astype(dtype)]
    env = {id(i): v for i, v in zip(node.inputs, vals)}
    prog, operands = encode_program(node, env)
    ref = dfp_fused_ref(prog, operands, (rows, d), dtype)
    return node, vals, ref


def _case_avgpool(dtype):
    from repro.kernels.avgpool.ref import avgpool_ref
    x = _arr((1, 4, 12, 12), dtype)
    node = Node(OpKind.AVGPOOL, [ir.input_node((1, 4, 12, 12), dtype)],
                TensorSpec((1, 4, 10, 10), dtype),
                attrs={"kernel": 3, "stride": 1})
    return node, [x], avgpool_ref(x, 3, 3)


def _case_conv2d(dtype):
    x, w = _arr((1, 3, 8, 8), dtype), _arr((4, 3, 3, 3), dtype)
    node = Node(OpKind.CONV2D,
                [ir.input_node((1, 3, 8, 8), dtype),
                 ir.param_node((4, 3, 3, 3), dtype, name="w")],
                TensorSpec((1, 4, 8, 8), dtype),
                attrs={"stride": 1, "padding": 1, "out_channels": 4,
                       "groups": 1})
    ref = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return node, [x, w], ref


CASES = {
    "linear": _case_linear,
    "matmul": _case_matmul,
    "attention": _case_attention,
    "attention_tp_shard": _case_attention_tp_shard,
    "decode_attention": _case_decode_attention,
    "rglru_scan": _case_rglru_scan,
    "rwkv6_scan": _case_rwkv6_scan,
    "fused": _case_fused,
    "avgpool": _case_avgpool,
    "conv2d": _case_conv2d,
}


@pytest.mark.parametrize("backend_name", BACKENDS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("op", sorted(CASES))
def test_conformance(op, dtype, backend_name):
    """Every admissible impl of (op, backend, dtype) — and every tuned
    config in its declared tune space — matches the family's ref.py oracle
    under the TOLERANCE table."""
    backend = get_backend(backend_name)
    node, vals, ref = CASES[op](dtype)
    cands = R.candidates(backend, node)
    assert cands, f"dispatch table admits nothing for {op} on {backend_name}"
    rtol, atol = TOLERANCE[op][dtype]
    ref32 = np.asarray(ref, np.float32)
    ran = 0
    for impl in cands:
        configs = [None]
        if impl.tunable is not None:
            space = impl.tunable.tune_space(node, backend.hw)
            if space:
                configs = space
        for cfg in configs:
            if impl.tunable is not None:
                impl.tunable.bind_config(node, cfg)
            out = impl.fn(node, list(vals), backend)
            assert out.dtype == jnp.dtype(dtype), (impl.name, out.dtype)
            np.testing.assert_allclose(
                np.asarray(out, np.float32), ref32, rtol=rtol, atol=atol,
                err_msg=f"{impl.name} cfg={cfg} on {backend_name}/{dtype}")
            ran += 1
        if impl.tunable is not None:
            impl.tunable.bind_config(node, None)
    assert ran >= len(cands)


def test_matrix_covers_every_kernel_family():
    """The matrix must not silently drop an OpKind that has a registered
    non-reference impl — extending the dispatch table forces a conformance
    entry (or an explicit exemption here)."""
    R._load_entry_points()
    case_kinds = {
        "linear": OpKind.LINEAR, "matmul": OpKind.MATMUL,
        "attention": OpKind.ATTENTION,
        "attention_tp_shard": OpKind.ATTENTION,
        "decode_attention": OpKind.DECODE_ATTENTION,
        "rglru_scan": OpKind.RGLRU_SCAN,
        "rwkv6_scan": OpKind.RWKV6_SCAN, "fused": OpKind.FUSED,
        "avgpool": OpKind.AVGPOOL, "conv2d": OpKind.CONV2D,
    }
    assert set(case_kinds) == set(CASES)
    have = {op for (_b, op) in R._BACKEND_IMPLS} | set(R._SHARED_IMPLS)
    missing = have - set(case_kinds.values())
    assert not missing, f"kernel families without a conformance case: {missing}"


# ---------------------------------------------------------------------------
# backward matrix: every registered gradient impl vs jax.vjp of the family's
# ref.py oracle (the same pullback ``executor.reference_vjp_grad`` serves as
# the capability fallback), every tuned config, f32 + bf16
# ---------------------------------------------------------------------------

# Backward tolerances get headroom over the forward table: a pullback chains
# the forward's reductions twice (recompute + transpose), so f32 kernels are
# pinned at 1e-4 (1e-3 for the recurrences, whose reverse scans re-associate
# the whole sequence) and bf16 at the format's ~3 digits with the same
# recurrence allowance.
GRAD_TOLERANCE = {
    "linear":     {"float32": (1e-4, 1e-4), "bfloat16": (5e-2, 5e-2)},
    "matmul":     {"float32": (1e-4, 1e-4), "bfloat16": (5e-2, 5e-2)},
    "attention":  {"float32": (1e-4, 1e-4), "bfloat16": (5e-2, 5e-2)},
    "attention_tp_shard": {"float32": (1e-4, 1e-4),
                           "bfloat16": (5e-2, 5e-2)},
    "rglru_scan": {"float32": (1e-3, 1e-4), "bfloat16": (5e-2, 5e-2)},
    "rwkv6_scan": {"float32": (1e-3, 1e-4), "bfloat16": (1e-1, 1e-1)},
    "fused":      {"float32": (1e-4, 1e-4), "bfloat16": (5e-2, 5e-2)},
    "avgpool":    {"float32": (1e-4, 1e-4), "bfloat16": (5e-2, 5e-2)},
}

# decode_attention and conv2d carry only the reference-vjp fallback, which
# IS the oracle — testing it against itself would be vacuous, so they are
# exempt here (the coverage guard below only demands cases for families
# with a non-reference backward).
GRAD_CASES = {op: CASES[op] for op in GRAD_TOLERANCE}


def _grad_oracle(node, vals, backend, ct):
    """``jax.vjp`` of the family's reference forward — per-input cotangents,
    None for non-inexact inputs.  Shared with the executor's capability
    fallback so the oracle and the fallback can never drift."""
    from repro.core.executor import reference_vjp_grad
    out = R._REFERENCE_IMPLS[node.op].fn(node, list(vals), backend)
    return out, reference_vjp_grad(node, (tuple(vals), out), ct, backend)


@pytest.mark.parametrize("backend_name", BACKENDS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("op", sorted(GRAD_CASES))
def test_grad_conformance(op, dtype, backend_name):
    """Every admissible *backward* impl of (op, backend, dtype) — and every
    config in its declared tune space — produces the same per-input
    cotangents as ``jax.vjp`` of the family's ref.py oracle, under the
    GRAD_TOLERANCE table."""
    backend = get_backend(backend_name)
    node, vals, _ref = GRAD_CASES[op](dtype)
    cands = R.grad_candidates(backend, node)
    if not cands or all(c.tier == R.TIER_REFERENCE for c in cands):
        pytest.skip(f"no non-reference backward for {op} on {backend_name}")
    rtol, atol = GRAD_TOLERANCE[op][dtype]
    ct = _arr(node.spec.shape, dtype)
    out, oracle = _grad_oracle(node, vals, backend, ct)
    res = (tuple(vals), out)
    ran = 0
    for impl in cands:
        configs = [None]
        if impl.tunable is not None:
            space = impl.tunable.tune_space(node, backend.hw)
            if space:
                configs = space
        for cfg in configs:
            if impl.tunable is not None:
                impl.tunable.bind_config(node, cfg)
            grads = impl.fn(node, res, ct, backend)
            assert len(grads) == len(vals), (impl.name, len(grads))
            for i, (g, o) in enumerate(zip(grads, oracle)):
                if o is None:
                    continue
                assert g is not None, \
                    f"{impl.name} dropped the input-{i} cotangent"
                np.testing.assert_allclose(
                    np.asarray(g, np.float32), np.asarray(o, np.float32),
                    rtol=rtol, atol=atol,
                    err_msg=f"{impl.name} d(input {i}) cfg={cfg} on "
                            f"{backend_name}/{dtype}")
            ran += 1
        if impl.tunable is not None:
            impl.tunable.bind_config(node, None)
    assert ran >= len(cands)


def test_grad_matrix_covers_every_backward_family():
    """Registering a non-reference backward impl forces a GRAD_CASES entry
    (or an explicit exemption here) — the backward matrix must not silently
    drop a family, mirroring the forward coverage guard."""
    R._load_entry_points()
    case_kinds = {
        "linear": OpKind.LINEAR, "matmul": OpKind.MATMUL,
        "attention": OpKind.ATTENTION,
        "attention_tp_shard": OpKind.ATTENTION,
        "rglru_scan": OpKind.RGLRU_SCAN, "rwkv6_scan": OpKind.RWKV6_SCAN,
        "fused": OpKind.FUSED, "avgpool": OpKind.AVGPOOL,
    }
    assert set(case_kinds) == set(GRAD_CASES)
    have = ({op for (_b, op) in R._GRAD_BACKEND_IMPLS}
            | set(R._GRAD_SHARED_IMPLS))
    missing = have - set(case_kinds.values())
    assert not missing, f"backward families without a grad case: {missing}"
