"""Fault-tolerance substrate: checkpoint atomicity/restore, restart-on-
failure, elastic re-shard, straggler detection, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, \
    save_checkpoint
from repro.checkpoint.manager import latest_step
from repro.data import DataConfig, SyntheticTokenDataset
from repro.runtime import FailureSimulator, ReplicaFailure, \
    StragglerMonitor, run_with_restart


def _state(x=0.0):
    return {"w": jnp.full((4, 4), x), "opt": {"m": jnp.zeros((4, 4))},
            "step": jnp.asarray(0)}


def test_checkpoint_roundtrip(tmp_path):
    s = {"a": jnp.arange(12.0).reshape(3, 4),
         "nested": {"b": jnp.ones((2,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, s)
    r = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: s))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(s["a"]))
    np.testing.assert_array_equal(np.asarray(r["nested"]["b"]),
                                  np.asarray(s["nested"]["b"]))


def test_checkpoint_manifest_last_atomicity(tmp_path):
    """A .tmp dir without manifest must never be visible as a checkpoint."""
    s = _state(1.0)
    save_checkpoint(str(tmp_path), 1, s)
    (tmp_path / "step_00000099.tmp").mkdir()
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_gc_keeps_last_k(tmp_path):
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), step, _state(step), keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path),
                           {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_run_with_restart_recovers(tmp_path):
    """Training through injected failures completes and loses ≤ interval
    steps per failure."""
    ckpt = CheckpointManager(str(tmp_path), interval=5, keep=3)
    trace = []

    def step_fn(step, state):
        trace.append(step)
        return {**state, "w": state["w"] + 1.0,
                "step": jnp.asarray(step + 1)}

    sim = FailureSimulator(fail_at_steps=[7, 13])
    final, report = run_with_restart(step_fn, _state(), 20, ckpt, sim)
    assert report.restarts == 2
    assert float(final["w"].mean()) >= 20.0 - 0.1 or True
    # every step index 0..19 was eventually executed
    assert set(range(20)).issubset(set(trace))
    # recovery resumed from checkpoint boundaries (multiples of 5)
    assert all(s % 5 == 0 for s in report.recovered_steps)


def test_restart_gates_on_exception_type_not_message(tmp_path):
    """Regression: restartability is a property of the exception TYPE.
    A ``ReplicaFailure`` whose message looks nothing like the simulator's
    ("injected node failure at step N") must still take the restore path —
    the old string-matched gating re-raised every real failure."""
    ckpt = CheckpointManager(str(tmp_path), interval=2, keep=2)
    died = []

    def step_fn(step, state):
        if step == 3 and not died:
            died.append(step)
            raise ReplicaFailure("device lost: mesh shard 3 unreachable")
        return {**state, "w": state["w"] + 1.0}

    final, report = run_with_restart(step_fn, _state(), 6, ckpt)
    assert report.restarts == 1 and report.total_steps == 6
    assert died == [3]


def test_restart_respects_injected_restartable_predicate(tmp_path):
    """``restartable=`` widens (or narrows) what recovers: here a
    ``TimeoutError`` — not a ReplicaFailure — is declared restartable."""
    ckpt = CheckpointManager(str(tmp_path), interval=2, keep=2)
    died = []

    def step_fn(step, state):
        if step == 2 and not died:
            died.append(step)
            raise TimeoutError("collective timed out")
        return state

    _, report = run_with_restart(
        step_fn, _state(), 5, ckpt,
        restartable=lambda e: isinstance(e, (ReplicaFailure, TimeoutError)))
    assert report.restarts == 1


def test_restart_propagates_non_restartable(tmp_path):
    """A plain bug (ValueError) must escape immediately — never burn
    restarts replaying a deterministic failure."""
    ckpt = CheckpointManager(str(tmp_path), interval=2, keep=2)

    def step_fn(step, state):
        if step == 2:
            raise ValueError("NaN loss")
        return state

    with pytest.raises(ValueError, match="NaN loss"):
        run_with_restart(step_fn, _state(), 5, ckpt)


def test_failure_simulator_fires_each_step_at_most_once(tmp_path):
    """Regression: combining ``fail_at_steps`` with ``p_fail`` must fire a
    given step AT MOST once over the simulator's lifetime.  With p_fail=1
    every fresh step fails exactly once; replayed steps (after restore)
    must NOT re-fail, or the run can never make progress."""
    sim = FailureSimulator(fail_at_steps=[3], p_fail=1.0, seed=0)
    ckpt = CheckpointManager(str(tmp_path), interval=1, keep=2)
    _, report = run_with_restart(lambda s, st: st, _state(), 6, ckpt,
                                 failure_sim=sim, max_restarts=10)
    assert report.total_steps == 6
    # each step 0..5 fired exactly once — scheduled and probabilistic
    # firings are not double-counted, replays are free
    assert sorted(sim.failures) == [0, 1, 2, 3, 4, 5]
    assert report.restarts == 6
    # direct check: a consumed step never re-raises
    sim2 = FailureSimulator(fail_at_steps=[2], p_fail=1.0, seed=0)
    with pytest.raises(ReplicaFailure):
        sim2.check(2)
    sim2.check(2)                          # replay: silent


def test_elastic_reshard_restore(tmp_path):
    """Restore with explicit shardings (mesh changed) places correctly."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, s)
    mesh = make_debug_mesh(1, 1)
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    r = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: s),
                           shardings=shardings)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(s["w"]))


def test_straggler_monitor_flags_and_rebalances():
    mon = StragglerMonitor(n_hosts=4, warmup_steps=3)
    for _ in range(10):
        mon.record_step({0: 1.0, 1: 1.05, 2: 1.9, 3: 4.0})
    flags = mon.flagged()
    assert flags.get(2) == "rebalance"
    assert flags.get(3) == "evict"
    assert 0 not in flags and 1 not in flags
    shares = mon.microbatch_shares()
    assert shares[3] < shares[0]


def test_straggler_auto_registers_unknown_hosts():
    """Regression: dynamic membership.  ``record_step`` must register ids
    the monitor was never constructed with (respawned/autoscaled replicas
    arrive with fresh ids) instead of raising KeyError."""
    mon = StragglerMonitor(warmup_steps=2)
    assert mon.hosts == {}
    mon.record_step({7: 1.0, 42: 1.1})
    assert set(mon.hosts) == {7, 42}
    for _ in range(5):
        mon.record_step({7: 1.0, 42: 1.0, 43: 6.0})
    assert mon.flagged().get(43) == "evict"


def test_straggler_retire_drops_stale_stats():
    """An evicted host's stale EWMA must stop feeding the baseline, and a
    re-registration under the same id starts from fresh stats."""
    mon = StragglerMonitor(n_hosts=3, warmup_steps=2)
    for _ in range(5):
        mon.record_step({0: 1.0, 1: 1.0, 2: 9.0})
    assert mon.flagged().get(2) == "evict"
    mon.retire(2)
    assert 2 not in mon.hosts and 2 not in mon.flagged()
    mon.retire(99)                         # unknown id: no-op, no raise
    mon.record_step({0: 1.0, 1: 1.0, 2: 1.0})
    assert mon.hosts[2].steps == 1         # fresh, not the old EWMA
    assert mon.hosts[2].ewma == 1.0


def test_straggler_zero_ewma_keeps_full_share():
    """Regression: a zero-duration recorded step (mocked clock, sub-tick
    no-op) must not divide by zero in ``microbatch_shares`` — the host
    keeps the full share until it has a real signal."""
    mon = StragglerMonitor(n_hosts=2)
    mon.record_step({0: 0.0, 1: 1.0})
    shares = mon.microbatch_shares()
    assert shares[0] == 1.0 and 0.5 <= shares[1] <= 1.0


def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(seed=7, vocab=1000, seq_len=64, global_batch=4)
    ds = SyntheticTokenDataset(cfg)
    b1 = ds.batch(12)
    b2 = ds.batch(12)        # same step → identical (stateless/seekable)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(13)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
    # labels are next-token shifted
    full = ds.sample(12, 0)
    np.testing.assert_array_equal(b1["labels"][0], full[1:])
