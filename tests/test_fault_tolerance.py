"""Fault-tolerance substrate: checkpoint atomicity/restore, restart-on-
failure, elastic re-shard, straggler detection, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, \
    save_checkpoint
from repro.checkpoint.manager import latest_step
from repro.data import DataConfig, SyntheticTokenDataset
from repro.runtime import FailureSimulator, StragglerMonitor, \
    run_with_restart


def _state(x=0.0):
    return {"w": jnp.full((4, 4), x), "opt": {"m": jnp.zeros((4, 4))},
            "step": jnp.asarray(0)}


def test_checkpoint_roundtrip(tmp_path):
    s = {"a": jnp.arange(12.0).reshape(3, 4),
         "nested": {"b": jnp.ones((2,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, s)
    r = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: s))
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(s["a"]))
    np.testing.assert_array_equal(np.asarray(r["nested"]["b"]),
                                  np.asarray(s["nested"]["b"]))


def test_checkpoint_manifest_last_atomicity(tmp_path):
    """A .tmp dir without manifest must never be visible as a checkpoint."""
    s = _state(1.0)
    save_checkpoint(str(tmp_path), 1, s)
    (tmp_path / "step_00000099.tmp").mkdir()
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_gc_keeps_last_k(tmp_path):
    for step in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), step, _state(step), keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path),
                           {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_run_with_restart_recovers(tmp_path):
    """Training through injected failures completes and loses ≤ interval
    steps per failure."""
    ckpt = CheckpointManager(str(tmp_path), interval=5, keep=3)
    trace = []

    def step_fn(step, state):
        trace.append(step)
        return {**state, "w": state["w"] + 1.0,
                "step": jnp.asarray(step + 1)}

    sim = FailureSimulator(fail_at_steps=[7, 13])
    final, report = run_with_restart(step_fn, _state(), 20, ckpt, sim)
    assert report.restarts == 2
    assert float(final["w"].mean()) >= 20.0 - 0.1 or True
    # every step index 0..19 was eventually executed
    assert set(range(20)).issubset(set(trace))
    # recovery resumed from checkpoint boundaries (multiples of 5)
    assert all(s % 5 == 0 for s in report.recovered_steps)


def test_elastic_reshard_restore(tmp_path):
    """Restore with explicit shardings (mesh changed) places correctly."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, s)
    mesh = make_debug_mesh(1, 1)
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    r = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: s),
                           shardings=shardings)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(s["w"]))


def test_straggler_monitor_flags_and_rebalances():
    mon = StragglerMonitor(n_hosts=4, warmup_steps=3)
    for _ in range(10):
        mon.record_step({0: 1.0, 1: 1.05, 2: 1.9, 3: 4.0})
    flags = mon.flagged()
    assert flags.get(2) == "rebalance"
    assert flags.get(3) == "evict"
    assert 0 not in flags and 1 not in flags
    shares = mon.microbatch_shares()
    assert shares[3] < shares[0]


def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(seed=7, vocab=1000, seq_len=64, global_batch=4)
    ds = SyntheticTokenDataset(cfg)
    b1 = ds.batch(12)
    b2 = ds.batch(12)        # same step → identical (stateless/seekable)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(13)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
    # labels are next-token shifted
    full = ds.sample(12, 0)
    np.testing.assert_array_equal(b1["labels"][0], full[1:])
