"""Per-kernel allclose tests vs the pure-jnp oracles, swept over shapes and
dtypes (interpret=True executes the TPU kernel bodies on CPU)."""
from _hypo import hypothesis, st  # real hypothesis, or skip-stubs when absent
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.avgpool import avgpool
from repro.kernels.avgpool.ref import avgpool_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul import matmul, tile_space
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

KEY = jax.random.PRNGKey(0)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("s,h,kv,hd,bq,bk", [
    (128, 4, 4, 32, 64, 64),     # MHA
    (128, 4, 2, 32, 32, 64),     # GQA
    (256, 8, 1, 16, 64, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(s, h, kv, hd, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (2, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (2, s, kv, hd), dtype)
    o = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    r = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,cap,causal", [
    (0, 0.0, True), (32, 0.0, True), (0, 20.0, True), (64, 50.0, True),
    (0, 0.0, False),
])
def test_flash_attention_variants(window, cap, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    o = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                        bq=32, bk=32, interpret=True)
    r = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        cap=cap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s,bq,bk,causal,window,cap", [
    (97, 512, 512, True, 0, 0.0),    # prime length, default-sized blocks
    (97, 32, 64, False, 0, 0.0),     # prime length, explicit uneven blocks
    (100, 64, 32, True, 32, 20.0),   # ragged + sliding window + softcap
])
def test_flash_attention_ragged_sequence(s, bq, bk, causal, window, cap):
    """ISSUE satellite regression: flash_attention_call used to hard-error
    on sequence lengths the blocks don't divide ('seq s must divide
    blocks'); ragged tails are now zero-padded and sliced like
    kernels/matmul, with padded key positions masked in-kernel."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, s, 4, 16))
    k = jax.random.normal(ks[1], (2, s, 2, 16))
    v = jax.random.normal(ks[2], (2, s, 2, 16))
    o = flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                        bq=bq, bk=bk, interpret=True)
    r = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        cap=cap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=1e-5, atol=1e-5)


def test_dfp_fused_split_program_matches_unsplit():
    """Fusion-group splitting is a pure perf knob: every legal max_group
    produces the same numerics as the single-launch program."""
    from benchmarks.autotune import _build
    from repro.kernels.dfp_fused.ops import dfp_fused, dfp_fused_segmented
    from repro.kernels.dfp_fused.program import encode_program, split_program
    node, vals = _build("fused", (64, 32))
    env = {id(i): v for i, v in zip(node.inputs, vals)}
    prog, operands = encode_program(node, env)
    ref = np.asarray(dfp_fused(prog, operands, interpret=True))
    for max_group in range(1, len(prog.instrs) + 1):
        segs = split_program(prog, max_group)
        # a pure chain has every split point, so the cap is always honoured
        assert all(len(p.instrs) <= max_group for p, _sel in segs)
        out = dfp_fused_segmented(prog, operands, max_group, interpret=True)
        np.testing.assert_allclose(np.asarray(out), ref,
                                   rtol=1e-6, atol=1e-6)


def test_flash_attention_matches_model_chunked_path():
    """Triangle check: Pallas kernel == model's jnp online-softmax scan."""
    from repro.models import layers as L
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 16))
    k = jax.random.normal(ks[1], (1, 256, 2, 16))
    v = jax.random.normal(ks[2], (1, 256, 2, 16))
    o1 = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    o2 = L._chunked_attention(q, k, v, causal=True, window=0, cap=0.0,
                              q_pos=jnp.arange(256), kv_pos=jnp.arange(256),
                              chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


# -- rglru --------------------------------------------------------------------

@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    b=st.integers(1, 3), t=st.sampled_from([8, 32, 96]),
    d=st.sampled_from([64, 128, 256]), seed=st.integers(0, 1000))
def test_rglru_scan_property(b, t, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, t, d)))
    bb = jax.random.normal(ks[1], (b, t, d)) * 0.1
    h0 = jax.random.normal(ks[2], (b, d)) * 0.1
    h1, hl1 = rglru_scan(a, bb, h0, bd=64, interpret=True)
    h2, hl2 = rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl1), np.asarray(hl2),
                               rtol=1e-4, atol=1e-5)


# -- rwkv6 --------------------------------------------------------------------

@pytest.mark.parametrize("t,h,hd", [(16, 2, 16), (64, 4, 32), (32, 1, 64)])
def test_rwkv6_scan_shapes(t, h, hd):
    ks = jax.random.split(KEY, 5)
    shape = (2, t, h, hd)
    r = jax.random.normal(ks[0], shape) * 0.5
    k = jax.random.normal(ks[1], shape) * 0.5
    v = jax.random.normal(ks[2], shape) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5)
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    s0 = jnp.zeros((2, h, hd, hd))
    o1, s1 = rwkv6_scan(r, k, v, logw, u, s0, interpret=True)
    o2, s2 = rwkv6_scan_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-5)


def test_rwkv6_nonzero_initial_state():
    ks = jax.random.split(KEY, 6)
    shape = (1, 8, 2, 8)
    r, k, v = (jax.random.normal(ks[i], shape) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5)
    u = jax.random.normal(ks[4], (2, 8)) * 0.3
    s0 = jax.random.normal(ks[5], (1, 2, 8, 8)) * 0.2
    o1, s1 = rwkv6_scan(r, k, v, logw, u, s0, interpret=True)
    o2, s2 = rwkv6_scan_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


# -- dfp fused ----------------------------------------------------------------

def _dfp_graph_and_inputs(seed, n_ops):
    """Random elementwise chain as an IR fusion group."""
    from repro.core import ir
    from repro.core.ir import Graph, Node, OpKind, TensorSpec
    from repro.core import passes
    rng = np.random.default_rng(seed)
    kinds = [OpKind.RELU, OpKind.GELU, OpKind.SILU, OpKind.TANH,
             OpKind.SIGMOID, OpKind.SOFTCAP, OpKind.SCALE]
    x = ir.input_node((4, 32))
    g1 = ir.param_node((32,), name="gain")
    cur = x
    for i in range(n_ops):
        op = kinds[rng.integers(len(kinds))]
        attrs = {}
        if op is OpKind.SOFTCAP:
            attrs = {"cap": 10.0}
        if op is OpKind.SCALE:
            attrs = {"value": 1.7}
        cur = Node(op, [cur], cur.spec, attrs=attrs)
    cur = Node(OpKind.RMSNORM, [cur, g1], cur.spec)
    return Graph([x], [cur], {"gain": g1})


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 10_000), n_ops=st.integers(1, 5))
def test_dfp_fused_kernel_vs_compose(seed, n_ops):
    """The Pallas DFP kernel and the XLA compose path agree for random
    fusion chains (the core DFP-correctness property)."""
    from repro.core import passes
    from repro.core.executor import lower_graph
    from repro.backends import get_backend
    params = {"gain": jnp.ones((32,)) * 1.1}
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
    ys = {}
    for bk in ("xla", "pallas_interpret"):
        g = _dfp_graph_and_inputs(seed, n_ops)
        g = passes.run_pipeline(g, get_backend(bk))
        ys[bk] = np.asarray(lower_graph(g, get_backend(bk))(params, x))
    np.testing.assert_allclose(ys["xla"], ys["pallas_interpret"],
                               rtol=1e-5, atol=1e-6)


# -- tiled MXU matmul ----------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),     # exactly one MXU tile
    (256, 128, 256),     # multi-tile, MXU-aligned
    (100, 70, 36),       # ragged in every dim
    (33, 128, 65),       # ragged M/N, aligned K
    (8, 8, 8),           # smaller than one tile
])
def test_matmul_parity_vs_einsum(m, k, n):
    """ISSUE acceptance: the tiled Pallas matmul matches the einsum
    reference at 1e-5 for shapes that are and aren't mxu_dim multiples."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n))
    y = matmul(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_matmul_k_loop_carry_multi_step():
    """K larger than the block forces the f32 VMEM accumulator to carry
    across grid steps (3 steps here: K=300, bk=128)."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (300, 300))
    w = jax.random.normal(ks[1], (300, 300))
    y = matmul(x, w, block=(128, 128, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-4)


def test_matmul_every_tile_config_agrees():
    """Every config in the autotune search space computes the same result —
    tile choice is a pure perf knob."""
    from repro.backends.registry import TPU_V5E
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (160, 200))
    w = jax.random.normal(ks[1], (200, 96))
    ref = np.asarray(matmul_ref(x, w))
    space = tile_space(160, 200, 96, TPU_V5E)
    assert len(space) >= 2
    for blk in space:
        y = matmul(x, w, block=blk, interpret=True)
        np.testing.assert_allclose(np.asarray(y), ref,
                                   rtol=1e-5, atol=1e-4)


def test_matmul_batched_and_bf16():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (2, 5, 48), jnp.bfloat16)
    w = jax.random.normal(ks[1], (48, 24), jnp.bfloat16)
    y = matmul(x, w, interpret=True)
    assert y.shape == (2, 5, 24)
    assert y.dtype == jnp.bfloat16
    # f32 accumulation: compare against the f32-accumulated oracle
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(matmul_ref(x, w), np.float32),
                               rtol=3e-2, atol=3e-2)


# -- avgpool (paper Listing 3) ------------------------------------------------

@pytest.mark.parametrize("n,c,hw,k", [(1, 4, 12, 3), (2, 8, 16, 5),
                                      (1, 1, 8, 2)])
def test_avgpool_listing3(n, c, hw, k):
    x = jax.random.normal(KEY, (n, c, hw, hw))
    np.testing.assert_allclose(
        np.asarray(avgpool(x, k, k, interpret=True)),
        np.asarray(avgpool_ref(x, k, k)), rtol=1e-5, atol=1e-6)
