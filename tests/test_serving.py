"""Serving-subsystem tests: continuous batching through the SOL pipeline.

Covers the ISSUE 5 acceptance surface: scheduler fairness (no request
starves), bucket-padding parity against an unbatched forward at 1e-5,
served elections matching ``impl_report(provenance=True)`` on the same
shapes, the deploy→serve round-trip, and the single-DMA batch staging."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import autotune as AT
from repro.frontends.offload import device
from repro.frontends.optimize import SolModel, optimize
from repro.launch.serve import (ProvenanceError, ServeConfig, SlotArena,
                                SolServer, embedding_table)
from repro.runtime import packed
from repro.runtime.async_queue import AsyncQueue


def tiny_cfg(**kw) -> ServeConfig:
    base = dict(d_model=32, n_heads=2, n_layers=1, vocab=64, max_seq=32,
                max_batch=2, slots=3, backend="xla")
    base.update(kw)
    return ServeConfig(**base)


@pytest.fixture(autouse=True)
def _native_mode_and_local_cache():
    """Native offload mode + a private autotune cache per test, so serving
    elections never leak into (or read from) the process-wide state other
    tests use."""
    device.set("cpu", 0, mode="native")
    prev = AT.get_cache()
    AT.set_cache(AT.AutotuneCache())
    yield
    AT.set_cache(prev)
    device.set("cpu", 0, mode="native")


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_fairness_no_starvation():
    """5 requests over 3 KV slots and a max_batch of 2: every request
    finishes, and while resident no request waits more than
    ceil(slots/max_batch) steps between serves (LRU round-robin bound)."""
    cfg = tiny_cfg(max_seq=16)
    server = SolServer(cfg)
    reqs = [server.submit([1 + i, 2, 3, 4], max_new_tokens=4)
            for i in range(5)]
    server.run()
    assert server.stats["admitted"] == 5
    assert server.stats["evicted"] == 5
    for r in reqs:
        assert r.done and len(r.generated) == 4
        gaps = np.diff(r.served_steps)
        assert gaps.size == 0 or gaps.max() <= 2, \
            f"request {r.rid} starved: served at steps {r.served_steps}"
    server.close()


def test_prefill_and_decode_interleave():
    """Admission happens mid-stream: a request submitted after serving has
    begun gets a freed/free slot and its prefill shares batches with the
    older requests' decode steps."""
    cfg = tiny_cfg(max_seq=16, slots=3)
    server = SolServer(cfg)
    a = server.submit([1, 2, 3], max_new_tokens=6)
    b = server.submit([4, 5], max_new_tokens=6)
    server.step()                       # both prefill
    late = server.submit([6, 7, 8], max_new_tokens=2)
    server.run()
    assert a.done and b.done and late.done
    # the late request was served while a/b were still decoding
    assert late.served_steps[0] <= max(a.served_steps[-1],
                                       b.served_steps[-1])
    assert server.stats["prefills"] == 3
    assert server.stats["decodes"] == server.stats["tokens"] - 3
    server.close()


def test_admission_blocks_when_slots_full():
    cfg = tiny_cfg(max_seq=16, slots=1, max_batch=2)
    server = SolServer(cfg)
    first = server.submit([1, 2], max_new_tokens=3)
    second = server.submit([3, 4], max_new_tokens=3)
    server.step()
    assert first.phase != "pending" and second.phase == "pending"
    assert server.arena.free_slots == 0
    server.run()
    assert first.done and second.done
    # eviction released the slot for the second request
    assert second.served_steps[0] > first.served_steps[-1]
    server.close()


def test_submit_validation():
    server = SolServer(tiny_cfg())
    with pytest.raises(ValueError):
        server.submit([], 4)
    with pytest.raises(ValueError):
        server.submit(list(range(1, 33)), 4)          # no room to decode
    with pytest.raises(ValueError):
        server.submit([999], 4)                       # out of vocab
    server.close()


# ---------------------------------------------------------------------------
# bucket padding ↔ autotune alignment
# ---------------------------------------------------------------------------

def test_ceil_pow2_buckets_are_their_own_cache_bucket():
    for d in (1, 2, 3, 5, 8, 9, 17, 31, 32, 33, 100):
        p = AT.ceil_pow2(d)
        assert p >= d and (p & (p - 1)) == 0
        assert AT.bucket_dim(p) == p        # pow2 is its own bucket
    assert AT.pad_shape((3, 11, 32)) == (4, 16, 32)


def test_bucket_padding_parity_vs_unbatched_forward():
    """A prompt of length 11 served through the padded (1, 16) bucket must
    produce the same next-token logits as an unpadded, unbatched (1, 11)
    forward through the same pipeline — at 1e-5."""
    cfg = tiny_cfg(max_batch=1, slots=1)
    server = SolServer(cfg)
    prompt = (np.arange(1, 12) % cfg.vocab).astype(np.int32)
    req = server.submit(prompt, max_new_tokens=1)
    server.run()
    assert req.done and req.last_logits is not None
    assert "1x16" in server.stats["buckets"]          # served padded

    x = embedding_table(cfg)[prompt][None]            # (1, 11, d_model)
    sol = optimize(server.model, (1, len(prompt), cfg.d_model),
                   backend=cfg.backend)
    ref = np.asarray(sol(jnp.asarray(x)))[0, -1]
    np.testing.assert_allclose(req.last_logits, ref, rtol=1e-5, atol=1e-5)
    server.close()


# ---------------------------------------------------------------------------
# elections + provenance
# ---------------------------------------------------------------------------

def test_served_elections_match_impl_report_with_measured_provenance():
    cfg = tiny_cfg()
    server = SolServer(cfg, strict_provenance=True)
    for i in range(3):
        server.submit([i + 1, 2, 3, 4, 5], max_new_tokens=3)
    counts = server.warm_autotune()
    assert counts["impls"] > 0
    server.run()
    assert server.served_elections
    for bucket, rec in server.served_elections.items():
        model = server._models[bucket]
        assert isinstance(model, SolModel)
        assert model.check_provenance() == []
        rep = model.impl_report(by_kind=True)
        prov = model.impl_report(provenance=True)
        for kind, impls in rec["by_op"].items():
            assert rep[kind] == impls, \
                f"served elections diverge from impl_report for {kind}"
            for name in impls:
                assert set(prov[name]["sources"]) == {"measured"}
    server.close()


def test_strict_provenance_cold_cache_is_loud():
    """With an empty autotune cache a strict server must refuse to serve —
    the 'silent roofline fallback' the smoke run exists to catch."""
    server = SolServer(tiny_cfg(), strict_provenance=True)
    server.submit([1, 2, 3], max_new_tokens=2)
    with pytest.raises(ProvenanceError, match="unmeasured"):
        server.run()
    server.close()


def test_strict_provenance_rejects_nearest_bucket_fallback():
    """'measured' provenance via the cache's nearest-bucket fallback is
    timings from a DIFFERENT shape: a strict server must refuse a bucket
    whose exact shapes were never measured, even when nearby buckets were
    — and an incremental re-warm (which skips covered buckets) unblocks."""
    cfg = tiny_cfg()
    server = SolServer(cfg, strict_provenance=True)
    server.submit([1, 2, 3, 4], max_new_tokens=2)
    server.warm_autotune()                   # covers seq bucket 8 only
    server.submit(list(range(1, 13)), max_new_tokens=2)   # opens seq 16
    with pytest.raises(ProvenanceError, match="nearest-bucket"):
        server.run()
    again = server.warm_autotune()           # warm the new bucket only
    assert again["nodes"] > 0 and again["skipped"] > 0
    server.run()
    assert all(r.done for r in server._finished)
    server.close()


def test_warm_autotune_skips_already_measured_buckets():
    cfg = tiny_cfg()
    server = SolServer(cfg)
    server.submit([1, 2, 3, 4], max_new_tokens=2)
    first = server.warm_autotune(warmup=0, iters=1)
    again = server.warm_autotune(warmup=0, iters=1)
    assert first["nodes"] > 0
    assert again["nodes"] == 0 and again["skipped"] >= first["nodes"]
    server.close()


# ---------------------------------------------------------------------------
# deploy → serve round-trip
# ---------------------------------------------------------------------------

def test_deploy_serve_roundtrip():
    cfg = tiny_cfg(max_seq=16, max_batch=2, slots=2)
    live = SolServer(cfg)
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    live_reqs = [live.submit(p, max_new_tokens=3) for p in prompts]
    live.run()
    arts = live.export_artifacts()
    assert arts, "live serving compiled no bucket models?"
    assert all(isinstance(b, bytes) for b in arts.values())

    replay = SolServer(cfg, deployed=arts)
    rep_reqs = [replay.submit(p, max_new_tokens=3) for p in prompts]
    replay.run()
    for a, b in zip(live_reqs, rep_reqs):
        assert a.generated == b.generated, \
            f"artifact serving diverged for request {a.rid}"
    # the artifact's election metadata mirrors the live model's report
    for bucket in arts:
        assert (replay._models[bucket].impl_report(by_kind=True)
                == live._models[bucket].impl_report(by_kind=True))
    # a bucket without an artifact is loud, never a silent live compile
    with pytest.raises(KeyError, match="deploy"):
        replay._model_for((8, 8))
    live.close()
    replay.close()


# ---------------------------------------------------------------------------
# staging + arena
# ---------------------------------------------------------------------------

def test_stage_batch_is_one_dma():
    packed.reset_transfer_stats()
    rows = [np.full((8, 4), i, np.float32) for i in range(3)]
    x = packed.stage_batch(rows)
    assert x.shape == (3, 8, 4)
    for i in range(3):
        assert float(np.asarray(x)[i, 0, 0]) == i
    assert packed.TRANSFER_STATS["packed_dmas"] == 1
    assert packed.TRANSFER_STATS["direct_dmas"] == 0
    with pytest.raises(ValueError, match="uniform"):
        packed.stage_batch([np.zeros((2,)), np.zeros((3,))])
    with pytest.raises(ValueError):
        packed.stage_batch([])


def test_serving_uses_one_dma_per_forward():
    """Each program dispatch stages its whole input set as one DMA: a step
    that runs both a prefill and a decode forward issues exactly two."""
    cfg = tiny_cfg(max_seq=16)
    server = SolServer(cfg)
    for i in range(3):
        server.submit([i + 1, 2, 3], max_new_tokens=2)
    packed.reset_transfer_stats()
    summary = server.run()
    assert summary["dmas"] == summary["forwards"]
    assert summary["forwards"] >= summary["steps"]
    assert (packed.TRANSFER_STATS["packed_dmas"]
            + packed.TRANSFER_STATS["direct_dmas"]) == summary["dmas"]
    server.close()


def test_reforward_baseline_uses_one_dma_per_step():
    """The decode=False baseline keeps the old invariant: one mixed-phase
    forward, one packed DMA, per scheduler step."""
    cfg = tiny_cfg(max_seq=16, decode=False)
    server = SolServer(cfg)
    for i in range(3):
        server.submit([i + 1, 2, 3], max_new_tokens=2)
    packed.reset_transfer_stats()
    summary = server.run()
    assert summary["mode"] == "reforward"
    assert summary["dmas"] == summary["steps"] == summary["forwards"]
    assert packed.TRANSFER_STATS["packed_dmas"] == summary["steps"]
    server.close()


def test_slot_arena_admission_eviction_and_pointer_append():
    q = AsyncQueue()
    arena = SlotArena(q, n_slots=2, max_seq=8)
    s0 = arena.admit(np.asarray([5, 6, 7], np.int32))
    s1 = arena.admit(np.asarray([9], np.int32))
    assert arena.admit(np.asarray([1], np.int32)) is None   # full
    arena.append(s0, 42)
    q.synchronize()
    assert arena.tokens(s0).tolist() == [5, 6, 7, 42]
    assert arena.tokens(s1).tolist() == [9]
    arena.evict(s1)
    s2 = arena.admit(np.asarray([2, 3], np.int32))          # slot reused
    assert s2 is not None
    q.synchronize()
    assert arena.tokens(s2).tolist() == [2, 3]
    q.close()


def test_slot_arena_rejects_oversized_prompt():
    q = AsyncQueue()
    arena = SlotArena(q, n_slots=1, max_seq=4)
    with pytest.raises(ValueError, match="exceeds"):
        arena.admit(np.arange(5, dtype=np.int32))
    assert arena.free_slots == 1       # nothing leaked
    q.close()


# ---------------------------------------------------------------------------
# incremental decode program (ISSUE 6 tentpole)
# ---------------------------------------------------------------------------

def test_decode_program_matches_reforward_baseline():
    """The incremental decode path (prefill seeds the KV slots, then one
    DECODE_ATTENTION token step per tick) must reproduce the full
    re-forward baseline token-for-token, and its final-step logits to
    1e-5 — same workload, same greedy sampling, two schedulers."""
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    from repro.launch.serve import build_lm
    model = build_lm(tiny_cfg(max_seq=16))     # ONE weight init, two paths
    runs = {}
    for decode in (True, False):
        cfg = tiny_cfg(max_seq=16, decode=decode)
        server = SolServer(cfg, model)
        reqs = [server.submit(p, max_new_tokens=4) for p in prompts]
        server.run()
        runs[decode] = reqs
        server.close()
    for a, b in zip(runs[True], runs[False]):
        assert a.generated == b.generated, \
            f"decode path diverged for request {a.rid}"
        np.testing.assert_allclose(a.last_logits, b.last_logits,
                                   rtol=1e-5, atol=1e-5)


def test_decode_buckets_and_elections():
    """Decode steps run through (batch, cache)-bucketed decode programs
    whose elections include the DECODE_ATTENTION op — the decode forward
    never silently falls back to the full program."""
    cfg = tiny_cfg(max_seq=32)
    server = SolServer(cfg)
    server.submit([1, 2, 3, 4, 5, 6, 7], max_new_tokens=8)
    summary = server.run()
    assert summary["mode"] == "decode"
    assert any(k.startswith("d") for k in summary["buckets"]), \
        f"no decode buckets served: {summary['buckets']}"
    decode_keys = [k for k in server._models if k[0] == "decode"]
    assert decode_keys
    for key in decode_keys:
        by_op = server.served_elections[key]["by_op"]
        assert "decode_attention" in by_op, \
            f"decode bucket {key} elected no DECODE_ATTENTION impl"
    # prefill ran exactly once per request; every other token was O(1)
    assert summary["prefills"] == 1
    assert summary["decodes"] == summary["tokens"] - 1
    server.close()


def test_decode_input_size_is_cache_bucket_not_history():
    """O(1)-per-token structurally: the decode program's input bytes are a
    function of the CACHE bucket, not of how many steps already ran — the
    re-forward baseline's per-step bytes instead grow with the context."""
    cfg = tiny_cfg(max_seq=32)
    server = SolServer(cfg)
    server.submit([1, 2, 3], max_new_tokens=12)
    sizes = []
    orig = packed.stage_inputs

    def spy(arrays, device=None):
        sizes.append(sum(a.nbytes for a in arrays))
        return orig(arrays, device)

    packed.stage_inputs = spy
    try:
        server.run()
    finally:
        packed.stage_inputs = orig
    # first token came from prefill; the other 11 are one decode DMA each
    assert len(sizes) == 11
    # within one cache bucket the staged bytes are constant
    assert len(set(sizes[:4])) == 1, sizes      # cache lens 3..6 → cb 8
    server.close()


def test_slot_arena_kv_regions_pointer_append_and_gather():
    q = AsyncQueue()
    arena = SlotArena(q, n_slots=2, max_seq=4,
                      kv_row_shapes=[(2, 3), (2, 3)])
    s = arena.admit(np.asarray([7], np.int32))
    rows0 = np.arange(12, dtype=np.float32).reshape(2, 2, 3)
    arena.write_kv_rows(s, 0, 0, rows0)               # seed rows [0, 2)
    arena.write_kv_rows(s, 1, 1, rows0[:1] + 100.0)   # append at row 1
    q.synchronize()
    np.testing.assert_array_equal(arena.kv_rows(s, 0, 2), rows0)
    np.testing.assert_array_equal(arena.kv_rows(s, 1, 2)[1],
                                  rows0[0] + 100.0)
    with pytest.raises(ValueError, match="overflows"):
        arena.write_kv_rows(s, 0, 3, rows0)           # rows [3, 5) > max 4
    arena.evict(s)
    s2 = arena.admit(np.asarray([1], np.int32))       # regions recycled
    assert s2 is not None
    q.synchronize()
    q.close()


# ---------------------------------------------------------------------------
# sampling determinism (ISSUE 6 satellite)
# ---------------------------------------------------------------------------

def _run_sampling(cfg, model, sampling):
    server = SolServer(cfg, model)
    reqs = [server.submit([3, 1, 4, 1], max_new_tokens=5,
                          sampling=sampling),
            server.submit([2, 7, 1], max_new_tokens=5, sampling=sampling)]
    server.run()
    server.close()
    return [r.generated for r in reqs]


def test_sampling_same_seed_is_identical_across_runs():
    from repro.launch.serve import SamplingParams, build_lm
    cfg = tiny_cfg(max_seq=16)
    model = build_lm(cfg)
    sp = SamplingParams(temperature=0.8, top_k=8, top_p=0.9, seed=123)
    assert _run_sampling(cfg, model, sp) == _run_sampling(cfg, model, sp)


def test_sampling_live_vs_deployed_identical():
    """Temperature sampling replayed through deployed artifacts must
    reproduce the live tokens exactly: same logits bits, same per-request
    seeded generator."""
    from repro.launch.serve import SamplingParams
    cfg = tiny_cfg(max_seq=16)
    sp = SamplingParams(temperature=0.7, top_p=0.95, seed=42)
    live = SolServer(cfg)
    live_reqs = [live.submit([5, 6, 7], max_new_tokens=4, sampling=sp),
                 live.submit([8, 9], max_new_tokens=4, sampling=sp)]
    live.run()
    replay = SolServer(cfg, deployed=live.export_artifacts())
    rep_reqs = [replay.submit([5, 6, 7], max_new_tokens=4, sampling=sp),
                replay.submit([8, 9], max_new_tokens=4, sampling=sp)]
    replay.run()
    for a, b in zip(live_reqs, rep_reqs):
        assert a.generated == b.generated
    live.close()
    replay.close()


def test_sampling_edge_cases_reduce_to_greedy_and_full_mass():
    from repro.launch.serve import SamplingParams, sample_token
    rng = np.random.default_rng(0)
    logits = np.asarray([0.1, 2.5, -1.0, 0.4], np.float32)
    # top_k=1 keeps only the argmax regardless of temperature
    sp1 = SamplingParams(temperature=1.3, top_k=1, seed=0)
    for _ in range(5):
        assert sample_token(logits, sp1, rng) == int(np.argmax(logits))
    # top_p=1.0 is plain temperature sampling: same seed → same token
    spa = SamplingParams(temperature=0.9, top_p=1.0, seed=5)
    ta = sample_token(logits, spa, np.random.default_rng(5))
    tb = sample_token(logits, spa, np.random.default_rng(5))
    assert ta == tb
    # temperature<=0 is greedy and consumes no randomness
    assert sample_token(logits, SamplingParams(), None) \
        == int(np.argmax(logits))


def test_sampling_params_validation():
    from repro.launch.serve import SamplingParams
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
